"""Prediction-service load bench: replay heavy mixed traffic, compare
measured latency percentiles against the analytic SLO self-model, and
prove the batching front saves compiled dispatches.

Three tenants replay the traffic mix the ROADMAP names:

* ``sweeper``   — bursts of ``mode="simulate"`` sweep cells (the
  paper-kernel grid on both CPU models, both schedulers), repeated
  rounds so later rounds exercise the cross-request cache;
* ``interactive`` — steady single-point analytic requests;
* ``hlo-dryrun`` — HLO module dry-runs (the TPU serving path).

The replay records per-request latency envelopes
(:class:`repro.service.ServiceResponse`), then:

1. **SLO validation** — the service's busy-period self-model
   (``repro.service.slo``, calibrated only from arrival rates, the
   batch window and measured dispatch costs) predicts p50/p99; the
   bench records measured vs. predicted into ``BENCH_service.json``.
   Cache hits bypass the queue entirely, so the SLO comparison is over
   the *queued* (non-cache-hit) requests; the all-traffic percentiles
   are recorded alongside.
2. **Dispatch accounting** — the same requests are issued serially
   through a fresh ``AnalysisService.predict`` / ``predict_hlo``; the
   service must have issued *strictly fewer* compiled dispatches
   (cohort batching turns one round of sweep cells into one
   ``simulate_many`` dispatch per machine model) with bit-identical
   results.
3. **Admission probe** — a deliberately tiny service (queue depth and
   token bucket both small) replays a burst and must reject explicitly
   (``AdmissionError``), not queue unboundedly.

Usage::

    PYTHONPATH=src python benchmarks/service_bench.py \
        [--fast] [--out BENCH_service.json] [--check]

``--check`` (the CI ``service-smoke`` gate) exits non-zero unless:
zero dropped requests at nominal load; the SLO p99 prediction is
within 50% of measurement; the service issued strictly fewer compiled
dispatches than the serial baseline; results are bit-identical.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import random
import sys
import time


_HLO_MODULES = {
    "dot64": """
HloModule dot64, entry_computation_layout={()->f32[64,64]{1,0}}

ENTRY %main.1 () -> f32[64,64] {
  %a = f32[64,64]{1,0} constant({...})
  ROOT %d = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
""",
    "chain512": """
HloModule chain512, entry_computation_layout={()->f32[512,512]{1,0}}

ENTRY %main.1 () -> f32[512,512] {
  %a = f32[512,512]{1,0} constant({...})
  %d = f32[512,512]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %s = f32[512,512]{1,0} add(%d, %d)
}
""",
    "wide128": """
HloModule wide128, entry_computation_layout={()->f32[128,128]{1,0}}

ENTRY %main.1 () -> f32[128,128] {
  %a = f32[128,128]{1,0} constant({...})
  %b = f32[128,128]{1,0} constant({...})
  %x = f32[128,128]{1,0} add(%a, %a)
  %y = f32[128,128]{1,0} multiply(%b, %b)
  ROOT %d = f32[128,128]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
""",
}


def _sweep_cells():
    """The matched kernel x arch grid (each triad on its own model —
    the pairs on which the tick-loop and batch drivers are locked
    bit-identical by tests/test_sweep_engine.py)."""
    from repro.core import paper_kernels as pk
    return [("skl", pk.TRIAD_SKL_O3), ("zen", pk.TRIAD_ZEN_O3),
            ("skl", pk.PI_O1), ("zen", pk.PI_O1),
            ("skl", pk.PI_O2), ("zen", pk.PI_O2),
            ("skl", pk.PI_SKL_O3), ("zen", pk.PI_ZEN_O3)]


def build_traffic(fast: bool = False, seed: int = 0):
    """``[(offset_s, ServiceRequest), ...]`` for the nominal replay."""
    from repro.core.engine import AnalysisRequest
    from repro.service import HloRequest, ServiceRequest

    rng = random.Random(seed)
    cells = _sweep_cells()
    rounds = 2 if fast else 4
    n_interactive = 16 if fast else 48
    n_hlo = 6 if fast else 12
    span = 1.2 if fast else 2.5      # arrival horizon (seconds)
    traffic: list[tuple[float, ServiceRequest]] = []

    # sweeper: one burst of the full grid per round (both schedulers)
    for r in range(rounds):
        t0 = r * span / rounds
        for arch, src in cells:
            for sched in ("uniform", "balanced"):
                traffic.append((t0 + rng.uniform(0, 0.01),
                                ServiceRequest(
                    analysis=AnalysisRequest(kernel=src, arch=arch,
                                             scheduler=sched,
                                             mode="simulate"),
                    tenant="sweeper", tag=f"round{r}")))

    # interactive: steady single analytic points, heavy duplicates
    for i in range(n_interactive):
        arch, src = cells[rng.randrange(len(cells))]
        traffic.append((rng.uniform(0, span), ServiceRequest(
            analysis=AnalysisRequest(kernel=src, arch=arch),
            tenant="interactive", tag=f"pt{i}")))

    # hlo dry-runs: the serving path, a few distinct modules
    names = list(_HLO_MODULES)
    for i in range(n_hlo):
        text = _HLO_MODULES[names[i % len(names)]]
        traffic.append((rng.uniform(0, span), ServiceRequest(
            hlo=HloRequest(text=text), tenant="hlo-dryrun",
            tag=f"hlo{i}")))

    traffic.sort(key=lambda t: t[0])
    return traffic


def _percentiles(xs: list[float]) -> dict[str, float]:
    if not xs:
        return {"count": 0, "p50_s": 0.0, "p99_s": 0.0, "max_s": 0.0}
    ys = sorted(xs)

    def q(p: float) -> float:
        i = p * (len(ys) - 1)
        lo = int(i)
        hi = min(lo + 1, len(ys) - 1)
        return ys[lo] + (ys[hi] - ys[lo]) * (i - lo)

    return {"count": len(ys), "p50_s": round(q(0.50), 6),
            "p90_s": round(q(0.90), 6), "p99_s": round(q(0.99), 6),
            "max_s": round(ys[-1], 6)}


def _result_signature(sreq, result) -> tuple:
    """The exact-comparison fields for bit-identity between the
    service (batched) and serial (per-request) paths."""
    if sreq.analysis is not None:
        return (result.predicted_cycles, result.port_bound_cycles,
                result.lcd_cycles, result.bound_sim, result.binding)
    t = result.terms
    return (t.bound_combined, t.bound_overlap, t.critical_path_s)


def serial_baseline(traffic) -> tuple[list[tuple], int]:
    """The same requests, in arrival order, through per-request
    ``AnalysisService.predict`` / ``predict_hlo`` on a fresh engine.
    Returns (result signatures, compiled dispatch count)."""
    from repro.core.engine import AnalysisService
    engine = AnalysisService()
    sigs = []
    for _, sreq in traffic:
        if sreq.analysis is not None:
            res = engine.predict(sreq.analysis)
        else:
            h = sreq.hlo
            res = engine.predict_hlo(
                h.text, ici_links=h.ici_links, flop_dtype=h.flop_dtype,
                mode=h.mode, machine=h.machine,
                working_set=h.working_set)
        sigs.append(_result_signature(sreq, res))
    # each cold simulate cell is one tick-loop dispatch; each unique
    # HLO module is one analysis dispatch
    return sigs, engine.stats.sim_runs + engine.stats.hlo_misses


def admission_probe() -> dict:
    """A deliberately tiny service must reject a burst explicitly."""
    from repro.core import paper_kernels as pk
    from repro.core.engine import AnalysisRequest
    from repro.service import (PredictionService, ServiceConfig,
                               ServiceRequest, TenantPolicy, replay)

    svc = PredictionService(config=ServiceConfig(
        batch_window_s=0.005, max_queue_depth=8,
        default_policy=TenantPolicy(max_in_flight=4, rate_per_s=50.0,
                                    burst=4.0)))
    burst = [(0.0, ServiceRequest(
        analysis=AnalysisRequest(kernel=pk.PI_O1, arch="skl",
                                 unroll_factor=1 + (i % 8)),
        tenant="flooder")) for i in range(32)]
    resps = replay(svc, burst)
    from repro.service import AdmissionError
    rejected = sum(1 for r in resps
                   if isinstance(r.error, AdmissionError))
    served = sum(1 for r in resps if r.ok)
    return {"requests": len(burst), "rejected": rejected,
            "served": served,
            "rejected_reasons": sorted(
                {r.error.reason for r in resps
                 if isinstance(r.error, AdmissionError)})}


def chaos_probe() -> dict:
    """Persistent single-backend failure must degrade, never drop.

    A fault plan kills every dispatch on the primary simulation
    backend; the degradation ladder (docs/robustness.md) must demote
    each affected cohort down the rungs, every admitted request must
    still resolve, affected responses must carry ``degraded=True``
    with the fallback backend recorded, and the circuit breaker must
    visibly open and half-open across replay rounds."""
    from repro.core import (AnalysisService, BreakerConfig, FaultPlan,
                            FaultSpec)
    from repro.core.engine import AnalysisRequest
    from repro.core.sim import has_jax
    from repro.service import (PredictionService, ServiceConfig,
                               ServiceRequest, replay)

    primary = "jit" if has_jax() else "numpy"
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": primary}),))
    engine = AnalysisService(
        faults=plan,
        breaker_config=BreakerConfig(failure_threshold=1,
                                     cooldown_s=0.05))
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.01, backend=primary,
        cache_ttl_s=0.0))       # no cross-request hits: every round
    #                             must re-enter the engine

    cells = _sweep_cells()
    rounds = 3
    resolved = 0
    degraded = []
    for r in range(rounds):
        burst = [(0.0, ServiceRequest(
            analysis=AnalysisRequest(kernel=src, arch=arch,
                                     mode="simulate"),
            tenant="chaos", tag=f"round{r}")) for arch, src in cells]
        resps = replay(svc, burst)
        resolved += sum(1 for x in resps if x.ok or x.error is not None)
        degraded += [x for x in resps if x.ok and x.degraded]
        # past the breaker cooldown, so the next round probes the dead
        # primary rung through half_open instead of skipping it while
        # open; drop the memoized results so the cohort re-dispatches
        time.sleep(0.08)
        svc.engine.drop_results()

    snap = engine.breakers.snapshot()
    transitions = {e["to"] for e in snap["events"]}
    fallbacks = sorted({x.backend_used for x in degraded})
    return {
        "primary_backend": primary,
        "requests": rounds * len(cells),
        "resolved": resolved,
        "dropped": rounds * len(cells) - resolved,
        "degraded_responses": len(degraded),
        "fallback_backends": fallbacks,
        "fallback_recorded": bool(degraded) and all(
            x.backend_used and x.backend_used != primary
            for x in degraded),
        "breaker_transitions": sorted(transitions),
        "breaker_opened": "open" in transitions,
        "breaker_half_opened": "half_open" in transitions,
        "fault_events": engine.faults.summary(),
    }


def routing_probe() -> dict:
    """Breaker-aware routing (docs/robustness.md#health-aware-routing):
    once the pallas rung's breaker is open, the :class:`HealthRouter`
    must start every later cohort below it — zero dispatch attempts
    against the open rung, zero drops, and the skip recorded as
    provenance (``routed_from``) on every affected response.

    The fault plan kills the pallas dispatch before the driver runs,
    so the probe is jax-independent: round one trips the breaker, and
    from then on any further pallas attempt is a routing bug, not a
    scheduled probe (the cooldown is far past the bench horizon)."""
    from repro.core import (AnalysisService, BreakerConfig, FaultPlan,
                            FaultSpec, HealthRouter)
    from repro.core.engine import AnalysisRequest
    from repro.service import (PredictionService, ServiceConfig,
                               ServiceRequest, replay)

    primary = "pallas"
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": primary}),))
    engine = AnalysisService(
        faults=plan, router=HealthRouter(),
        breaker_config=BreakerConfig(failure_threshold=1,
                                     cooldown_s=300.0))
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.01, backend=primary, cache_ttl_s=0.0))

    cells = _sweep_cells()
    rounds = 3
    resolved = 0
    routed = []
    attempts_round1 = None
    for r in range(rounds):
        burst = [(0.0, ServiceRequest(
            analysis=AnalysisRequest(kernel=src, arch=arch,
                                     mode="simulate"),
            tenant="router", tag=f"round{r}")) for arch, src in cells]
        resps = replay(svc, burst)
        resolved += sum(1 for x in resps if x.ok)
        routed += [x for x in resps if x.ok and x.routed_from]
        if attempts_round1 is None:
            attempts_round1 = engine.stats.rung_attempts.get(primary, 0)
        svc.engine.drop_results()

    attempts_final = engine.stats.rung_attempts.get(primary, 0)
    return {
        "primary_backend": primary,
        "requests": rounds * len(cells),
        "resolved": resolved,
        "dropped": rounds * len(cells) - resolved,
        "primary_attempts_round1": attempts_round1,
        "primary_attempts_after_trip": attempts_final - attempts_round1,
        "routed_responses": len(routed),
        "routed_from_recorded": bool(routed) and all(
            x.routed_from == primary and x.backend_used != primary
            for x in routed),
        "routed_groups": engine.stats.routed_groups,
        "router_stats": engine.router.snapshot()["stats"],
    }


def retry_probe() -> dict:
    """Retry governance (docs/robustness.md#retry-budgets): transient
    dispatch faults must be retried under capped full-jitter backoff
    and resolve, while a tenant with an exhausted retry budget must
    fail fast with an explicit reason instead of looping."""
    from repro.core import AnalysisService, FaultPlan, FaultSpec
    from repro.service import (PredictionService, ServiceConfig,
                               ServiceRequest, TenantPolicy, replay)
    from repro.service.request import HloRequest

    def burst(tenant):
        # hlo_parse faults propagate as DispatchError (the ladder does
        # not contain the parse stage), so they drive the retry loop
        return [(0.0, ServiceRequest(
            hlo=HloRequest(text=_HLO_MODULES["dot64"]),
            tenant=tenant))]

    # transient: two parse failures, then clean — governed retries win
    engine = AnalysisService(faults=FaultPlan(specs=(
        FaultSpec(point="engine.hlo_parse", mode="fail", count=2),)))
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.005, max_retries=3, retry_backoff_s=0.005,
        retry_backoff_cap_s=0.02))
    ok_resps = replay(svc, burst("patient"))
    tele = svc.telemetry
    recovered = all(r.ok for r in ok_resps)
    retries = sum(c.retries for c in tele.cohort_classes.values())
    sleeps = tele.retry_sleep.count

    # exhausted budget: same transient fault, but the tenant has no
    # retry tokens — the response must fail fast with the reason
    engine2 = AnalysisService(faults=FaultPlan(specs=(
        FaultSpec(point="engine.hlo_parse", mode="fail", count=2),)))
    svc2 = PredictionService(engine2, ServiceConfig(
        batch_window_s=0.005, max_retries=3, retry_backoff_s=0.005,
        default_policy=TenantPolicy(retry_rate_per_s=0.0,
                                    retry_burst=0.0)))
    broke_resps = replay(svc2, burst("broke"))
    failed_fast = all((not r.ok) and r.error is not None
                      and "retry budget" in str(r.error)
                      for r in broke_resps)
    exhausted = svc2.telemetry.tenant("broke").retry_budget_exhausted
    return {
        "recovered": recovered,
        "retries": retries,
        "retry_sleeps_recorded": sleeps,
        "budget_failed_fast": failed_fast,
        "budget_exhausted_count": exhausted,
    }


def run_bench(fast: bool = False) -> dict:
    from repro.service import PredictionService, ServiceConfig, replay

    window = 0.02
    traffic = build_traffic(fast=fast)
    svc = PredictionService(config=ServiceConfig(
        batch_window_s=window, max_queue_depth=1024,
        backend="numpy"))       # grouped vectorized dispatch, always
    t0 = time.perf_counter()
    resps = replay(svc, traffic)
    wall = time.perf_counter() - t0

    dropped = sum(1 for r in resps if not r.ok)
    queued = [r for r in resps if r.ok and not r.cache_hit]
    measured_queued = _percentiles([r.total_s for r in queued])
    measured_all = _percentiles([r.total_s for r in resps if r.ok])
    prediction = svc.predict_slo()

    # warm tail: replay a slice of the same traffic against the (still
    # warm) cross-request cache — these must be submit-time cache hits
    rng = random.Random(1)
    tail = [(rng.uniform(0, 0.1), sreq)
            for _, sreq in traffic[:: max(1, len(traffic) // 10)]]
    tail_resps = replay(svc, tail)
    tail_hits = sum(1 for r in tail_resps if r.ok and r.cache_hit)
    stats = svc.export_stats()

    sigs_service = [_result_signature(r.request, r.result)
                    for r in resps if r.ok]
    sigs_service += [_result_signature(r.request, r.result)
                     for r in tail_resps if r.ok]
    sigs_serial, serial_dispatches = serial_baseline(
        [t for t, r in zip(traffic, resps) if r.ok]
        + [t for t, r in zip(tail, tail_resps) if r.ok])
    bit_identical = sigs_service == sigs_serial
    service_dispatches = svc.telemetry.engine_dispatches

    p99_meas = measured_queued["p99_s"]
    p99_pred = prediction.p99_s
    p99_ratio = (p99_pred / p99_meas) if p99_meas else float("inf")

    report = {
        "benchmark": "service_bench",
        "host": {"cpu_count": os.cpu_count(),
                 "platform": platform.platform(),
                 "python": platform.python_version()},
        "config": {"fast": fast, "batch_window_s": window,
                   "backend": "numpy"},
        "traffic": {
            "requests": len(traffic),
            "tenants": sorted({r.tenant for _, r in traffic}),
            "kinds": {
                "x86_simulate": sum(
                    1 for _, r in traffic
                    if r.analysis is not None
                    and r.analysis.mode == "simulate"),
                "x86_analytic": sum(
                    1 for _, r in traffic
                    if r.analysis is not None
                    and r.analysis.mode == "analytic"),
                "hlo": sum(1 for _, r in traffic if r.hlo is not None),
            },
            "wall_s": round(wall, 4),
        },
        "dropped": dropped,
        "measured": measured_queued,
        "measured_all": measured_all,
        "predicted": {
            "p50_s": round(prediction.p50_s, 6),
            "p99_s": round(prediction.p99_s, 6),
            "utilization": round(prediction.utilization, 4),
            "per_class": prediction.per_class,
        },
        "slo": {
            "p99_measured_s": p99_meas,
            "p99_predicted_s": round(p99_pred, 6),
            "p99_ratio": round(p99_ratio, 4),
            "within_50pct": bool(0.5 <= p99_ratio <= 1.5),
        },
        "dispatches": {"service": service_dispatches,
                       "serial": serial_dispatches},
        "bit_identical": bit_identical,
        "warm_tail": {"requests": len(tail), "cache_hits": tail_hits},
        "cache": stats["cache"],
        "stages": stats["stages"],
        "batch_size": stats["batch_size"],
        "tenants": stats["tenants"],
        "engine_hit_rates": stats["engine_hit_rates"],
        "admission_probe": admission_probe(),
        "chaos_probe": chaos_probe(),
        "routing_probe": routing_probe(),
        "retry_probe": retry_probe(),
    }
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smaller replay (CI service-smoke)")
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on dropped requests, SLO p99 "
                         "off by >50%%, no dispatch savings, or "
                         "result drift")
    args = ap.parse_args()

    report = run_bench(fast=args.fast)
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=2)

    m, p = report["measured"], report["predicted"]
    print(f"replayed {report['traffic']['requests']} requests "
          f"({', '.join(report['traffic']['tenants'])}) in "
          f"{report['traffic']['wall_s']}s, dropped {report['dropped']}")
    print(f"measured  p50 {m['p50_s'] * 1e3:8.2f} ms   "
          f"p99 {m['p99_s'] * 1e3:8.2f} ms  "
          f"({m['count']} queued requests)")
    print(f"predicted p50 {p['p50_s'] * 1e3:8.2f} ms   "
          f"p99 {p['p99_s'] * 1e3:8.2f} ms  "
          f"(utilization {p['utilization']})")
    d = report["dispatches"]
    wt = report["warm_tail"]
    print(f"dispatches: service {d['service']} vs serial "
          f"{d['serial']}  bit_identical={report['bit_identical']}  "
          f"warm tail {wt['cache_hits']}/{wt['requests']} cache hits "
          f"(overall hit rate {report['cache']['hit_rate']:.3f})")
    ap_ = report["admission_probe"]
    print(f"admission probe: {ap_['rejected']}/{ap_['requests']} "
          f"rejected ({', '.join(ap_['rejected_reasons'])})")
    cp = report["chaos_probe"]
    print(f"chaos probe [{cp['primary_backend']} down]: "
          f"{cp['resolved']}/{cp['requests']} resolved, "
          f"{cp['degraded_responses']} degraded via "
          f"{', '.join(cp['fallback_backends']) or '-'}; breaker "
          f"transitions: {', '.join(cp['breaker_transitions']) or '-'}")
    rt = report["routing_probe"]
    print(f"routing probe [{rt['primary_backend']} tripped]: "
          f"{rt['resolved']}/{rt['requests']} resolved, "
          f"{rt['routed_responses']} routed past the open rung "
          f"({rt['primary_attempts_after_trip']} attempts after trip)")
    rp = report["retry_probe"]
    print(f"retry probe: recovered={rp['recovered']} after "
          f"{rp['retries']} governed retries; budget fail-fast="
          f"{rp['budget_failed_fast']} "
          f"({rp['budget_exhausted_count']} exhausted)")
    print(f"wrote {args.out}")

    if args.check:
        failures = []
        if report["dropped"]:
            failures.append(f"{report['dropped']} requests dropped at "
                            "nominal load")
        if not report["slo"]["within_50pct"]:
            failures.append(
                f"SLO self-model p99 off by more than 50% "
                f"(predicted {report['slo']['p99_predicted_s']}s vs "
                f"measured {report['slo']['p99_measured_s']}s, ratio "
                f"{report['slo']['p99_ratio']})")
        if d["service"] >= d["serial"]:
            failures.append(
                f"no dispatch savings: service {d['service']} vs "
                f"serial {d['serial']}")
        if not report["bit_identical"]:
            failures.append("service results drifted from serial "
                            "predict")
        if not wt["cache_hits"]:
            failures.append("warm tail produced no cross-request "
                            "cache hits")
        if not ap_["rejected"]:
            failures.append("admission probe rejected nothing")
        if cp["dropped"]:
            failures.append(f"chaos probe dropped {cp['dropped']} "
                            "requests under single-backend failure")
        if not (cp["degraded_responses"] and cp["fallback_recorded"]):
            failures.append("chaos probe responses not flagged "
                            "degraded with a fallback backend "
                            "recorded")
        if not (cp["breaker_opened"] and cp["breaker_half_opened"]):
            failures.append(
                f"breaker open/half-open not visible in telemetry "
                f"(saw: {cp['breaker_transitions']})")
        if rt["dropped"]:
            failures.append(f"routing probe dropped {rt['dropped']} "
                            "requests with the primary rung open")
        if rt["primary_attempts_after_trip"]:
            failures.append(
                f"router allowed {rt['primary_attempts_after_trip']} "
                f"dispatch attempts against the open "
                f"{rt['primary_backend']} rung")
        if not (rt["routed_responses"] and rt["routed_from_recorded"]):
            failures.append("routed responses missing routed_from/"
                            "backend_used provenance")
        if not (rp["recovered"] and rp["retries"]
                and rp["retry_sleeps_recorded"]):
            failures.append("transient faults did not recover through "
                            "governed retries")
        if not (rp["budget_failed_fast"]
                and rp["budget_exhausted_count"]):
            failures.append("exhausted retry budget did not fail fast "
                            "with an explicit reason")
        if failures:
            for f_ in failures:
                print(f"FAIL: {f_}", file=sys.stderr)
            raise SystemExit(1)


if __name__ == "__main__":
    main()
