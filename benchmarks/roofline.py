"""§Roofline report: per (arch x shape x mesh) three-term roofline from
the dry-run artifacts (results/dryrun_baseline.json), with MODEL_FLOPS =
6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode) usefulness ratios and
the roofline fraction used as the §Perf score."""
from __future__ import annotations

import json
import os

from repro.configs import get_config
from repro.core.arch.registry import get_model

# Hardware numbers single-sourced from the registry's machine-model
# artifact — the same constants the HLO analyzer prices with — so this
# report cannot drift from the prediction path
# (tests/test_benchmarks.py pins the identity).
_TPU = get_model("tpu_v5e").constants
PEAK = _TPU["peak_flops"]["bf16"]
HBM_BW = _TPU["hbm_bw"]
SHAPE_TOKENS = {
    "train_4k": (4096, 256), "prefill_32k": (32768, 32),
    "decode_32k": (32768, 128), "long_500k": (524288, 1),
}


def _attention_flops_fwd(cfg, S: int, B: int) -> float:
    """Score+PV matmul FLOPs per forward (global, all layers)."""
    kinds = cfg.layer_kinds()
    total = 0.0
    for kind in kinds:
        if kind == "attn":
            keys = min(S, cfg.window) if cfg.attention == "swa" else S
            frac = 0.5 if (cfg.causal and cfg.attention != "swa") else 1.0
            total += 4.0 * B * S * keys * frac * cfg.n_heads * cfg.d_head
        else:
            Q, H, P, N = (cfg.ssm_chunk, cfg.ssm_heads,
                          cfg.ssm_head_dim, cfg.ssm_state)
            # CB (Q^2 N) + intra w*x (Q^2 ... per token Q) + state io
            total += 2.0 * B * S * (Q * N + Q * H * P + 2 * H * P * N)
    return total


def model_flops(record: dict) -> float:
    """Useful model FLOPs (global): 6/2·N·D parameter work plus the
    attention/SSD mixer work the 6ND rule does not cover."""
    S, B = SHAPE_TOKENS[record["shape"]]
    cfg = get_config(record["arch"])
    n = record.get("active_params") or record["params"]
    attn = _attention_flops_fwd(cfg, S, B)
    if record["shape"] == "train_4k":
        return 6.0 * n * S * B + 3.0 * attn
    if record["step"] in ("prefill_step", "encode_step"):
        return 2.0 * n * S * B + attn
    return 2.0 * n * B          # decode: one token per sequence


def decode_useful_bytes(record: dict) -> float:
    """Decode is bandwidth-bound: the useful work per step is reading the
    active parameters once plus the KV/SSM state for every sequence."""
    S, B = SHAPE_TOKENS[record["shape"]]
    cfg = get_config(record["arch"])
    n = record.get("active_params") or record["params"]
    kinds = cfg.layer_kinds()
    cache = 0.0
    for kind in kinds:
        if kind == "attn":
            keys = min(S, cfg.window) if cfg.attention == "swa" else S
            cache += 2.0 * B * keys * cfg.n_kv_heads * cfg.d_head * 2
        else:
            cache += B * cfg.ssm_heads * cfg.ssm_head_dim \
                * cfg.ssm_state * 4
    return 2.0 * n + cache


def analyse_record(r: dict) -> dict:
    pm = r["portmodel"]
    chips = r["n_chips"]
    useful = model_flops(r)
    useful_s = useful / (chips * PEAK)
    hlo_flops_global = pm["mxu_flops_per_device"] * chips
    bound = pm["bound_overlap_s"]
    if r["step"] == "serve_step":
        # decode cells: bandwidth roofline (params + state per step)
        useful_s = decode_useful_bytes(r) / (chips * HBM_BW)
    return {
        "name": f"{r['arch']}|{r['shape']}|{r['mesh']}",
        "step": r["step"],
        "compute_s": pm["compute_s"],
        "memory_s": pm["memory_s"],
        "collective_s": pm["collective_s"],
        "dominant": pm["dominant"],
        "model_flops": useful,
        "hlo_flops": hlo_flops_global,
        "useful_ratio": useful / hlo_flops_global
        if hlo_flops_global else 0.0,
        "useful_s": useful_s,
        "bound_s": bound,
        "roofline_fraction": useful_s / bound if bound else 0.0,
        "temp_gib": r["memory"].get("temp_size_in_bytes", 0) / 2 ** 30,
    }


def load(path: str = "results/dryrun_baseline.json") -> list[dict]:
    with open(path) as f:
        return json.load(f)


def report(path: str = "results/dryrun_baseline.json",
           mesh: str | None = "16x16") -> list[dict]:
    rows = []
    for r in load(path):
        if mesh and r.get("mesh") != mesh:
            continue
        if r["status"] != "ok":
            rows.append({"name": f"{r['arch']}|{r['shape']}|{r['mesh']}",
                         "skipped": r.get("reason", r["status"])})
            continue
        rows.append(analyse_record(r))
    return rows


def render_markdown(path: str = "results/dryrun_baseline.json",
                    mesh: str = "16x16") -> str:
    rows = report(path, mesh)
    out = ["| arch | shape | step | compute [s] | memory [s] | "
           "collective [s] | dominant | 6ND/HLO | roofline frac | "
           "temp GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        arch, shape, _ = r["name"].split("|")
        if "skipped" in r:
            out.append(f"| {arch} | {shape} | — | — | — | — | "
                       f"SKIPPED: {r['skipped']} | — | — | — |")
            continue
        out.append(
            f"| {arch} | {shape} | {r['step']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3%} | {r['temp_gib']:.1f} |")
    return "\n".join(out)


def compare(baseline_path: str = "results/dryrun_baseline.json",
            v1_path: str = "results/dryrun_v1.json",
            mesh: str = "16x16") -> str:
    """Before/after table across the whole fleet (§Perf)."""
    base = {r["name"]: r for r in report(baseline_path, mesh)
            if "skipped" not in r}
    new = {r["name"]: r for r in report(v1_path, mesh)
           if "skipped" not in r}
    out = ["| cell | bound v0 [s] | bound v1 [s] | speedup | frac v0 | "
           "frac v1 |", "|---|---|---|---|---|---|"]
    total_gain = []
    for name in sorted(base):
        if name not in new:
            continue
        b, n = base[name], new[name]
        gain = b["bound_s"] / n["bound_s"] if n["bound_s"] else 0
        total_gain.append(gain)
        arch, shape, _ = name.split("|")
        out.append(f"| {arch} × {shape} | {b['bound_s']:.2f} | "
                   f"{n['bound_s']:.2f} | {gain:.2f}× | "
                   f"{b['roofline_fraction']:.2%} | "
                   f"{n['roofline_fraction']:.2%} |")
    if total_gain:
        import math
        geo = math.exp(sum(math.log(max(g, 1e-9)) for g in total_gain)
                       / len(total_gain))
        out.append(f"\ngeomean speedup v0→v1: {geo:.2f}× over "
                   f"{len(total_gain)} cells")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "compare":
        print(compare())
    else:
        print(render_markdown(*sys.argv[1:]))
