"""Benchmark harness: one function per paper table plus the TPU-adaptation
reports.  Prints ``name,us_per_call,derived`` CSV rows (run.py contract).

    PYTHONPATH=src python -m benchmarks.run [--fast] [--skip-host]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _csv(row: dict) -> str:
    name = row.pop("name")
    us = row.pop("us_per_call", "")
    derived = ";".join(f"{k}={_fmt(v)}" for k, v in row.items())
    return f"{name},{_fmt(us)},{derived}"


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller ibench sweeps")
    ap.add_argument("--skip-host", action="store_true",
                    help="skip wall-clock host benchmarks (CI)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()

    # ---- paper tables (static predictions; exact) -------------------
    from benchmarks import paper_tables
    for table, fn in paper_tables.ALL_TABLES.items():
        for row in fn():
            print(_csv(dict(row)))

    # ---- sweep-engine throughput (perf trajectory) ------------------
    # writes BENCH_sweep.json and emits one CSV row per batch size; see
    # benchmarks/sweep_bench.py and docs/performance.md.  Wall-clock
    # timing like the host benches, so --skip-host skips it too.
    if args.skip_host:
        print("sweep_bench/skipped,,run benchmarks.sweep_bench directly")
    else:
        from benchmarks.sweep_bench import run_bench
        sweep_report = run_bench(fast=args.fast)
        with open("BENCH_sweep.json", "w", encoding="utf-8") as f:
            json.dump(sweep_report, f, indent=2)
        for brow in sweep_report["batches"]:
            row = {"name": f"sweep_bench/batch{brow['batch']}"}
            for backend, r in brow["backends"].items():
                row[f"{backend}_pts_per_s"] = r["points_per_s"]
            if "speedup_jit_vs_numpy" in brow:
                row["speedup_jit_vs_numpy"] = \
                    brow["speedup_jit_vs_numpy"]
                row["speedup_jit_vs_pointwise"] = \
                    brow["speedup_jit_vs_pointwise"]
            print(_csv(row))
        sw = sweep_report["sweep"]
        print(_csv({"name": "sweep_bench/service_grid",
                    "backend": sw["backend"],
                    "cold_cells_per_s": sw["cold_cells_per_s"],
                    "warm_cells_per_s": sw["warm_cells_per_s"],
                    "group_dispatches": sw["group_dispatches"],
                    "sim_runs": sw["sim_runs"]}))
        # the full ServiceStats.hit_rate() breakdown: a cache
        # regression (cold programs, re-resolved machines, ...) shows
        # up here in every bench run, not only in the --check gate
        print(_csv({"name": "sweep_bench/cache_hit_rates",
                    **{f"{k}_hit_rate": sw["hit_rates"][k]
                       for k in ("result", "lookup", "lp", "edge",
                                 "program", "classify", "machine")}}))
        # journal health: replay hits on resume plus the compacted
        # on-disk footprint of the 10k-cell kill/resume probe
        # (docs/robustness.md#journal-segments)
        rs, cpn = sweep_report["resume"], sweep_report["compaction"]
        print(_csv({"name": "sweep_bench/journal",
                    "resume_journal_hits": rs["journal_hits"],
                    "resume_bit_identical": rs["resume_bit_identical"],
                    "compaction_cells": cpn["cells"],
                    "compaction_journal_hits": cpn["journal_hits"],
                    "journal_records": cpn["journal_final"]["records"],
                    "journal_segments": cpn["journal_final"]["segments"],
                    "journal_loose_files":
                        cpn["journal_final"]["loose_files"],
                    "journal_bytes": cpn["journal_final"]["bytes"]}))

    # ---- prediction-service load replay (docs/serving-service.md) ---
    if args.skip_host:
        print("service_bench/skipped,,run benchmarks.service_bench "
              "directly")
    else:
        from benchmarks.service_bench import run_bench as run_service
        service_report = run_service(fast=args.fast)
        with open("BENCH_service.json", "w", encoding="utf-8") as f:
            json.dump(service_report, f, indent=2)
        m, p = service_report["measured"], service_report["predicted"]
        print(_csv({"name": "service_bench/latency",
                    "requests": service_report["traffic"]["requests"],
                    "measured_p50_s": m["p50_s"],
                    "measured_p99_s": m["p99_s"],
                    "predicted_p50_s": p["p50_s"],
                    "predicted_p99_s": p["p99_s"]}))
        print(_csv({"name": "service_bench/dispatch",
                    "service_dispatches":
                        service_report["dispatches"]["service"],
                    "serial_dispatches":
                        service_report["dispatches"]["serial"],
                    "bit_identical":
                        service_report["bit_identical"],
                    "dropped": service_report["dropped"]}))

    # ---- roofline reports over the dry-run sweeps ---------------------
    # v0 = paper-faithful framework baseline; v1 = beyond-baseline
    # optimized defaults (EXPERIMENTS.md §Perf) — both recorded.
    from benchmarks.roofline import compare, report
    for tag, path in (("v0", "results/dryrun_baseline.json"),
                      ("v1", "results/dryrun_v1.json")):
        if not os.path.exists(path):
            print(f"roofline-{tag}/missing,,run repro.launch.dryrun "
                  f"--all --out {path}")
            continue
        for mesh in ("16x16", "2x16x16"):
            for row in report(path, mesh):
                row = dict(row)
                row["name"] = f"roofline-{tag}/{mesh}/" + row.pop("name")
                if "skipped" in row:
                    print(_csv({"name": row["name"],
                                "skipped": row["skipped"]}))
                else:
                    row.pop("model_flops", None)
                    row.pop("hlo_flops", None)
                    print(_csv(row))
    if os.path.exists("results/dryrun_v1.json") and \
            os.path.exists("results/dryrun_baseline.json"):
        lines = compare().splitlines()
        if lines and lines[-1].startswith("geomean"):
            print(f"roofline/geomean_speedup_v0_v1,,{lines[-1]}")

    # ---- host measurements (paper Sec. II/III methodology) ----------
    if not args.skip_host:
        from benchmarks.host_validation import all_host_benchmarks
        for row in all_host_benchmarks():
            print(_csv(dict(row)))
        from benchmarks.ibench_suite import (conflict_probe, host_model,
                                             ibench_sweep)
        for row in ibench_sweep(fast=True):
            print(_csv(dict(row)))
        for row in conflict_probe():
            print(_csv(dict(row)))
        for row in host_model():
            print(_csv(dict(row)))

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
