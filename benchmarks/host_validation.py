"""Paper Sec. III-A/B analogue on the host: run the Schoenauer triad and
the pi kernel in JAX, measure iterations/s, and compare with the
throughput prediction from the semi-automatically built host machine
model — the same predict-vs-measure loop as the paper's Tables III/V,
executed on the hardware we actually have."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _measure(fn, *args, repeats: int = 5) -> float:
    fn(*args)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def triad_benchmark(size: int = 1_000_000, reps: int = 20) -> dict:
    b = jnp.ones((size,), jnp.float32)
    c = jnp.full((size,), 1.5, jnp.float32)
    d = jnp.full((size,), 0.5, jnp.float32)

    @jax.jit
    def run(b, c, d):
        def body(_, a):
            return b + c * d + a * 0  # a[:] = b + c*d, kept live
        return jax.lax.fori_loop(0, reps, body, b)

    seconds = _measure(run, b, c, d)
    it_per_s = size * reps / seconds
    flops = 2 * size * reps / seconds
    return {
        "name": "host/triad",
        "us_per_call": seconds * 1e6,
        "Mit_per_s": it_per_s / 1e6,
        "MFLOP_per_s": flops / 1e6,
    }


def pi_benchmark(slices: int = 2_000_000) -> dict:
    @jax.jit
    def run():
        delta = 1.0 / slices
        def body(i, s):
            x = (i + 0.5) * delta
            return s + 4.0 / (1.0 + x * x)
        return jax.lax.fori_loop(0, slices, body, 0.0) * delta

    seconds = _measure(run)
    value = float(run())
    return {
        "name": "host/pi",
        "us_per_call": seconds * 1e6,
        "Mit_per_s": slices / seconds / 1e6,
        "abs_err_vs_pi": abs(value - np.pi),
    }


def all_host_benchmarks() -> list[dict]:
    return [triad_benchmark(), pi_benchmark()]
