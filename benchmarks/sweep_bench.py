"""Sweep-throughput benchmark: the perf trajectory of the batch engine.

Measures points/sec of the three ways this repo can run a
``mode="simulate"`` sweep point and writes ``BENCH_sweep.json``:

* ``pointwise`` — the legacy hot path: one reference tick-loop
  simulation (``sim.pipeline.simulate``) per sweep point, which is what
  ``AnalysisService.sweep`` dispatched before the grouped planner.
* ``numpy`` — the vectorized struct-of-arrays driver
  (``simulate_many(backend="numpy")``).
* ``jit`` — the compiled driver (``backend="jit"``): sharded
  ``jax.jit`` recurrence, float64, bit-compatible with numpy to 1e-9.

It also runs a service-level grid through the grouped
``AnalysisService.sweep`` planner and records the cache hit rates
(result/edge/program/classify/machine) plus the number of compiled
group dispatches — the counters that tell you whether a production
sweep is amortizing its preprocessing.

Usage::

    PYTHONPATH=src python benchmarks/sweep_bench.py \
        [--fast] [--out BENCH_sweep.json] [--check]

``--check`` exits non-zero if the jit backend is slower than numpy at
any batch >= 64, if an ECM re-sweep leaves the planner fast path, or
if the recompute pass (sweep again after ``drop_results()`` expiry)
fails to reuse any compiled ``SimProgram`` (the program cache must not
be cold across successive sweeps).  See docs/performance.md for how to
read the output.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time


def _build_programs():
    """Compile the paper kernels on both CPU models (prep is excluded
    from the timed region — the planner memoizes it in production)."""
    from repro.core import extract_kernel
    from repro.core import paper_kernels as pk
    from repro.core.arch.skylake import build_skylake_db
    from repro.core.arch.zen import build_zen_db
    from repro.core.sim import compile_program

    skl, zen = build_skylake_db(), build_zen_db()
    cases = [("skl", pk.TRIAD_SKL_O3), ("zen", pk.TRIAD_ZEN_O3),
             ("skl", pk.PI_O1), ("zen", pk.PI_O1),
             ("skl", pk.PI_O2), ("zen", pk.PI_O2),
             ("skl", pk.PI_SKL_O3), ("zen", pk.PI_ZEN_O3)]
    return [compile_program(extract_kernel(src),
                            skl if arch == "skl" else zen)
            for arch, src in cases]


def _time(fn, repeats: int = 1) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_batches(batches: list[int], repeats: int = 2) -> list[dict]:
    """Driver throughput at each batch size; same programs, bit-equal
    results across backends (asserted)."""
    from repro.core.sim import has_jax, simulate, simulate_many

    base = _build_programs()
    rows = []
    for B in batches:
        progs = (base * (-(-B // len(base))))[:B]
        row: dict = {"batch": B, "backends": {}}

        # legacy pointwise reference: constant per-point cost, so the
        # rate is measured on a bounded prefix
        n_pt = min(B, 16)
        t_pt = _time(lambda: [simulate(p) for p in progs[:n_pt]])
        row["backends"]["pointwise"] = {
            "points_per_s": round(n_pt / t_pt, 2),
            "measured_points": n_pt,
        }

        t_np = _time(lambda: simulate_many(progs, backend="numpy"),
                     repeats)
        row["backends"]["numpy"] = {
            "seconds": round(t_np, 4),
            "points_per_s": round(B / t_np, 2),
        }

        if has_jax():
            res_np = simulate_many(progs, backend="numpy")
            t_cold = _time(lambda: simulate_many(progs, backend="jit"))
            t_jit = _time(lambda: simulate_many(progs, backend="jit"),
                          repeats)
            res_jit = simulate_many(progs, backend="jit")
            drift = max(abs(a.cycles_per_iteration -
                            b.cycles_per_iteration)
                        for a, b in zip(res_np, res_jit))
            assert drift < 1e-9, f"backend drift {drift}"
            row["backends"]["jit"] = {
                "cold_seconds": round(t_cold, 4),
                "seconds": round(t_jit, 4),
                "points_per_s": round(B / t_jit, 2),
                "max_drift_vs_numpy": drift,
            }
            row["speedup_jit_vs_numpy"] = round(t_np / t_jit, 2)
            row["speedup_jit_vs_pointwise"] = round(
                (B / t_jit) / row["backends"]["pointwise"]
                ["points_per_s"], 2)
        rows.append(row)
    return rows


def bench_sweep(cells_target: int = 1024) -> dict:
    """A service-level grid through the grouped planner: cache hit
    rates and dispatch counts for a ~``cells_target``-cell sweep."""
    from repro.core import AnalysisService
    from repro.core import paper_kernels as pk

    from repro.core.sim import has_jax

    kernels = {"triad_skl": pk.TRIAD_SKL_O3, "triad_zen": pk.TRIAD_ZEN_O3,
               "pi_o1": pk.PI_O1, "pi_o2": pk.PI_O2,
               "pi_skl_o3": pk.PI_SKL_O3, "pi_zen_o3": pk.PI_ZEN_O3}
    # force the compiled driver: "auto" would pick numpy here (each
    # machine group holds only len(kernels) unique programs, below
    # AUTO_JIT_MIN_BATCH), and the recorded trajectory must say which
    # driver it measured
    backend = "jit" if has_jax() else "numpy"
    svc = AnalysisService(sim_backend=backend)
    reps = max(1, cells_target // (len(kernels) * 2 * 2))
    # cold: the first grid pays parsing, analytic passes, program
    # compilation and the grouped dispatches; warm: every further grid
    # is the dedupe/cache path a steady-state sweeping service runs on.
    # The two rates answer different questions — keep them separate.
    t0 = time.perf_counter()
    grid = svc.sweep(kernels, archs=("skl", "zen"),
                     schedulers=("uniform", "balanced"),
                     mode="simulate")
    cold_dt = time.perf_counter() - t0
    cells = len(grid)
    t1 = time.perf_counter()
    warm_cells = 0
    for _ in range(reps - 1):
        warm_cells += len(svc.sweep(
            kernels, archs=("skl", "zen"),
            schedulers=("uniform", "balanced"), mode="simulate"))
    warm_dt = time.perf_counter() - t1
    # recompute pass: expire the volatile caches (results, sims) the
    # way a persistent service does when result TTLs lapse, then sweep
    # again — compiled SimPrograms and dependency edges must be
    # *reused* (program-cache hits), not recompiled.  Before this pass
    # existed the program cache recorded hit rate 0.0 on every bench
    # run: nothing ever exercised reuse across sweeps.
    t2 = time.perf_counter()
    svc.drop_results()
    recompute_cells = len(svc.sweep(
        kernels, archs=("skl", "zen"),
        schedulers=("uniform", "balanced"), mode="simulate"))
    recompute_dt = time.perf_counter() - t2
    program_hit_rate = svc.stats.hit_rate("program")
    # ECM pass over the already-swept grid (docs/ecm.md): must reuse
    # every cached analytic pass and simulation — the working set only
    # keys the traffic memo, never the sim cache
    sim_runs_before = svc.stats.sim_runs
    dispatches_before = svc.stats.sim_group_dispatches
    t3 = time.perf_counter()
    ecm_grid = svc.sweep(kernels, archs=("skl", "zen"),
                         schedulers=("uniform", "balanced"),
                         mode="simulate", working_set=64.0 * 2**20)
    ecm_dt = time.perf_counter() - t3
    ecm_extra_sims = svc.stats.sim_runs - sim_runs_before
    ecm_extra_dispatches = (svc.stats.sim_group_dispatches
                            - dispatches_before)
    s = svc.stats
    return {
        "backend": backend,
        "cells": cells + warm_cells,
        "cold_cells": cells,
        "cold_seconds": round(cold_dt, 4),
        "cold_cells_per_s": round(cells / cold_dt, 2),
        "warm_cells": warm_cells,
        "warm_seconds": round(warm_dt, 4),
        "warm_cells_per_s": round(warm_cells / warm_dt, 2)
        if warm_dt else 0.0,
        "sim_runs": s.sim_runs,
        "group_dispatches": s.sim_group_dispatches,
        "recompute_cells": recompute_cells,
        "recompute_seconds": round(recompute_dt, 4),
        "program_hits": s.program_hits,
        "program_hit_rate": round(program_hit_rate, 4),
        "ecm_cells": len(ecm_grid),
        "ecm_seconds": round(ecm_dt, 4),
        "ecm_cells_per_s": round(len(ecm_grid) / ecm_dt, 2)
        if ecm_dt else 0.0,
        "ecm_extra_sim_runs": ecm_extra_sims,
        "ecm_extra_group_dispatches": ecm_extra_dispatches,
        "hit_rates": {k: round(s.hit_rate(k), 4)
                      for k in ("result", "lookup", "lp", "edge",
                                "program", "classify", "machine")},
        "stats": s.as_dict(),
    }


def bench_resume() -> dict:
    """Crash-safe resume probe (docs/robustness.md): journal a sweep,
    kill it mid-flight with an injected abort, resume from the journal
    and demand a bit-identical grid with zero re-dispatch of the
    journaled machine group."""
    import tempfile

    from repro.core import AnalysisService, FaultPlan, FaultSpec
    from repro.core import paper_kernels as pk
    from repro.core.faults import FaultAbort

    kernels = {"triad_skl": pk.TRIAD_SKL_O3, "pi_o2": pk.PI_O2}
    sweep_kw = dict(archs=("skl", "zen"), schedulers=("uniform",),
                    mode="simulate")

    t0 = time.perf_counter()
    reference = AnalysisService(sim_backend="numpy").sweep(
        kernels, **sweep_kw)
    ref_dt = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        # the second engine.dispatch fire (the zen machine group) dies
        # the way a SIGKILL would: no containment, no ladder, the sweep
        # call never returns.  The skl group's record is already on
        # disk by then — RecordJournal.append is atomic per record.
        plan = FaultPlan(specs=(
            FaultSpec(point="engine.dispatch", mode="abort", skip=1),))
        killed = AnalysisService(sim_backend="numpy", faults=plan)
        aborted = False
        try:
            killed.sweep(kernels, journal=td, **sweep_kw)
        except FaultAbort:
            aborted = True

        resumed_svc = AnalysisService(sim_backend="numpy")
        t1 = time.perf_counter()
        resumed = resumed_svc.sweep(kernels, journal=td,
                                    resume_from=td, **sweep_kw)
        resume_dt = time.perf_counter() - t1

    identical = (set(resumed) == set(reference) and all(
        (resumed[k].predicted_cycles, resumed[k].bound_sim,
         resumed[k].binding)
        == (reference[k].predicted_cycles, reference[k].bound_sim,
            reference[k].binding)
        for k in reference))
    s = resumed_svc.stats
    return {
        "cells": len(reference),
        "aborted_mid_sweep": aborted,
        "journal_hits": s.journal_hits,
        "group_dispatches_on_resume": s.sim_group_dispatches,
        "resume_bit_identical": identical,
        "reference_seconds": round(ref_dt, 4),
        "resume_seconds": round(resume_dt, 4),
    }


def bench_compaction(cells_target: int = 10000,
                     segment_size: int = 8) -> dict:
    """Journal-compaction probe (docs/robustness.md#journal-segments):
    a >= ``cells_target``-cell journaled sweep over many derived
    machine models is killed mid-run, resumed through at least one
    compaction cycle, and must come back bit-identical with zero
    re-dispatch of journaled groups while the journal keeps
    O(segments) live files instead of O(records).

    The grid is wide, not deep: kernel-name aliases share two unique
    kernel texts and the derived machines share the base model's
    tables, so the engine's dedupe keeps the compute bounded — the
    probe measures journal mechanics at 10k-cell scale, not the
    simulator."""
    import tempfile

    from repro.core import (AnalysisService, FaultPlan, FaultSpec,
                            get_model)
    from repro.core import paper_kernels as pk
    from repro.core.faults import FaultAbort
    from repro.core.journal import SweepJournal

    n_machines = 25
    kill_after = n_machines // 2
    base = get_model("skl")
    texts = [pk.TRIAD_SKL_O3, pk.PI_O2]
    n_names = -(-cells_target // n_machines)
    kernels = {f"k{i:04d}": texts[i % len(texts)]
               for i in range(n_names)}

    def service(**kw):
        svc = AnalysisService(sim_backend="numpy", **kw)
        archs = tuple(svc.register(base.derive(f"skl_v{i:03d}"))
                      for i in range(n_machines))
        return svc, archs

    sweep_kw = dict(schedulers=("uniform",), mode="simulate",
                    backend="numpy")

    svc_ref, archs = service()
    t0 = time.perf_counter()
    reference = svc_ref.sweep(kernels, archs=archs, **sweep_kw)
    ref_dt = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        # simulated SIGKILL after kill_after machine groups journaled
        plan = FaultPlan(specs=(
            FaultSpec(point="engine.dispatch", mode="abort",
                      skip=kill_after),))
        svc_kill, archs_k = service(faults=plan)
        aborted = False
        try:
            svc_kill.sweep(kernels, archs=archs_k, journal=td,
                           journal_segment_size=segment_size, **sweep_kw)
        except FaultAbort:
            aborted = True
        mid = SweepJournal(td).stats()
        svc_res, archs_r = service()
        t1 = time.perf_counter()
        resumed = svc_res.sweep(kernels, archs=archs_r, journal=td,
                                resume_from=td,
                                journal_segment_size=segment_size,
                                **sweep_kw)
        resume_dt = time.perf_counter() - t1
        final = SweepJournal(td).stats()

    identical = (set(resumed) == set(reference) and all(
        (resumed[k].predicted_cycles, resumed[k].bound_sim,
         resumed[k].binding)
        == (reference[k].predicted_cycles, reference[k].bound_sim,
            reference[k].binding)
        for k in reference))
    s = svc_res.stats
    return {
        "cells": len(reference),
        "machine_groups": n_machines,
        "segment_size": segment_size,
        "aborted_mid_sweep": aborted,
        "journal_hits": s.journal_hits,
        "group_dispatches_on_resume": s.sim_group_dispatches,
        "resume_bit_identical": identical,
        "journal_at_kill": mid,
        "journal_final": final,
        "engine_journal_stats": {
            "records": s.journal_records,
            "segments": s.journal_segments,
            "bytes": s.journal_bytes},
        "reference_seconds": round(ref_dt, 4),
        "resume_seconds": round(resume_dt, 4),
    }


def run_bench(fast: bool = False) -> dict:
    from repro.core.sim import AUTO_JIT_MIN_BATCH, JIT_SHARD, has_jax

    batches = [1, 64, 256] if fast else [1, 64, 1024]
    report = {
        "benchmark": "sweep_bench",
        "host": {"cpu_count": os.cpu_count(),
                 "platform": platform.platform(),
                 "python": platform.python_version()},
        "config": {"fast": fast, "jit_shard": JIT_SHARD,
                   "auto_jit_min_batch": AUTO_JIT_MIN_BATCH,
                   "jax_available": has_jax()},
        "batches": bench_batches(batches, repeats=1 if fast else 2),
        "sweep": bench_sweep(256 if fast else 1024),
        "resume": bench_resume(),
        "compaction": bench_compaction(),
    }
    gate_rows = [r for r in report["batches"]
                 if r["batch"] >= 64 and "jit" in r["backends"]]
    # the jit-vs-numpy speedup scales with how many cores the shard
    # pool gets; 10x was measured on a 16-core host.  Scale the target
    # to this container so the gate carries signal instead of being a
    # hard false on the 2-core CI reference (docs/performance.md)
    cores = os.cpu_count() or 1
    scale_target = max(1.0, 10.0 * cores / 16)
    # both 10x readings are recorded so the trajectory is honest about
    # what is and is not met on this host: vs the legacy per-point hot
    # path the planner replaced, and vs the vectorized numpy driver
    # (the latter needs more cores than the 2-core reference container
    # gives the shard pool — see docs/performance.md)
    report["gate"] = {
        "jit_not_slower_than_numpy_at_64plus": all(
            r["speedup_jit_vs_numpy"] >= 1.0 for r in gate_rows),
        "jit_10x_pointwise_at_max_batch": bool(
            gate_rows and gate_rows[-1]
            ["speedup_jit_vs_pointwise"] >= 10.0),
        "jit_10x_numpy_at_max_batch": bool(
            gate_rows and gate_rows[-1]
            ["speedup_jit_vs_numpy"] >= 10.0),
        # scale-aware variant of the 10x-vs-numpy reading: target
        # proportional to the container's core count (recorded in
        # host.cpu_count), floored at parity
        "jit_numpy_scale_aware_target": round(scale_target, 2),
        "jit_numpy_scale_aware": bool(
            gate_rows and gate_rows[-1]
            ["speedup_jit_vs_numpy"] >= scale_target),
        # a killed, journaled sweep must resume bit-identical with
        # zero re-dispatch of journaled machine groups
        "resume_bit_identical": (
            report["resume"]["resume_bit_identical"]
            and report["resume"]["aborted_mid_sweep"]),
        "resume_zero_redispatch": (
            report["resume"]["journal_hits"] >= 1
            and report["resume"]["group_dispatches_on_resume"]
            + report["resume"]["journal_hits"] == 2),
        # a killed 10k-cell journaled sweep must resume bit-identical
        # through at least one compaction cycle, with zero re-dispatch
        # of journaled groups and a live file count bounded by the
        # segment size (docs/robustness.md#journal-segments)
        "compaction_bit_identical": (
            report["compaction"]["resume_bit_identical"]
            and report["compaction"]["aborted_mid_sweep"]
            and report["compaction"]["cells"] >= 10000),
        "compaction_zero_redispatch": (
            report["compaction"]["journal_hits"] >= 1
            and report["compaction"]["journal_hits"]
            + report["compaction"]["group_dispatches_on_resume"]
            == report["compaction"]["machine_groups"]),
        "compaction_files_bounded": (
            report["compaction"]["journal_final"]["segments"] >= 1
            and report["compaction"]["journal_final"]["loose_files"]
            <= report["compaction"]["segment_size"]
            and report["compaction"]["journal_final"]["records"]
            == report["compaction"]["machine_groups"]),
        # an ECM sweep over a warm grid must stay on the planner fast
        # path: zero additional simulations or compiled dispatches
        "ecm_zero_extra_dispatches": (
            report["sweep"]["ecm_extra_sim_runs"] == 0
            and report["sweep"]["ecm_extra_group_dispatches"] == 0),
        # compiled SimPrograms must be *reused* when a later sweep
        # re-simulates after result expiry (the recompute pass) — a
        # 0.0 program hit rate means every sweep recompiles from
        # scratch
        "program_cache_reused": report["sweep"]["program_hits"] > 0,
    }
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smaller batches (CI perf-smoke)")
    ap.add_argument("--out", default="BENCH_sweep.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless jit >= numpy at batch >= 64")
    args = ap.parse_args()

    report = run_bench(fast=args.fast)
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=2)
    for row in report["batches"]:
        line = f"batch={row['batch']:5d}"
        for name, r in row["backends"].items():
            line += f"  {name}={r['points_per_s']:.0f} pts/s"
        if "speedup_jit_vs_numpy" in row:
            line += (f"  (jit {row['speedup_jit_vs_numpy']}x numpy, "
                     f"{row['speedup_jit_vs_pointwise']}x pointwise)")
        print(line)
    sw = report["sweep"]
    print(f"sweep[{sw['backend']}]: cold {sw['cold_cells']} cells at "
          f"{sw['cold_cells_per_s']} cells/s "
          f"({sw['group_dispatches']} dispatches, {sw['sim_runs']} "
          f"simulations), warm {sw['warm_cells']} cells at "
          f"{sw['warm_cells_per_s']} cells/s, recompute "
          f"{sw['recompute_cells']} cells with program hit rate "
          f"{sw['program_hit_rate']}, ecm {sw['ecm_cells']} "
          f"cells at {sw['ecm_cells_per_s']} cells/s "
          f"(+{sw['ecm_extra_sim_runs']} sims)")
    rs = report["resume"]
    print(f"resume: {rs['cells']} cells, aborted={rs['aborted_mid_sweep']}, "
          f"journal_hits={rs['journal_hits']}, "
          f"dispatches={rs['group_dispatches_on_resume']}, "
          f"bit_identical={rs['resume_bit_identical']}")
    cp = report["compaction"]
    print(f"compaction: {cp['cells']} cells over "
          f"{cp['machine_groups']} machine groups, "
          f"journal_hits={cp['journal_hits']}, "
          f"dispatches={cp['group_dispatches_on_resume']}, "
          f"segments={cp['journal_final']['segments']}, "
          f"loose={cp['journal_final']['loose_files']} "
          f"(bound {cp['segment_size']}), "
          f"bit_identical={cp['resume_bit_identical']}")
    print(f"wrote {args.out}")
    failures = []
    if args.check:
        if not report["gate"]["jit_not_slower_than_numpy_at_64plus"]:
            failures.append("jit backend slower than numpy at "
                            "batch >= 64")
        if not report["gate"]["jit_numpy_scale_aware"]:
            failures.append(
                f"jit speedup over numpy below the scale-aware target "
                f"{report['gate']['jit_numpy_scale_aware_target']}x "
                f"for this host (see docs/performance.md)")
        if not report["gate"]["ecm_zero_extra_dispatches"]:
            failures.append("ECM sweep left the planner fast path "
                            "(extra sim runs/dispatches)")
        if not report["gate"]["program_cache_reused"]:
            failures.append("program cache cold: recompute sweep "
                            "after drop_results() reused no compiled "
                            "SimPrograms (hit rate 0.0)")
        if not report["gate"]["resume_bit_identical"]:
            failures.append("resumed sweep is not bit-identical to an "
                            "uninterrupted reference sweep")
        if not report["gate"]["resume_zero_redispatch"]:
            failures.append("resume re-dispatched a journaled machine "
                            "group (journal replay must cost zero "
                            "dispatches)")
        if not report["gate"]["compaction_bit_identical"]:
            failures.append("compacted 10k-cell sweep did not resume "
                            "bit-identical to an uninterrupted "
                            "reference")
        if not report["gate"]["compaction_zero_redispatch"]:
            failures.append("compacted resume re-dispatched a "
                            "journaled machine group")
        if not report["gate"]["compaction_files_bounded"]:
            failures.append("journal live file count not bounded by "
                            "the segment size after compaction")
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
