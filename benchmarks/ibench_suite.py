"""Paper Sec. II methodology on the machine we have: latency chains,
parallelism sweeps and port-conflict probes for JAX ops on the host CPU,
rendered in the paper's ibench output format (Sec. II-C)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bench import (conflict_benchmark, infer_port_count,
                              sweep_parallelism)
from repro.core.bench.model_builder import build_host_machine

FREQ = 2.0e9   # nominal; cycles reported are indicative on shared CPU


def ibench_sweep(fast: bool = True) -> list[dict]:
    ops = {
        "add": lambda x, c: x + c,
        "mul": lambda x, c: x * c,
        "fma": lambda x, c: x * c + c,
        "div": lambda x, c: x / c,
    }
    levels = (1, 2, 4, 8) if fast else (1, 2, 4, 5, 8, 10, 12)
    rows = []
    for name, op in ops.items():
        sweep = sweep_parallelism(op, levels=levels, name=name)
        ports = infer_port_count(sweep)
        for r in sweep:
            rows.append({
                "name": f"ibench/{r.ibench_line(FREQ).split(':')[0]}",
                "us_per_call": r.seconds_per_op * 1e6,
                "clk_cy": r.cycles(FREQ),
            })
        rows.append({"name": f"ibench/{name}-inferred-ports",
                     "ports": ports})
    return rows


def conflict_probe() -> list[dict]:
    """Sec. II-B: does op B share a port with op A?  (On a superscalar
    host CPU with few FP ports, fma vs mul conflicts harder than fma vs
    add-with-separate-chain, mirroring the paper's Zen finding.)"""
    rows = []
    base = lambda x, c: x * c + c          # fma
    for name, probe in (("vaddpd", lambda x, c: x + c),
                        ("vmulpd", lambda x, c: x * c)):
        res = conflict_benchmark(base, probe, name=f"fma+{name}")
        rows.append({
            "name": f"conflict/fma_vs_{name}",
            "us_per_call": res.combined_seconds_per_iter * 1e6,
            "slowdown": res.slowdown,
            "shares_port": res.shares_port,
        })
    return rows


def host_model() -> list[dict]:
    """Measured host machine as a MachineModel artifact: per-form rows
    plus the serialized model's digest (models are data — the measured
    machine ships like any hand-written one)."""
    machine, measured = build_host_machine()
    rows = []
    for m in measured:
        rows.append({
            "name": f"host_model/{m.name}",
            "us_per_call": m.throughput_s * 1e6,
            "latency_us": m.latency_s * 1e6,
            "ports": m.ports,
        })
    rows.append({"name": "host_model/artifact",
                 "ports": len(machine.ports),
                 "forms": len(machine.forms),
                 "digest": machine.digest[:16]})
    return rows
