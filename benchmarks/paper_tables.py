"""Reproductions of the paper's Tables I-VII: OSACA predictions from our
engine vs the paper's published OSACA/IACA/measured numbers, plus the
cycle-level simulator comparison column (``simulator_table``) and the
machine-model registry guard (``registry_guard``).

All cells are served by one shared :class:`AnalysisService`; archs
resolve through the architecture registry, so DB construction, form
lookups, repeated kernel analyses and pipeline simulations are memoized
across the whole table sweep."""
from __future__ import annotations

from repro.core import AnalysisRequest, default_service
from repro.core import paper_kernels as pk

SERVICE = default_service()
SKL = SERVICE.database("skl")
ZEN = SERVICE.database("zen")


def _pred(arch, src, unroll):
    return SERVICE.predict(AnalysisRequest(
        kernel=src, arch=arch, unroll_factor=unroll))


def table1() -> list[dict]:
    """Triad predictions per assembly iteration (paper Table I)."""
    rows = []
    for (compiled, flag), (unroll, exp_zen, exp_skl, iaca) in \
            pk.TABLE1.items():
        src = pk.TRIAD_KERNELS[(compiled, flag)]
        # paper's OSACA numbers are the pure throughput (port) bound
        zen = _pred("zen", src, unroll).port_bound_cycles
        skl = _pred("skl", src, unroll).port_bound_cycles
        rows.append({
            "name": f"table1/triad_{compiled}_{flag}",
            "pred_zen_cy": zen, "paper_zen_cy": exp_zen,
            "pred_skl_cy": skl, "paper_skl_cy": exp_skl,
            "iaca_skl_cy": iaca, "unroll": unroll,
            "match": abs(zen - exp_zen) < 0.01 and
                     abs(skl - exp_skl) < 0.01,
        })
    return rows


def table2() -> list[dict]:
    res = _pred("skl", pk.TRIAD_SKL_O3, 4)
    rows = []
    for port, exp in pk.TABLE2_TOTALS.items():
        rows.append({"name": f"table2/port_{port}",
                     "pred": res.port_totals[port], "paper": exp,
                     "match": abs(res.port_totals[port] - exp) < 0.01})
    return rows


def table3() -> list[dict]:
    """Predictions vs the paper's measured triad cy/it (Table III)."""
    rows = []
    for (run_on, compiled, flag), measured in pk.TABLE3_MEASURED.items():
        unroll = pk.TABLE1[(compiled, flag)][0]
        pred = _pred(run_on, pk.TRIAD_KERNELS[(compiled, flag)],
                     unroll).cycles_per_source_iteration
        rows.append({
            "name": f"table3/triad_on_{run_on}_for_{compiled}_{flag}",
            "pred_cy_it": pred, "paper_measured_cy_it": measured,
            "rel_err": abs(pred - measured) / measured,
        })
    return rows


def table4() -> list[dict]:
    res = _pred("zen", pk.TRIAD_ZEN_O3, 2)
    rows = []
    for port, exp in pk.TABLE4_TOTALS.items():
        rows.append({"name": f"table4/port_{port}",
                     "pred": res.port_totals[port], "paper": exp,
                     "match": abs(res.port_totals[port] - exp) < 0.01})
    hidden = res.rows[0].hidden_occupation
    rows.append({"name": "table4/hidden_load_P8",
                 "pred": hidden.get("8", 0.0), "paper": 0.5,
                 "match": abs(hidden.get("8", 0.0) - 0.5) < 1e-6})
    return rows


def table5() -> list[dict]:
    """pi benchmark: the unified engine's port bound, LCD bound and the
    combined ``max`` prediction in one pass per cell."""
    rows = []
    for (arch, flag), (unroll, iaca, exp, measured) in pk.TABLE5.items():
        res = _pred(arch, pk.PI_KERNELS[(arch, flag)], unroll)
        combined = res.cycles_per_source_iteration
        rows.append({
            "name": f"table5/pi_{arch}_{flag}",
            "pred_tp_cy_it": res.port_bound_per_source_iteration,
            "paper_osaca_cy_it": exp, "iaca_cy_it": iaca,
            "paper_measured_cy_it": measured,
            "lcd_cy_it": res.lcd_per_source_iteration,
            "combined_pred_cy_it": combined,
            "binding": res.binding,
            "combined_rel_err": abs(combined - measured) / measured,
            "match_paper": abs(res.port_bound_per_source_iteration - exp)
            < 0.01,
        })
    return rows


def table6() -> list[dict]:
    res = _pred("skl", pk.PI_SKL_O3, 8)
    return [{"name": f"table6/port_{p}", "pred": res.port_totals[p],
             "paper": e, "match": abs(res.port_totals[p] - e) < 0.01}
            for p, e in pk.TABLE6_TOTALS.items()]


def table7() -> list[dict]:
    res = _pred("skl", pk.PI_O2, 1)
    return [{"name": f"table7/port_{p}", "pred": res.port_totals[p],
             "paper": e, "match": abs(res.port_totals[p] - e) < 0.01}
            for p, e in pk.TABLE7_TOTALS.items()]


def fma_model_construction() -> list[dict]:
    """Sec. II-C: database entries derived for vfmadd132pd match the
    paper's measured latency/throughput on both architectures."""
    from repro.core.isa import parse_assembly
    rows = []
    ins = parse_assembly("vfmadd132pd (%rax), %xmm0, %xmm1")[0]
    for arch, db in (("zen", ZEN), ("skl", SKL)):
        e = db.lookup(ins)
        exp = pk.FMA_EXAMPLE[arch]
        rows.append({
            "name": f"fma_example/{arch}",
            "tp": e.throughput, "paper_tp": exp["throughput"],
            "lat": e.latency, "paper_lat": exp["latency"],
            "match": e.throughput == exp["throughput"] and
                     e.latency == exp["latency"],
        })
    return rows


# every paper kernel on both CPU models — shared by the simulator
# comparison and the ECM table so the two sweeps stay in lockstep
KERNEL_CASES = {
    "triad_skl_O3": ("skl", pk.TRIAD_SKL_O3, 4),
    "triad_zen_O3": ("zen", pk.TRIAD_ZEN_O3, 2),
    "pi_skl_O1": ("skl", pk.PI_O1, 1),
    "pi_skl_O2": ("skl", pk.PI_O2, 1),
    "pi_skl_O3": ("skl", pk.PI_SKL_O3, 8),
    "pi_zen_O1": ("zen", pk.PI_O1, 1),
    "pi_zen_O2": ("zen", pk.PI_O2, 1),
    "pi_zen_O3": ("zen", pk.PI_ZEN_O3, 2),
}

# working sets chosen to land each dataset squarely inside one level of
# both shipped hierarchies (SKL: 32K/256K/8M, Zen: 32K/512K/8M)
ECM_WORKING_SETS = {
    "L1": 16.0 * 1024,
    "L2": 128.0 * 1024,
    "L3": 2.0 * 1024 * 1024,
    "MEM": 64.0 * 1024 * 1024,
}


def simulator_table() -> list[dict]:
    """Third-backend comparison: the cycle-level pipeline simulation
    (``mode="simulate"``) next to the analytic ``max(port, LCD)`` bound
    for every paper kernel on both CPU models (see docs/simulation.md).
    """
    rows = []
    for name, (arch, src, unroll) in KERNEL_CASES.items():
        res = SERVICE.predict(AnalysisRequest(
            kernel=src, arch=arch, unroll_factor=unroll, mode="simulate"))
        analytic = max(res.port_bound_cycles, res.lcd_cycles)
        rows.append({
            "name": f"simulator/{name}",
            "analytic_cy_it": analytic / unroll,
            "sim_cy_it": res.sim_per_source_iteration,
            "port_cy_it": res.port_bound_per_source_iteration,
            "lcd_cy_it": res.lcd_per_source_iteration,
            "binding": res.binding,
            "sim_bottleneck": res.sim_result.bottleneck,
            "converged": res.sim_result.converged,
            "rel_to_analytic": (res.bound_sim - analytic) / analytic
            if analytic else 0.0,
        })
    return rows


def ecm_table() -> list[dict]:
    """ECM memory-hierarchy predictions: every paper kernel at a working
    set resident in each level of the shipped hierarchy (docs/ecm.md).
    Working sets at or under L1 must leave the in-core prediction and
    binding untouched (the paper's infinite-L1 assumption recovered)."""
    rows = []
    for name, (arch, src, unroll) in KERNEL_CASES.items():
        for level, ws in ECM_WORKING_SETS.items():
            res = SERVICE.predict(AnalysisRequest(
                kernel=src, arch=arch, unroll_factor=unroll,
                working_set=ws))
            ecm = res.ecm_result
            rows.append({
                "name": f"ecm/{name}@{level}",
                "ecm_cy_it": res.ecm_per_source_iteration,
                "incore_cy": ecm.t_incore,
                "t_nol_cy": ecm.t_nol,
                "transfer_cy": ecm.transfer_cycles,
                "resident": ecm.resident,
                "binding": res.binding,
                "notation": ecm.notation(),
            })
    return rows


def registry_guard() -> list[dict]:
    """Machine-model registry guard: every paper-kernel prediction must
    be reproduced *bit-for-bit* by a model that took the full data round
    trip — registry build -> ``to_json`` -> ``from_json`` ->
    ``register`` on a fresh service (headline check: pi -O1 at 9.0
    cy/it on SKL, 11.5 on Zen).  This is what makes models safe to ship
    to workers / cache by digest: the serialized artifact *is* the
    model."""
    from repro.core import AnalysisService, MachineModel, get_model

    svc = AnalysisService()
    rows = []
    for arch, expected_pi_o1 in (("skl", 9.0), ("zen", 11.5)):
        clone = MachineModel.from_json(get_model(arch).to_json())
        guard_id = f"{arch}-roundtrip"
        svc.register(clone.derive(guard_id))
        exact = True
        for (karch, flag), src in pk.PI_KERNELS.items():
            if karch != arch:
                continue
            unroll = pk.TABLE5[(arch, flag)][0]
            ref = SERVICE.predict(AnalysisRequest(
                kernel=src, arch=arch, unroll_factor=unroll))
            got = svc.predict(AnalysisRequest(
                kernel=src, arch=guard_id, unroll_factor=unroll))
            exact &= (got.predicted_cycles == ref.predicted_cycles
                      and got.port_bound_cycles == ref.port_bound_cycles
                      and got.lcd_cycles == ref.lcd_cycles
                      and got.port_totals == ref.port_totals)
        pi = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch=guard_id))
        rows.append({
            "name": f"registry/pi_O1_{arch}_roundtrip",
            "pred_cy_it": pi.cycles_per_source_iteration,
            "paper_cy_it": expected_pi_o1,
            "digest": get_model(arch).digest[:16],
            "match": exact and abs(pi.cycles_per_source_iteration
                                   - expected_pi_o1) < 1e-9,
        })
    return rows


ALL_TABLES = {
    "table1": table1, "table2": table2, "table3": table3,
    "table4": table4, "table5": table5, "table6": table6,
    "table7": table7, "fma_example": fma_model_construction,
    "simulator": simulator_table, "ecm": ecm_table,
    "registry": registry_guard,
}
