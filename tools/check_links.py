#!/usr/bin/env python3
"""Docs link check: every relative markdown link in README.md and docs/
must resolve to an existing file or directory.

Used by CI (.github/workflows/ci.yml); run locally with:

    python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# [text](target) — excluding images handled identically, code spans ignored
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def iter_md_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").rglob("*.md"))
    return [f for f in files if f.exists()]


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    # drop fenced code blocks: asm/py snippets contain `(...)` operands
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(_EXTERNAL):
            continue
        if target.startswith("#"):  # intra-document anchor
            continue
        rel = target.split("#", 1)[0]
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link "
                          f"-> {target}")
    return errors


def main() -> int:
    errors: list[str] = []
    files = iter_md_files()
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
