#!/usr/bin/env python3
"""Docs link check.

Three passes over README.md and docs/:

1. every relative markdown link must resolve to an existing file or
   directory,
2. every anchor fragment (``#section`` — intra-document or
   ``file.md#section``) must match a heading slug in the target file,
3. every page under docs/ must be reachable from README.md by following
   relative markdown links (no orphan pages).

Used by CI (.github/workflows/ci.yml); run locally with:

    python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# [text](target) — excluding images handled identically, code spans ignored
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
_EXTERNAL = ("http://", "https://", "mailto:")


def iter_md_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").rglob("*.md"))
    return [f for f in files if f.exists()]


def _strip_code(text: str) -> str:
    """Drop fenced code blocks: asm/py snippets contain `(...)` operands."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


def heading_anchors(path: Path) -> set[str]:
    """GitHub-style anchor slugs of every heading in ``path``."""
    anchors: set[str] = set()
    for line in _strip_code(path.read_text(encoding="utf-8")).splitlines():
        m = _HEADING_RE.match(line)
        if not m:
            continue
        title = re.sub(r"`([^`]*)`", r"\1", m.group(2))   # drop code spans
        title = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", title)  # links
        slug = title.strip().lower()
        slug = re.sub(r"[^\w\- ]", "", slug).replace(" ", "-")
        base, n = slug, 1
        while slug in anchors:                 # duplicate headings: -1, -2
            slug = f"{base}-{n}"
            n += 1
        anchors.add(slug)
    return anchors


_links_cache: dict[Path, list[tuple[str, Path, str]]] = {}


def iter_links(path: Path) -> list[tuple[str, Path, str]]:
    """(target, resolved_path, fragment) per relative link; parsed once
    per file (check_file and the orphan BFS both walk the same pages)."""
    cached = _links_cache.get(path)
    if cached is not None:
        return cached
    text = _strip_code(path.read_text(encoding="utf-8"))
    links = []
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(_EXTERNAL):
            continue
        rel, _, fragment = target.partition("#")
        resolved = (path.parent / rel).resolve() if rel else path.resolve()
        links.append((target, resolved, fragment))
    _links_cache[path] = links
    return links


def check_file(path: Path, anchor_cache: dict[Path, set[str]]) -> list[str]:
    errors = []
    rel_path = path.relative_to(ROOT)
    for target, resolved, fragment in iter_links(path):
        if not resolved.exists():
            errors.append(f"{rel_path}: broken link -> {target}")
            continue
        if not fragment or resolved.suffix != ".md":
            continue
        if resolved not in anchor_cache:
            anchor_cache[resolved] = heading_anchors(resolved)
        # exact match: GitHub anchor ids are lowercase and fragment
        # matching is case-sensitive, so #Section is broken even when
        # #section exists
        if fragment not in anchor_cache[resolved]:
            errors.append(f"{rel_path}: broken anchor -> {target} "
                          f"(no heading for #{fragment})")
    return errors


def find_orphans(files: list[Path]) -> list[str]:
    """docs/*.md pages not reachable from README.md via relative links."""
    start = ROOT / "README.md"
    reachable: set[Path] = set()
    stack = [start.resolve()]
    while stack:
        page = stack.pop()
        if page in reachable or not page.exists():
            continue
        reachable.add(page)
        if page.suffix != ".md":
            continue
        for _, resolved, _ in iter_links(page):
            if resolved not in reachable:
                stack.append(resolved)
    return [f"{f.relative_to(ROOT)}: orphan page (not reachable from "
            f"README.md)" for f in files
            if f.resolve() not in reachable]


def main() -> int:
    errors: list[str] = []
    files = iter_md_files()
    anchor_cache: dict[Path, set[str]] = {}
    for f in files:
        errors.extend(check_file(f, anchor_cache))
    errors.extend(find_orphans(files))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files (links, anchors, "
          f"orphans): {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
