#!/usr/bin/env python3
"""Validate the shipped machine-model artifacts (CI lint job).

Checks, for every ``src/repro/core/arch/models/*.json`` plus the
built-in lazily-registered models:

* the file parses and builds a ``MachineModel`` (full-model files via
  ``from_dict``, derived files by resolving their ``base`` through the
  default registry and applying ``derive``),
* the schema tag is present and supported,
* every uop of every instruction form references only declared ports,
* every divider port is itself in the port list,
* ids and aliases are unique across *all* models (shipped + built-in),
* full round trip: ``MachineModel.from_json(m.to_json()) == m``.

Run:  PYTHONPATH=src python tools/check_models.py
"""
from __future__ import annotations

import dataclasses
import json
import math
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.core.arch.registry import MODELS_DIR, default_registry  # noqa: E402
from repro.core.machine import SCHEMA, MachineModel  # noqa: E402


def _bad_number(value) -> bool:
    """NaN, infinity, or negative — none of which any latency, port
    pressure, bandwidth or size constant may carry.  A corrupt artifact
    must fail here, in lint, not deep inside a solve where the NaN has
    already propagated through a max()."""
    if value is None:
        return False
    try:
        v = float(value)
    except (TypeError, ValueError):
        return True
    return not math.isfinite(v) or v < 0


def check_numbers(model: MachineModel, origin: str,
                  errors: list[str]) -> None:
    """Reject NaN/negative latencies, port pressures and hierarchy
    constants (the `<= 0` style checks elsewhere let NaN through —
    every NaN comparison is False)."""
    for f in model.forms:
        if _bad_number(f.throughput):
            errors.append(f"{origin}: form {f.mnemonic!r} {f.signature} "
                          f"has NaN/negative throughput {f.throughput!r}")
        if _bad_number(f.latency):
            errors.append(f"{origin}: form {f.mnemonic!r} {f.signature} "
                          f"has NaN/negative latency {f.latency!r}")
        for u in f.uops:
            if _bad_number(u.cycles):
                errors.append(
                    f"{origin}: form {f.mnemonic!r} {f.signature} has "
                    f"NaN/negative port pressure {u.cycles!r} on "
                    f"{u.ports}")
    if _bad_number(model.frequency_hz):
        errors.append(f"{origin}: NaN/negative frequency_hz "
                      f"{model.frequency_hz!r}")
    if _bad_number(model.store_forward_latency):
        errors.append(f"{origin}: NaN/negative store_forward_latency "
                      f"{model.store_forward_latency!r}")
    pl = model.pipeline
    if pl is not None:
        for fld in dataclasses.fields(pl):
            v = getattr(pl, fld.name)
            if isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and _bad_number(v):
                errors.append(f"{origin}: pipeline.{fld.name} is "
                              f"NaN/negative ({v!r})")
    hz = model.hierarchy
    if hz is not None:
        for i, lv in enumerate(hz.levels):
            for fld in dataclasses.fields(lv):
                v = getattr(lv, fld.name)
                if isinstance(v, (int, float)) and not isinstance(v, bool) \
                        and _bad_number(v):
                    errors.append(
                        f"{origin}: hierarchy level {i} ({fld.name}) is "
                        f"NaN/negative ({v!r})")


def check_model(model: MachineModel, origin: str,
                errors: list[str]) -> None:
    # the port/divider checks duplicate MachineModel.__post_init__ on
    # purpose: this tool validates the *artifact* independently of
    # whatever construction-time validation the library happens to do
    known = set(model.ports)
    undeclared_div = set(model.divider_ports) - known
    if undeclared_div:
        errors.append(f"{origin}: divider ports {sorted(undeclared_div)} "
                      f"not in port list")
    for f in model.forms:
        for u in f.uops:
            bad = set(u.ports) - known
            if bad:
                errors.append(
                    f"{origin}: form {f.mnemonic!r} {f.signature} uses "
                    f"unknown ports {sorted(bad)}")
    pl = model.pipeline
    if pl is not None:
        # front-end width consistency: PipelineParams deliberately does
        # not enforce these (what-if machines may be inconsistent on
        # purpose), but a *shipped* artifact must be coherent
        if pl.decode_width > pl.issue_width:
            errors.append(
                f"{origin}: decode_width {pl.decode_width} exceeds "
                f"issue_width {pl.issue_width} (decoded uops would "
                f"never drain)")
        if pl.decode_width and pl.predecode_width and \
                pl.predecode_width < pl.decode_width:
            errors.append(
                f"{origin}: predecode_width {pl.predecode_width} "
                f"starves the {pl.decode_width}-wide decoders")
        if pl.decode_width and pl.complex_decode_width > pl.decode_width:
            errors.append(
                f"{origin}: complex_decode_width "
                f"{pl.complex_decode_width} exceeds decode_width "
                f"{pl.decode_width}")
        if bool(pl.dsb_width) != bool(pl.dsb_size):
            errors.append(
                f"{origin}: dsb_width and dsb_size must be enabled "
                f"together (got {pl.dsb_width}/{pl.dsb_size})")
    hz = model.hierarchy
    if hz is not None:
        # semantic hierarchy checks (level ordering by size, positive
        # bandwidths, line-size consistency, unbounded last level) live
        # on MemoryHierarchy.validate() so a malformed artifact reports
        # every defect instead of failing construction on the first
        for err in hz.validate():
            errors.append(f"{origin}: hierarchy: {err}")
    check_numbers(model, origin, errors)
    clone = MachineModel.from_json(model.to_json())
    if clone != model:
        errors.append(f"{origin}: JSON round trip is not the identity")


def main() -> int:
    errors: list[str] = []
    registry = default_registry()

    files = sorted(MODELS_DIR.glob("*.json")) if MODELS_DIR.is_dir() else []
    file_ids: dict[str, Path] = {}
    for path in files:
        try:
            data = json.loads(path.read_text(encoding="utf-8"))
        except ValueError as e:
            errors.append(f"{path.name}: invalid JSON: {e}")
            continue
        schema = data.get("schema")
        if schema != SCHEMA:
            errors.append(f"{path.name}: schema is {schema!r}, "
                          f"expected {SCHEMA!r}")
            continue
        if "base" in data:
            arch_id = data.get("overrides", {}).get("arch_id")
            if not arch_id:
                errors.append(f"{path.name}: derived model without "
                              f"overrides.arch_id")
                continue
        else:
            arch_id = data.get("model", data).get("arch_id")
        file_ids[path.name] = arch_id

    # build every registered model (forces the lazy builders AND the
    # shipped files, since discover() ran at registry construction)
    seen_names: dict[str, str] = {}
    for arch_id in registry.ids():
        origin = next((n for n, a in file_ids.items() if a == arch_id),
                      f"builtin:{arch_id}")
        try:
            model = registry.model(arch_id)
        except Exception as e:  # noqa: BLE001 - report, don't crash
            errors.append(f"{origin}: building {arch_id!r} failed: {e}")
            continue
        check_model(model, origin, errors)
        for name in (model.arch_id, *model.aliases):
            if name in seen_names and seen_names[name] != origin:
                errors.append(
                    f"{origin}: name {name!r} already used by "
                    f"{seen_names[name]}")
            seen_names.setdefault(name, origin)
    # registry-level aliases (register_lazy may add aliases beyond the
    # model's own, e.g. for the built-ins)
    for alias, target in registry.alias_map().items():
        owner = seen_names.get(alias)
        target_origin = seen_names.get(target, f"builtin:{target}")
        if owner is not None and owner != target_origin:
            errors.append(f"alias {alias!r} -> {target!r} clashes with a "
                          f"name owned by {owner}")

    n_models = len(registry.ids())
    if errors:
        print(f"check_models: {len(errors)} error(s) across {n_models} "
              f"model(s), {len(files)} shipped file(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"check_models: OK — {n_models} models "
          f"({', '.join(sorted(registry.ids()))}), "
          f"{len(files)} shipped file(s), "
          f"{len(registry.alias_map())} aliases, all unique and valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
