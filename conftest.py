"""Test path setup: make `repro` (src layout) and `benchmarks` importable
regardless of PYTHONPATH.  Device count is deliberately NOT forced here —
smoke tests and benches must see the single real device; only the
dry-run (its own process) forces 512 (see repro/launch/dryrun.py)."""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
