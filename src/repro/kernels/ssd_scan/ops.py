"""jit'd wrapper for the SSD Pallas kernel: model layout (B,S,H,P) plus
per-head decay -> kernel layout, chunking, interpret auto-select."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_bhcqp


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, da, dt, bm, cm, *, chunk: int = 128,
             interpret: bool | None = None):
    """x: (B,S,H,P); da, dt: (B,S,H); bm, cm: (B,S,N) -> (B,S,H,P)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, P = x.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    xk = x.transpose(0, 2, 1, 3).reshape(B, H, nc, Q, P)
    dak = da.transpose(0, 2, 1).reshape(B, H, nc, Q)
    dtk = dt.transpose(0, 2, 1).reshape(B, H, nc, Q)
    bk = bm.reshape(B, nc, Q, -1)
    ck = cm.reshape(B, nc, Q, -1)
    y = ssd_scan_bhcqp(xk, dak, dtk, bk, ck, interpret=bool(interpret))
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
