"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

The GPU implementation (mamba_ssm) is a fused selective-scan CUDA kernel
built around warp-parallel prefix scans.  The TPU-native formulation
(state-space duality, arXiv:2405.21060) re-expresses each chunk as two
MXU matmuls (intra-chunk quadratic form + state projection) plus a small
recurrent state carried across chunks; the TPU grid executes the chunk
dimension sequentially per (batch, head), so the (P x N) state lives in
VMEM scratch between grid steps — no cross-core scan primitive needed.

Layouts: x (B,H,nc,Q,P), dt/dA (B,H,nc,Q), Bm/Cm (B,nc,Q,N) shared across
heads (single SSD group).  Q (chunk) and P, N are 128-aligned by config.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _kernel(x_ref, da_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    da = da_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    bm = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)          # (Q, N)

    cum = jnp.cumsum(da)                          # (Q,)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(ii >= jj, seg, NEG_INF)
    L = jnp.exp(seg)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # (Q,Q)
    w = cb * L * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))     # (Q,P)

    # inter-chunk: contribution of carried state h (P,N)
    c_scaled = cm * jnp.exp(cum)[:, None]                       # (Q,N)
    y = y + jax.lax.dot_general(
        c_scaled, h_ref[...], (((1,), (1,)), ((), ())))         # (Q,P)

    # state update: h' = exp(total) h + sum_j exp(total-cum_j) dt_j x_j B_j
    total = cum[chunk - 1]
    decay = jnp.exp(total - cum) * dt                           # (Q,)
    dS = jax.lax.dot_general(
        x * decay[:, None], bm, (((0,), (0,)), ((), ())))       # (P,N)
    h_ref[...] = h_ref[...] * jnp.exp(total) + dS

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan_bhcqp(x, da, dt, bm, cm, *, interpret: bool = False):
    """x: (B,H,nc,Q,P); da, dt: (B,H,nc,Q); bm, cm: (B,nc,Q,N).
    Returns y: (B,H,nc,Q,P) (the D-skip/gating epilogue stays in the
    caller)."""
    B, H, nc, Q, P = x.shape
    N = bm.shape[-1]
    kernel = functools.partial(_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Q, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, da, dt, bm, cm)
