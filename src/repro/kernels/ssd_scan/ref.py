"""Pure-jnp oracle for the SSD chunk scan: the naive O(S) recurrence
    h_t = exp(dA_t) h_{t-1} + dt_t * B_t (x) x_t ;  y_t = C_t . h_t
computed step by step (no chunking) — ground truth for both the Pallas
kernel and the chunked XLA path in repro.models.ssm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, da, dt, bm, cm):
    """x: (B,H,S,P); da, dt: (B,H,S); bm, cm: (B,S,N) -> y (B,H,S,P)."""
    B, H, S, P = x.shape
    N = bm.shape[-1]

    def step(h, inp):
        xt, dat, dtt, bt, ct = inp
        # h: (B,H,P,N)
        h = h * jnp.exp(dat)[..., None, None] + \
            (xt * dtt[..., None])[..., :, None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (x.transpose(2, 0, 1, 3), da.transpose(2, 0, 1),
          dt.transpose(2, 0, 1), bm.transpose(1, 0, 2),
          cm.transpose(1, 0, 2))
    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype)
