"""Pure-jnp oracle for the grouped expert GEMM."""
import jax.numpy as jnp


def grouped_matmul_reference(x, w):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f), fp32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
