"""Grouped expert GEMM (MoE) as a Pallas TPU kernel.

Computes y[e] = x[e] @ w[e] for every expert's capacity buffer in one
launch — the TPU analogue of MegaBlocks' grouped GEMM (arXiv:2211.15841):
instead of CUDA block-scheduling over a ragged CSR structure, the
fixed-capacity dispatch (repro.models.moe) gives a dense (E, C, d) layout
and the kernel tiles (C, d, f) per expert through VMEM with a sequential
reduction over d-tiles accumulated in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(x_ref, w_ref, y_ref, acc_ref, *, n_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)       # (bc, bd)
    w = w_ref[0].astype(jnp.float32)       # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())))

    @pl.when(di == n_d - 1)
    def _finish():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *, block_c: int = 128,
                   block_d: int = 512, block_f: int = 512,
                   interpret: bool = False) -> jax.Array:
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    f = w.shape[-1]
    bc = min(block_c, C)
    bd = min(block_d, d)
    bf = min(block_f, f)
    assert C % bc == 0 and d % bd == 0 and f % bf == 0
    grid = (E, C // bc, f // bf, d // bd)
    kernel = functools.partial(_kernel, n_d=d // bd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
