"""jit'd wrapper for the grouped expert GEMM kernel."""
from functools import partial

import jax

from .moe_gmm import grouped_matmul as _gmm


@partial(jax.jit, static_argnames=("block_c", "block_d", "block_f",
                                   "interpret"))
def grouped_matmul(x, w, *, block_c: int = 128, block_d: int = 512,
                   block_f: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _gmm(x, w, block_c=block_c, block_d=block_d, block_f=block_f,
                interpret=bool(interpret))
