"""Flash-attention forward as a Pallas TPU kernel.

TPU-native adaptation (DESIGN.md §3): the GPU kernel's warp-level shuffles
become MXU tile matmuls with VMEM-resident online-softmax state; the grid's
last dimension (kv blocks) executes sequentially per TPU core, so the
running (m, l, acc) state lives in VMEM scratch across grid steps instead
of registers.

Layout: q, k, v are (B, H, S, D) (the ops.py wrapper transposes from the
model's (B, S, H, D)).  Grid = (B, Hq, nq, nkv); BlockSpecs stream one
(block_q x D) query tile and one (block_k x D) KV tile into VMEM per step;
block sizes default to 512 x 128-aligned tiles so MXU matmuls are
hardware-aligned and the working set (q + k + v + scores + acc ~ 4-8 MB at
D<=256) fits the 16 MiB VMEM budget.

Causal masking skips fully-masked kv blocks via ``pl.when`` (no MXU work
issued), halving the causal FLOPs — the optimization the XLA reference
path cannot express with a static scan (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, n_kv: int,
            causal: bool, window: int, softcap: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_k

    # skip fully-masked blocks (strictly above the causal diagonal or
    # entirely left of the sliding window)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(
            run, k_start + block_k - 1 >= q_start - window + 1) \
            if causal else (k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run if not isinstance(run, bool) else True)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale   # (bq, bk)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         softcap: float = 0.0, block_q: int = 512,
                         block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq = G * Hkv."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nkv = S // block_q, S // block_k
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv=nkv, causal=causal, window=window, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
