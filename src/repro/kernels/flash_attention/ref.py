"""Pure-jnp oracle for the flash-attention kernel ((B,H,S,D) layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B,Hq,S,D); k,v: (B,Hkv,S,D).  fp32 math, exact softmax."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / (D ** 0.5)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, S, D).astype(q.dtype)
