"""jit'd public wrapper: model layout (B,S,H,D) <-> kernel layout
(B,H,S,D); interpret mode auto-selected off-TPU so the same call site
works in tests, on CPU and on real hardware."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 512,
                    block_k: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B,S,Hq,D); k,v: (B,S,Hkv,D) -> (B,S,Hq,D)."""
    if interpret is None:
        interpret = not _on_tpu()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=bool(interpret))
    return o.transpose(0, 2, 1, 3)
