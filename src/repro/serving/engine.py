"""Batched serving engine on top of ``prefill`` / ``decode_step``.

Cohort (static) batching: requests are served in cohorts of ``n_slots``;
within a cohort all prompts are left-padded to one length so every slot
shares the decode position and the compiled decode step is reused across
cohorts with zero recompiles (the production property that matters).
Early-finishing slots are masked until the cohort drains — continuous
batching would also need per-slot positions (scatter cache writes); the
dry-run/roofline analysis is identical either way, so the simpler,
exactly-correct scheme is used here.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclass
class GenerationResult:
    rid: int
    tokens: list[int] = field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_len: int = 512, eos_id: int = 0, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.key = jax.random.key(seed)
        self._decode = jax.jit(
            lambda p, t, pos, c: decode_step(p, t, pos, c, cfg))
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg))

    # ------------------------------------------------------------ #
    def _cohort_prefill(self, cohort: list[Request]):
        plen = max(len(r.prompt) for r in cohort)
        B = self.n_slots
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(cohort):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompts)})
        dt = time.perf_counter() - t0
        cache = init_cache(self.cfg, B, self.max_len)
        cache = self._install(cache, caches, plen)
        first = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        return cache, first, plen, dt

    def _install(self, dst_tree, src_tree, plen: int):
        """Copy prefill caches (seq len = plen or the SWA window) into
        the engine's max_len buffers.  ``ax`` is the batch axis: 0 for
        prefix-layer caches, 1 for group-stacked stack caches."""
        def merge(ax):
            def f(dst, src):
                head = (slice(None),) * ax
                if dst.ndim > ax + 1 and src.ndim > ax + 1 and \
                        dst.shape[ax + 1] != src.shape[ax + 1]:
                    w = min(src.shape[ax + 1], dst.shape[ax + 1])
                    return dst.at[head + (slice(None), slice(0, w))].set(
                        src[head + (slice(None), slice(-w, None))]
                        .astype(dst.dtype))
                return src.astype(dst.dtype) if dst.shape == src.shape \
                    else dst
            return f
        return {
            "prefix": [jax.tree.map(merge(0), d, s) for d, s in
                       zip(dst_tree["prefix"], src_tree["prefix"])],
            "stack": jax.tree.map(merge(1), dst_tree["stack"],
                                  src_tree["stack"]),
        }

    # ------------------------------------------------------------ #
    def dryrun_estimate(self, prompt_len: int = 128,
                        service=None, mode: str = "analytic",
                        machine=None,
                        working_set: float | None = None) -> dict:
        """Static port-model latency estimate of this engine's serving
        path — no execution, just lower/compile + the unified analysis.

        Lowers the cohort prefill and the single-token decode step and
        runs them through :meth:`AnalysisService.predict_hlo`, so the
        returned times use the combined ``max(overlap, critical-path)``
        bound (the same rule the x86 engine applies as
        ``max(port_bound, LCD)``).  With ``mode="simulate"`` the entry
        ops are additionally list-scheduled onto the TPU ports
        (``repro.core.sim.dag``) and the scalar summaries use that
        refined ``terms.bound_sim`` makespan.  ``machine`` selects the
        accelerator model (arch id/alias or
        ``repro.core.machine.MachineModel``; default the registry's
        ``"tpu_v5e"``) — estimating the same serving path on a derived
        accelerator is a one-argument change.  Returns per-phase
        ``HloAnalysis`` objects plus scalar summaries::

            {"prefill": HloAnalysis, "decode": HloAnalysis, "mode": ...,
             "prefill_s": ..., "decode_s_per_token": ...,
             "tokens_per_s_per_slot": ...}
        """
        if service is None:
            from repro.core.engine import default_service
            service = default_service()
        B = self.n_slots
        prompts = jnp.zeros((B, prompt_len), jnp.int32)
        prefill_txt = self._prefill.lower(
            self.params, {"tokens": prompts}).compile().as_text()
        cache = init_cache(self.cfg, B, self.max_len)
        tok = jnp.zeros((B, 1), jnp.int32)
        decode_txt = self._decode.lower(
            self.params, tok, jnp.int32(prompt_len),
            cache).compile().as_text()
        # one batched call: the machine model resolves once (memoized on
        # the service) instead of once per phase per sweep point
        prefill, decode = service.predict_hlo_batch(
            [prefill_txt, decode_txt], mode=mode, machine=machine,
            working_set=working_set)
        prefill_s = prefill.terms.bound_sim if mode == "simulate" \
            else prefill.terms.bound_combined
        decode_s = decode.terms.bound_sim if mode == "simulate" \
            else decode.terms.bound_combined
        return {
            "prefill": prefill, "decode": decode, "mode": mode,
            "prefill_s": prefill_s,
            "decode_s_per_token": decode_s,
            "tokens_per_s_per_slot": (1.0 / decode_s) if decode_s else
            float("inf"),
        }

    # ------------------------------------------------------------ #
    def run(self, requests: list[Request]) -> list[GenerationResult]:
        done: list[GenerationResult] = []
        queue = list(requests)
        while queue:
            cohort = queue[:self.n_slots]
            queue = queue[self.n_slots:]
            while len(cohort) < self.n_slots:     # pad with a dummy
                cohort.append(Request(rid=-1, prompt=cohort[0].prompt,
                                      max_new_tokens=1))
            cache, first, plen, prefill_s = self._cohort_prefill(cohort)
            results = [GenerationResult(r.rid, prefill_s=prefill_s)
                       for r in cohort]
            active = np.ones(self.n_slots, bool)
            budget = np.array([r.max_new_tokens for r in cohort])
            last = first.reshape(-1, 1).astype(np.int32)
            for i, res in enumerate(results):
                res.tokens.append(int(first[i]))
                budget[i] -= 1
                if first[i] == self.eos_id or budget[i] <= 0:
                    active[i] = False
            pos = plen
            while active.any() and pos < self.max_len - 1:
                t0 = time.perf_counter()
                logits, cache = self._decode(
                    self.params, jnp.asarray(last), jnp.int32(pos),
                    cache)
                dt = time.perf_counter() - t0
                toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
                for i in range(self.n_slots):
                    if not active[i]:
                        continue
                    results[i].decode_s += dt
                    results[i].tokens.append(int(toks[i]))
                    budget[i] -= 1
                    last[i, 0] = toks[i]
                    if toks[i] == self.eos_id or budget[i] <= 0:
                        active[i] = False
                pos += 1
            done.extend(r for r in results if r.rid >= 0)
        return done
