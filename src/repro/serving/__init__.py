from .engine import ServingEngine, Request, GenerationResult
