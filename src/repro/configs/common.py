"""Generic family-preserving config reduction for smoke tests.

The reduced config keeps the *structure* (layer pattern, MoE-ness, GQA,
modality, activation) while shrinking every dimension so one forward/train
step runs on a single CPU device in seconds.
"""
from __future__ import annotations

from repro.models.config import ModelConfig


def smoke_reduce(cfg: ModelConfig) -> ModelConfig:
    kw: dict = dict(
        d_model=128,
        vocab_size=256,
        attn_chunk_q=64, attn_chunk_kv=64, loss_chunk=64,
        rope_theta=1e4, remat="none",
    )
    if cfg.d_ff:
        kw["d_ff"] = 256
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
                  d_head=32)
    if cfg.attention == "swa":
        kw["window"] = 64
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), d_ff_expert=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.layer_pattern in ("ssm", "jamba"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.layer_pattern == "jamba":
        kw["n_layers"] = cfg.hybrid_group          # one full hybrid group
    elif cfg.n_dense_layers:
        kw["n_layers"] = cfg.n_dense_layers + 2    # prefix + 2 stacked
    else:
        kw["n_layers"] = 2
    if cfg.modality == "vision":
        kw["n_patches"] = 8
    return cfg.with_updates(**kw)
