"""H2O-Danube3-4B: dense llama/mistral mix with sliding-window attention,
24L, d=3840, 32H (GQA kv=8), ff=10240, vocab 32000 [arXiv:2401.16818]."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab_size=32000,
        attention="swa", window=4096,
        activation="silu", glu=True,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
