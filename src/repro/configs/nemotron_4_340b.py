"""Nemotron-4-340B: dense, 96L, d=18432, 96H (GQA kv=8), ff=73728,
vocab 256000, squared-ReLU FFN (no GLU) [arXiv:2402.16819]."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
        d_ff=73728, vocab_size=256000,
        activation="relu2", glu=False,
        optimizer_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
