"""HuBERT X-Large: encoder-only audio transformer, 48L, d=1280, 16H MHA,
ff=5120, vocab 504 (cluster targets) [arXiv:2106.07447].  The conv
waveform frontend is a STUB: input_specs provide precomputed 512-dim
frame embeddings (per instructions)."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab_size=504,
        modality="audio", encoder_only=True, causal=False,
        activation="gelu", glu=False,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
