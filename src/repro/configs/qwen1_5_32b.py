"""Qwen1.5-32B: dense, 64L, d=5120, 40H MHA (kv=40), ff=27392,
vocab 152064, QKV bias [hf:Qwen/Qwen1.5-*]."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab_size=152064,
        qkv_bias=True, activation="silu", glu=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
