"""Kimi K2: trillion-parameter MoE, 61L (first layer dense FFN), d=7168,
64H (GQA kv=8), 384 experts top-8 + 1 shared, expert ff=2048, dense
ff=18432, vocab 163840 [paper table; DeepSeek-V3-style layout]."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=18432, vocab_size=163840,
        n_experts=384, top_k=8, d_ff_expert=2048, n_shared_experts=1,
        n_dense_layers=1,
        activation="silu", glu=True,
        optimizer_dtype="bfloat16",   # 1T params: fp32 m/v cannot fit 256 chips
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
