"""Grok-1: 314B MoE, 64L, d=6144, 48H (GQA kv=8), 8 experts top-2 with
expert ff=32768, vocab 131072 [hf:xai-org/grok-1].  8 experts < 16-way
model axis -> expert-TP sharding mode (d_ff split)."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=32768, vocab_size=131072,
        n_experts=8, top_k=2, d_ff_expert=32768,
        activation="gelu", glu=True,
        attn_logit_softcap=30.0,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
