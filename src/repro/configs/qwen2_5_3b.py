"""Qwen2.5-3B: dense, 36L, d=2048, 16H (GQA kv=2), ff=11008,
vocab 151936, QKV bias [hf:Qwen/Qwen2.5-*]."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab_size=151936,
        qkv_bias=True, activation="silu", glu=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
