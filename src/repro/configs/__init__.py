"""Assigned architecture registry: ``get_config(arch_id)`` /
``get_smoke_config(arch_id)`` (reduced, CPU-runnable)."""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "jamba-1.5-large-398b",
    "kimi-k2-1t-a32b",
    "grok-1-314b",
    "qwen1.5-32b",
    "h2o-danube-3-4b",
    "nemotron-4-340b",
    "qwen2.5-3b",
    "hubert-xlarge",
    "mamba2-370m",
    "llava-next-34b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()
