"""LLaVA-NeXT-34B backbone: dense decoder, 60L, d=7168, 56H (GQA kv=8),
ff=20480, vocab 64000 [hf:llava-hf/llava-v1.6-*].  The anyres vision
tower is a STUB: input_specs provide precomputed patch embeddings at
d_model that a learned adapter injects at the sequence head."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
        d_ff=20480, vocab_size=64000,
        modality="vision", n_patches=576,
        activation="silu", glu=True,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
