"""Jamba-1.5-Large: hybrid Mamba+attention 1:7 interleave with MoE every
second layer [arXiv:2403.19887].  72L, d=8192, 64H (GQA kv=8), ff=24576,
vocab 65536, 16 experts top-2.  Jamba's Mamba-1 layers are realised with
the SSD (Mamba-2) chunked formulation — TPU adaptation, DESIGN.md §3."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab_size=65536,
        n_experts=16, top_k=2, d_ff_expert=24576, moe_every=2,
        layer_pattern="jamba", hybrid_group=8, hybrid_attn_index=3,
        ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
        activation="silu", glu=True,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
