"""Mamba2-370M: attention-free SSD (state-space duality), 48L, d=1024,
d_state=128, expand 2, head_dim 64, vocab 50280 [arXiv:2405.21060].
Pure Mamba-2: each layer is a single SSD mixer block (no FFN)."""
from repro.models.config import ModelConfig
from .common import smoke_reduce


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab_size=50280,
        layer_pattern="ssm",
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    )


def smoke_config() -> ModelConfig:
    return smoke_reduce(config())
