"""Fault-tolerance building blocks for 1000+ node operation.

* :class:`PreemptionSignal` — cooperative shutdown: SIGTERM/SIGINT (what
  cloud schedulers send before eviction) flips a flag the train loop
  checks each step; the loop then writes a final checkpoint and exits
  cleanly.  A restart resumes from ``latest_step``.
* :class:`StragglerMonitor` — per-step wall-time tracker with robust
  (median + MAD) outlier detection.  On real multi-host deployments the
  per-host step time is all-gathered over the DCN control plane; here the
  detector consumes whatever samples it is fed (tests inject synthetic
  stragglers).  Mitigation hook: the trainer records flagged steps and —
  when a host exceeds ``evict_after`` consecutive flags — requests an
  elastic restart without that host (mesh reshape via checkpoint
  resharding, see repro.checkpoint).
"""
from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field


class PreemptionSignal:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = False
        self._previous = {}
        self._signals = signals

    def install(self) -> "PreemptionSignal":
        for s in self._signals:
            self._previous[s] = signal.signal(s, self._handler)
        return self

    def uninstall(self) -> None:
        for s, prev in self._previous.items():
            signal.signal(s, prev)
        self._previous.clear()

    def _handler(self, signum, frame):
        self._flag = True

    def trigger(self) -> None:          # for tests
        self._flag = True

    @property
    def fired(self) -> bool:
        return self._flag


@dataclass
class StragglerReport:
    step: int
    host: int
    seconds: float
    median: float
    threshold: float


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold_mads: float = 6.0
    evict_after: int = 10
    _samples: dict[int, list[float]] = field(default_factory=dict)
    _consecutive: dict[int, int] = field(default_factory=dict)
    reports: list[StragglerReport] = field(default_factory=list)

    def record(self, step: int, host_times: dict[int, float]
               ) -> list[StragglerReport]:
        """Feed per-host step times; returns stragglers flagged now."""
        flagged = []
        times = list(host_times.values())
        med = statistics.median(times)
        mad = statistics.median(abs(t - med) for t in times) or 1e-9
        threshold = med + self.threshold_mads * mad
        for host, t in host_times.items():
            hist = self._samples.setdefault(host, [])
            hist.append(t)
            del hist[:-self.window]
            if len(times) > 1 and t > threshold and t > 1.2 * med:
                self._consecutive[host] = self._consecutive.get(host, 0) + 1
                rep = StragglerReport(step, host, t, med, threshold)
                self.reports.append(rep)
                flagged.append(rep)
            else:
                self._consecutive[host] = 0
        return flagged

    def hosts_to_evict(self) -> list[int]:
        return [h for h, n in self._consecutive.items()
                if n >= self.evict_after]
