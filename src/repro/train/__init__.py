from .trainer import Trainer, TrainerConfig
from .fault_tolerance import PreemptionSignal, StragglerMonitor
