"""Production train loop: checkpoint/restart, preemption handling,
straggler monitoring, metrics, deterministic data resume.

The loop is mesh-agnostic: pass any mesh (the 2x2 CI mesh, one pod, or
the 2x16x16 multi-pod production mesh) and the same code runs — that is
the elastic-scaling contract, together with reshard-on-load
checkpointing (a job restarted on a different mesh keeps training).
"""
from __future__ import annotations

import logging
import os
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.data import make_pipeline
from repro.launch.steps import build_train_step
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.schema import init_params
from repro.models.transformer import model_schema
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.sharding import (activation_sharding, make_rules,
                                     param_shardings)

from .fault_tolerance import PreemptionSignal, StragglerMonitor

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0
    async_checkpoint: bool = True
    microbatches: int | None = 1
    optimizer: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 tcfg: TrainerConfig):
        self.cfg, self.shape, self.mesh, self.tcfg = cfg, shape, mesh, tcfg
        self.rules = make_rules(mesh)
        self.store = CheckpointStore(tcfg.checkpoint_dir)
        self.monitor = StragglerMonitor()
        self.preemption = PreemptionSignal()
        self.pipeline = make_pipeline(
            cfg, shape.seq_len, shape.global_batch,
            process_index=jax.process_index(),
            process_count=jax.process_count(), seed=tcfg.seed)
        self.step_builder = build_train_step(
            cfg, shape, self.rules, opt=tcfg.optimizer,
            microbatches=tcfg.microbatches)
        self._compiled = None
        self.metrics_history: list[dict] = []

    # ------------------------------------------------------------- #
    def init_state(self):
        schema = model_schema(self.cfg)
        shardings = param_shardings(schema, self.rules)
        with self.mesh:
            params = jax.jit(
                lambda key: init_params(schema, key),
                out_shardings=shardings)(jax.random.key(self.tcfg.seed))
            opt = jax.jit(
                lambda p: adamw_init(p, self.tcfg.optimizer),
                out_shardings={"m": shardings, "v": shardings,
                               "step": None})(params)
        return {"params": params, "opt": opt}

    def restore_or_init(self):
        latest = self.store.latest_step()
        state = self.init_state()
        if latest is None:
            return state, 0
        log.info("resuming from checkpoint step %d", latest)
        shardings = self.step_builder.in_shardings[0]
        state = self.store.load(latest, state, shardings)
        return state, latest

    def compiled_step(self):
        if self._compiled is None:
            with self.mesh:
                self._compiled = self.step_builder.lower().compile()
        return self._compiled

    # ------------------------------------------------------------- #
    def run(self) -> dict:
        self.preemption.install()
        try:
            return self._run()
        finally:
            self.preemption.uninstall()
            self.store.wait()

    def _run(self) -> dict:
        state, start = self.restore_or_init()
        step_fn = self.compiled_step()
        batch_shardings = self.step_builder.in_shardings[1]
        interrupted = False
        t_prev = time.perf_counter()
        step = start
        with self.mesh:
            for step in range(start, self.tcfg.steps):
                if self.preemption.fired:
                    log.warning("preemption at step %d: checkpoint+exit",
                                step)
                    interrupted = True
                    break
                host = self.pipeline.batch(step)
                batch = jax.tree.map(
                    lambda a, s: jax.make_array_from_process_local_data(
                        s, a),
                    host, batch_shardings)
                state, metrics = step_fn(state, batch)
                now = time.perf_counter()
                self.monitor.record(step, {jax.process_index():
                                           now - t_prev})
                t_prev = now
                if step % self.tcfg.log_every == 0 or \
                        step == self.tcfg.steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step
                    self.metrics_history.append(m)
                    log.info("step %d  loss %.4f  gnorm %.3f", step,
                             m["loss"], m["grad_norm"])
                if (step + 1) % self.tcfg.checkpoint_every == 0:
                    self.store.save(step + 1, state,
                                    background=self.tcfg.async_checkpoint)
        final_step = step if interrupted else self.tcfg.steps
        self.store.save(final_step, state, background=False)
        return {"state": state, "final_step": final_step,
                "interrupted": interrupted,
                "metrics": self.metrics_history,
                "stragglers": self.monitor.reports}
