"""Optional-dependency shims for the test suite.

``hypothesis`` is an *optional* dev dependency (``pip install -e
".[dev]"``, see pyproject.toml).  When it is absent, the property-based
tests must skip — not abort the whole tier-1 collection with a
``ModuleNotFoundError``.  Test modules therefore import it as::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:      # optional dev dependency
        from repro.testing import given, settings, st

The stubs below keep module-level strategy expressions (``st.lists(...)``
etc.) evaluating harmlessly and turn every ``@given`` test into an
explicit ``pytest.skip`` so the rest of the module still runs.
"""
from __future__ import annotations


class _AnyStrategy:
    """Absorbs any attribute access / call chain used to build strategies
    at decoration time (``st.lists(st.tuples(...), min_size=1)``...)."""

    def __call__(self, *args, **kwargs) -> "_AnyStrategy":
        return self

    def __getattr__(self, name: str) -> "_AnyStrategy":
        return self


st = _AnyStrategy()


def given(*_args, **_kwargs):
    """Replacement ``hypothesis.given``: the test skips at run time."""

    def decorator(fn):
        # deliberately not functools.wraps: copying __wrapped__ would let
        # pytest see the original signature and demand its arguments as
        # fixtures; the replacement takes no arguments at all.
        def wrapper():
            import pytest
            pytest.skip("hypothesis not installed (optional [dev] "
                        "dependency); property test skipped")
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorator


def settings(*_args, **_kwargs):
    """Replacement ``hypothesis.settings``: identity decorator."""

    def decorator(fn):
        return fn

    return decorator
