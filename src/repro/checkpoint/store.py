"""Sharded, atomic, async-capable checkpointing with reshard-on-load.

Layout (one directory per step)::

    <root>/step_000128.tmp/...   -> atomic rename -> <root>/step_000128/
        manifest.json            # tree structure, shapes, dtypes
        <leaf-key>.npy           # one file per pytree leaf

Fault-tolerance properties required at 1000-node scale:
  * atomicity — a crash mid-write never corrupts the latest checkpoint
    (tmp-dir + rename; readers only ever see complete directories);
  * resumability — ``latest_step`` scans for the newest complete step;
  * elasticity — arrays are saved in full logical shape with their
    PartitionSpec recorded; on load they are re-laid-out onto whatever
    mesh the new job runs with (``reshard=...``), so restarts may change
    pod count / mesh shape;
  * async — ``save_checkpoint(..., background=True)`` snapshots to host
    memory synchronously (cheap) and writes files on a worker thread so
    the train loop is not blocked by the filesystem.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")
_REC_RE = re.compile(r"^rec_(\d+)\.json$")


class RecordJournal:
    """Append-only, crash-safe JSON record log.

    One file per record (``rec_00000001.json``), written with the same
    tmp + rename discipline as the checkpoint store: a writer killed
    mid-append never leaves a partial record visible, and readers only
    ever see complete records.  Used by ``AnalysisService.sweep`` to
    journal completed machine-group results so a killed sweep resumes
    with zero re-dispatch (docs/robustness.md)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    def _ids(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _REC_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def append(self, record: dict) -> int:
        """Atomically append one JSON record; returns its id."""
        with self._lock:
            ids = self._ids()
            rec_id = (ids[-1] + 1) if ids else 1
            final = os.path.join(self.root, f"rec_{rec_id:08d}.json")
            tmp = final + ".tmp"
            with open(tmp, "w") as f:
                json.dump(record, f)
            os.replace(tmp, final)
            return rec_id

    def records(self) -> list[dict]:
        """All complete records in append order.

        Stray ``.tmp`` files (a killed writer) and unparseable files
        are skipped — crash debris must never poison a resume."""
        out = []
        for rec_id in self._ids():
            path = os.path.join(self.root, f"rec_{rec_id:08d}.json")
            try:
                with open(path) as f:
                    out.append(json.load(f))
            except (OSError, ValueError):
                continue
        return out

    def clear(self) -> None:
        with self._lock:
            for rec_id in self._ids():
                try:
                    os.remove(os.path.join(self.root, f"rec_{rec_id:08d}.json"))
                except OSError:
                    pass

# numpy cannot round-trip ml_dtypes through .npy files (loads as void);
# store them through a same-width uint view and record the real dtype in
# the manifest.
_EXOTIC_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _EXOTIC_DTYPES:
        return arr.view(_EXOTIC_DTYPES[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC_DTYPES:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_token(p) for p in path)
        out.append((key, leaf))
    return out


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._worker: threading.Thread | None = None

    # ------------------------------------------------------------- #
    def save(self, step: int, tree, background: bool = False) -> None:
        leaves = _flatten_with_paths(tree)
        # snapshot to host synchronously (device buffers may be donated
        # by the next step)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in leaves]
        if background:
            self.wait()
            self._worker = threading.Thread(
                target=self._write, args=(step, tree, host), daemon=True)
            self._worker.start()
        else:
            self._write(step, tree, host)

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, tree, host) -> None:
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host:
            fname = key.replace("/", "__") + ".npy"
            storable, dtype_name = _to_storable(arr)
            np.save(os.path.join(tmp, fname), storable)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype_name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- #
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.root, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def load(self, step: int, like, shardings=None):
        """Restore into the structure of ``like``; when ``shardings`` (a
        matching pytree of NamedSharding) is given, every leaf is placed
        onto the new mesh — pod counts/mesh shape may differ from the
        saving job (elastic restart)."""
        path = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten_with_paths(like)
        shard_flat = _flatten_with_paths(shardings) if shardings \
            else [(k, None) for k, _ in flat_like]
        shard_map = dict(shard_flat)
        leaves = []
        for key, ref in flat_like:
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = _from_storable(np.load(os.path.join(path, meta["file"])),
                                 meta["dtype"])
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {ref.shape}")
            sh = shard_map.get(key)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)


# convenience functions ------------------------------------------------- #

def save_checkpoint(root: str, step: int, tree,
                    background: bool = False) -> None:
    CheckpointStore(root).save(step, tree, background=background)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    return CheckpointStore(root).latest_step()


def load_checkpoint(root: str, step: int, like, shardings=None):
    return CheckpointStore(root).load(step, like, shardings)
