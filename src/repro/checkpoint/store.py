"""Sharded, atomic, async-capable checkpointing with reshard-on-load.

Layout (one directory per step)::

    <root>/step_000128.tmp/...   -> atomic rename -> <root>/step_000128/
        manifest.json            # tree structure, shapes, dtypes
        <leaf-key>.npy           # one file per pytree leaf

Fault-tolerance properties required at 1000-node scale:
  * atomicity — a crash mid-write never corrupts the latest checkpoint
    (tmp-dir + rename; readers only ever see complete directories);
  * resumability — ``latest_step`` scans for the newest complete step;
  * elasticity — arrays are saved in full logical shape with their
    PartitionSpec recorded; on load they are re-laid-out onto whatever
    mesh the new job runs with (``reshard=...``), so restarts may change
    pod count / mesh shape;
  * async — ``save_checkpoint(..., background=True)`` snapshots to host
    memory synchronously (cheap) and writes files on a worker thread so
    the train loop is not blocked by the filesystem.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")
_REC_RE = re.compile(r"^rec_(\d+)\.json$")
_SEG_RE = re.compile(r"^seg_(\d+)_(\d+)\.json$")


class RecordJournal:
    """Append-only, crash-safe JSON record log with segment compaction.

    One file per record (``rec_00000001.json``), written with the same
    tmp + rename discipline as the checkpoint store: a writer killed
    mid-append never leaves a partial record visible, and readers only
    ever see complete records.  Used by ``AnalysisService.sweep`` to
    journal completed machine-group results so a killed sweep resumes
    with zero re-dispatch (docs/robustness.md).

    **Compaction** (``segment_size=``): once the loose-file count
    reaches the threshold, :meth:`compact` merges them into one sealed
    segment ``seg_<first>_<last>.json`` — the JSON body followed by a
    sha256 footer over the body, written tmp + fsync + rename — and
    deletes the loose files, so a million-record journal stays
    O(segments) files instead of O(records)
    (docs/robustness.md#journal-segments).  The reader verifies every
    segment's footer and skips torn/corrupt ones; a crash between
    sealing and loose-file deletion leaves duplicates whose ids are
    covered by a sealed segment — they are ignored on read and swept by
    the next compaction.  ``segment_size=None`` (default) never
    compacts: the PR 9 one-file-per-record layout, bit-identical."""

    def __init__(self, root: str, segment_size: int | None = None):
        if segment_size is not None and segment_size < 1:
            raise ValueError("segment_size must be >= 1 or None")
        self.root = root
        self.segment_size = segment_size
        self.compactions = 0
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    def _ids(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _REC_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _segments(self) -> list[tuple[int, int]]:
        """Sealed segment spans ``(first, last)``, ascending."""
        out = []
        for name in os.listdir(self.root):
            m = _SEG_RE.match(name)
            if m:
                out.append((int(m.group(1)), int(m.group(2))))
        return sorted(out)

    def _sealed_last(self) -> int:
        segs = self._segments()
        return segs[-1][1] if segs else 0

    def _read_segment(self, first: int, last: int) -> list[dict] | None:
        """Records of one sealed segment, or None when the segment is
        torn/corrupt (footer digest mismatch, truncation, bad JSON)."""
        path = os.path.join(self.root, f"seg_{first:08d}_{last:08d}.json")
        try:
            with open(path) as f:
                text = f.read()
            body, _, footer = text.rstrip("\n").rpartition("\n")
            if not body or footer != hashlib.sha256(
                    body.encode()).hexdigest():
                return None
            seg = json.loads(body)
            if seg.get("first") != first or seg.get("last") != last:
                return None
            return list(seg["records"])
        except (OSError, ValueError, KeyError, TypeError):
            return None

    def append(self, record: dict) -> int:
        """Atomically append one JSON record; returns its id.

        With ``segment_size`` set, reaching that many loose files
        triggers an in-line compaction."""
        with self._lock:
            ids = self._ids()
            last = max(ids[-1] if ids else 0, self._sealed_last())
            rec_id = last + 1
            final = os.path.join(self.root, f"rec_{rec_id:08d}.json")
            tmp = final + ".tmp"
            with open(tmp, "w") as f:
                json.dump(record, f)
            os.replace(tmp, final)
            if self.segment_size is not None and \
                    len(ids) + 1 >= self.segment_size:
                self._compact_locked()
            return rec_id

    def compact(self) -> int:
        """Merge every live loose record into one sealed segment and
        delete the loose files; returns the number of records sealed
        (0 = nothing to do).  Safe to call at any time — a crash
        anywhere in the sequence loses no record (the segment is
        sealed atomically before any loose file is removed)."""
        with self._lock:
            return self._compact_locked()

    def _compact_locked(self) -> int:
        sealed_last = self._sealed_last()
        live: list[tuple[int, dict]] = []
        debris: list[int] = []
        for rec_id in self._ids():
            if rec_id <= sealed_last:
                # duplicate from a crash between seal and delete: its
                # content is already in a sealed segment
                debris.append(rec_id)
                continue
            path = os.path.join(self.root, f"rec_{rec_id:08d}.json")
            try:
                with open(path) as f:
                    live.append((rec_id, json.load(f)))
            except (OSError, ValueError):
                continue
        if live:
            first, last = live[0][0], live[-1][0]
            body = json.dumps({"first": first, "last": last,
                               "records": [r for _, r in live]})
            footer = hashlib.sha256(body.encode()).hexdigest()
            final = os.path.join(self.root,
                                 f"seg_{first:08d}_{last:08d}.json")
            tmp = final + ".tmp"
            with open(tmp, "w") as f:
                f.write(body + "\n" + footer + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
            self.compactions += 1
        for rec_id, _ in live:
            debris.append(rec_id)
        for rec_id in debris:
            try:
                os.remove(os.path.join(self.root,
                                       f"rec_{rec_id:08d}.json"))
            except OSError:
                pass
        return len(live)

    def records(self) -> list[dict]:
        """All complete records in append order: sealed segments first
        (span order), then loose records newer than the last seal.

        Stray ``.tmp`` files (a killed writer), unparseable record
        files and torn segments are skipped — crash debris must never
        poison a resume.  Loose records whose ids a sealed segment
        covers are crash-window duplicates and are ignored."""
        out = []
        sealed_last = 0
        for first, last in self._segments():
            recs = self._read_segment(first, last)
            if recs is not None:
                out.extend(recs)
                sealed_last = max(sealed_last, last)
        for rec_id in self._ids():
            if rec_id <= sealed_last:
                continue
            path = os.path.join(self.root, f"rec_{rec_id:08d}.json")
            try:
                with open(path) as f:
                    out.append(json.load(f))
            except (OSError, ValueError):
                continue
        return out

    def stats(self) -> dict:
        """Journal shape: live record count, sealed segment count,
        loose file count, on-disk bytes, compactions this instance ran."""
        segs = self._segments()
        sealed_last = segs[-1][1] if segs else 0
        n_sealed = 0
        for first, last in segs:
            recs = self._read_segment(first, last)
            if recs is not None:
                n_sealed += len(recs)
        loose = [i for i in self._ids() if i > sealed_last]
        size = 0
        for name in os.listdir(self.root):
            if _REC_RE.match(name) or _SEG_RE.match(name):
                try:
                    size += os.path.getsize(os.path.join(self.root, name))
                except OSError:
                    pass
        return {"records": n_sealed + len(loose),
                "segments": len(segs), "loose_files": len(loose),
                "bytes": size, "compactions": self.compactions}

    def clear(self) -> None:
        with self._lock:
            for name in list(os.listdir(self.root)):
                if _REC_RE.match(name) or _SEG_RE.match(name):
                    try:
                        os.remove(os.path.join(self.root, name))
                    except OSError:
                        pass

# numpy cannot round-trip ml_dtypes through .npy files (loads as void);
# store them through a same-width uint view and record the real dtype in
# the manifest.
_EXOTIC_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _EXOTIC_DTYPES:
        return arr.view(_EXOTIC_DTYPES[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC_DTYPES:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_token(p) for p in path)
        out.append((key, leaf))
    return out


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._worker: threading.Thread | None = None

    # ------------------------------------------------------------- #
    def save(self, step: int, tree, background: bool = False) -> None:
        leaves = _flatten_with_paths(tree)
        # snapshot to host synchronously (device buffers may be donated
        # by the next step)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in leaves]
        if background:
            self.wait()
            self._worker = threading.Thread(
                target=self._write, args=(step, tree, host), daemon=True)
            self._worker.start()
        else:
            self._write(step, tree, host)

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, tree, host) -> None:
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host:
            fname = key.replace("/", "__") + ".npy"
            storable, dtype_name = _to_storable(arr)
            np.save(os.path.join(tmp, fname), storable)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype_name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- #
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.root, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def load(self, step: int, like, shardings=None):
        """Restore into the structure of ``like``; when ``shardings`` (a
        matching pytree of NamedSharding) is given, every leaf is placed
        onto the new mesh — pod counts/mesh shape may differ from the
        saving job (elastic restart)."""
        path = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten_with_paths(like)
        shard_flat = _flatten_with_paths(shardings) if shardings \
            else [(k, None) for k, _ in flat_like]
        shard_map = dict(shard_flat)
        leaves = []
        for key, ref in flat_like:
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = _from_storable(np.load(os.path.join(path, meta["file"])),
                                 meta["dtype"])
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {ref.shape}")
            sh = shard_map.get(key)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)


# convenience functions ------------------------------------------------- #

def save_checkpoint(root: str, step: int, tree,
                    background: bool = False) -> None:
    CheckpointStore(root).save(step, tree, background=background)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    return CheckpointStore(root).latest_step()


def load_checkpoint(root: str, step: int, like, shardings=None):
    return CheckpointStore(root).load(step, like, shardings)
