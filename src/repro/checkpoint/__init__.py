from .store import (CheckpointStore, latest_step, load_checkpoint,
                    save_checkpoint)
