from .store import (CheckpointStore, RecordJournal, latest_step,
                    load_checkpoint, save_checkpoint)
