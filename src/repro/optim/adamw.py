"""AdamW with decoupled weight decay and global-norm clipping.

Implemented from scratch (optax unavailable).  Moment dtype is
configurable: fp32 default; bf16 for trillion-parameter configs so the
optimizer state fits the per-chip HBM budget (see configs/kimi_k2*)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m_new / b1t
        vhat = v_new / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, gnorm
