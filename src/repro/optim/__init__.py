from .adamw import AdamWConfig, adamw_init, adamw_update
from .schedule import cosine_schedule
