"""Int8 error-feedback gradient compression (1000+ node DCN trick).

Cross-pod gradient reduction over DCN is bandwidth-starved relative to
ICI; int8 block-quantised gradients with an error-feedback residual
(1-bit Adam / PowerSGD lineage) cut the cross-pod bytes 4x while keeping
convergence (the residual re-injects the quantisation error next step).

``compress``/``decompress`` are pure jnp and run inside the train step;
the residual rides in the optimizer state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g: jax.Array, block: int = 256):
    """-> (int8 codes, per-block f32 scales).  Works on any shape."""
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127
                     ).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def decompress(codes: jax.Array, scale: jax.Array, shape,
               dtype=jnp.float32) -> jax.Array:
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(g: jax.Array, residual: jax.Array,
                           block: int = 256):
    """Error feedback: quantise (g + residual), keep the new residual."""
    target = g.astype(jnp.float32) + residual
    codes, scale = compress(target, block)
    approx = decompress(codes, scale, g.shape)
    new_residual = target - approx
    return codes, scale, approx, new_residual


def compressed_psum(g: jax.Array, axis_name: str,
                    residual: jax.Array, block: int = 256):
    """psum of int8-compressed gradients along ``axis_name`` (used for
    the cross-pod reduction inside shard_map); returns the dequantised
    sum and the updated error-feedback residual."""
    codes, scale, approx, new_residual = compress_with_feedback(
        g, residual, block)
    summed = jax.lax.psum(approx, axis_name)
    return summed, new_residual
