"""Port-assignment schedulers.

``uniform``  — the paper's assumption (2): every eligible port of a uop is
used with equal probability.  This is what OSACA 0.2 implements and what the
paper's Tables II/IV/VI/VII show.

``balanced`` — beyond-paper: minimise the maximum port load (what IACA's
undisclosed weighting approximates, paper Sec. III-A: "IACA does not schedule
instruction forms with an average probability but weighs specific ports").
Solved exactly as a fractional scheduling LP via binary search on the
bottleneck C + max-flow feasibility (uop -> eligible ports, port cap C).
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass

from .ports import PortModel, Uop


@dataclass
class ScheduledUop:
    uop: Uop
    instr_index: int
    assignment: dict[str, float]  # port -> occupied cycles
    hidden: bool = False


def schedule_uniform(model: PortModel,
                     uops: list[tuple[int, Uop]]) -> list[ScheduledUop]:
    out = []
    for idx, uop in uops:
        if not uop.ports:
            # port-less uop (e.g. an eliminated register move): occupies
            # nothing, contributes zero to every port total
            out.append(ScheduledUop(uop, idx, {}))
            continue
        share = uop.cycles / len(uop.ports)
        out.append(ScheduledUop(uop, idx, {p: share for p in uop.ports}))
    return out


# --------------------------------------------------------------------------
# Exact min-max fractional scheduling (max-flow feasibility)
# --------------------------------------------------------------------------

class _Flow:
    """Tiny float max-flow (BFS augmenting paths); graphs here are < 100
    nodes so asymptotics are irrelevant."""

    def __init__(self, n: int):
        self.n = n
        self.cap: list[dict[int, float]] = [defaultdict(float)
                                            for _ in range(n)]

    def add(self, u: int, v: int, c: float) -> None:
        self.cap[u][v] += c
        self.cap[v].setdefault(u, 0.0)

    def maxflow(self, s: int, t: int, eps: float = 1e-12) -> float:
        total = 0.0
        while True:
            parent = {s: s}
            queue = deque([s])
            while queue and t not in parent:
                u = queue.popleft()
                for v, c in self.cap[u].items():
                    if c > eps and v not in parent:
                        parent[v] = u
                        queue.append(v)
            if t not in parent:
                return total
            # bottleneck along path
            v, bottleneck = t, float("inf")
            while v != s:
                u = parent[v]
                bottleneck = min(bottleneck, self.cap[u][v])
                v = u
            v = t
            while v != s:
                u = parent[v]
                self.cap[u][v] -= bottleneck
                self.cap[v][u] += bottleneck
                v = u
            total += bottleneck


def schedule_balanced(model: PortModel,
                      uops: list[tuple[int, Uop]],
                      iterations: int = 50) -> list[ScheduledUop]:
    if not uops:
        return []
    # uops with an empty eligible-port set (pure-register-move streams
    # after move elimination) cannot be routed: they get an empty
    # assignment and are excluded from the flow problem.  Without this,
    # feasible(hi) can never satisfy the demand and the binary search
    # asserts (and all-empty kernels would take max() of an empty set).
    routable = [(i, idx, uop) for i, (idx, uop) in enumerate(uops)
                if uop.ports]
    out: list[ScheduledUop | None] = [
        None if uop.ports else ScheduledUop(uop, idx, {})
        for idx, uop in uops]
    if not routable:
        return [s for s in out if s is not None]

    ports = list(model.ports)
    pindex = {p: i for i, p in enumerate(ports)}
    n_uops = len(routable)
    total = sum(u.cycles for _, _, u in routable)
    lo = max((u.cycles for _, _, u in routable if len(u.ports) == 1),
             default=0.0)
    lo = max(lo, total / len(ports))
    hi = total

    # feasible() is memoized on the binary-search midpoint grid: the
    # search interval halves every step, so once it shrinks below the
    # grid resolution every further midpoint is a repeat and the
    # remaining iterations cost a dict hit instead of a max-flow solve
    # (a measurable win for AnalysisService.sweep over many kernels).
    memo: dict[float, _Flow | None] = {}

    def feasible(C: float) -> _Flow | None:
        key = round(C, 9)
        if key in memo:
            return memo[key]
        # nodes: 0 = src, 1..n_uops = uops, then ports, then sink
        fl = _Flow(1 + n_uops + len(ports) + 1)
        sink = 1 + n_uops + len(ports)
        need = 0.0
        for i, (_, _, uop) in enumerate(routable):
            fl.add(0, 1 + i, uop.cycles)
            need += uop.cycles
            for p in uop.ports:
                fl.add(1 + i, 1 + n_uops + pindex[p], uop.cycles)
        for p in ports:
            fl.add(1 + n_uops + pindex[p], sink, C)
        got = fl.maxflow(0, sink)
        res = fl if got >= need - 1e-9 else None
        memo[key] = res
        return res

    best_flow = feasible(hi)
    assert best_flow is not None
    for _ in range(iterations):
        mid = 0.5 * (lo + hi)
        fl = feasible(mid)
        if fl is not None:
            best_flow, hi = fl, mid
        else:
            lo = mid
        if hi - lo <= 1e-9 * max(1.0, hi):
            break                   # converged below the memo grid
    # recover per-uop assignment from residual graph: flow on edge
    # (uop -> port) = cap added originally - residual remaining
    for i, (pos, idx, uop) in enumerate(routable):
        assignment: dict[str, float] = {}
        for p in uop.ports:
            pnode = 1 + n_uops + pindex[p]
            sent = uop.cycles - best_flow.cap[1 + i][pnode]
            if sent > 1e-9:
                assignment[p] = sent
        out[pos] = ScheduledUop(uop, idx, assignment)
    return [s for s in out if s is not None]


SCHEDULERS = {
    "uniform": schedule_uniform,
    "balanced": schedule_balanced,
}
