"""Port-conflict (combined) benchmarks — paper Sec. II-B.

"By adding another instruction form into the already throughput-bound
benchmark, either an increase or no change in runtime is expected.  If the
runtime increased, both instruction forms utilize at least one common port."
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .ibench import _loop_overhead, _timeit


@dataclass
class ConflictResult:
    name: str
    base_seconds_per_iter: float
    combined_seconds_per_iter: float

    @property
    def slowdown(self) -> float:
        return self.combined_seconds_per_iter / self.base_seconds_per_iter

    @property
    def shares_port(self) -> bool:
        # >15% slowdown => at least one common port (threshold mirrors the
        # paper's Zen example: +104% for vmulpd, +4% for vaddpd)
        return self.slowdown > 1.15


def conflict_benchmark(base_op: Callable, probe_op: Callable,
                       shape=(4,), dtype=jnp.float32,
                       parallelism: int = 8, chain_len: int = 16,
                       iters: int = 1000,
                       name: str = "conflict") -> ConflictResult:
    c = jnp.full(shape, 1.0000001, dtype)

    def runner(include_probe: bool):
        @jax.jit
        def run(xs, ys):
            def body(_, state):
                xs, ys = state
                for _ in range(chain_len):
                    xs = tuple(base_op(x, c) for x in xs)
                    if include_probe:
                        ys = tuple(probe_op(y, c) for y in ys)
                return xs, ys
            return lax.fori_loop(0, iters, body, (xs, ys))
        xs0 = tuple(jnp.full(shape, 1.0 + i * 1e-3, dtype)
                    for i in range(parallelism))
        ys0 = tuple(jnp.full(shape, 2.0 + i * 1e-3, dtype)
                    for i in range(parallelism))
        return _timeit(lambda: run(xs0, ys0))

    overhead = _loop_overhead(shape, dtype, iters)
    base = max(runner(False) - overhead, 1e-12) / iters
    combined = max(runner(True) - overhead, 1e-12) / iters
    return ConflictResult(name, base, combined)
