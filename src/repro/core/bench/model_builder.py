"""Semi-automatic machine-model construction (paper Sec. II-C).

From parallelism sweeps, infer the number of independent ports an
instruction form can use (reciprocal TP = 1/ports at saturation), then
assemble a declarative :class:`~repro.core.machine.MachineModel` for the
host — the same workflow the paper walks through for vfmadd132pd on
Zen/Skylake, and the measurement-driven counterpart of
``MachineModel.from_benchmarks``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from ..database import InstructionDB
from ..machine import BenchRecord, MachineModel
from ..ports import PortModel
from .ibench import BenchResult, sweep_parallelism


def infer_port_count(results: list[BenchResult],
                     saturation_tol: float = 0.15) -> int:
    """Latency / saturated-throughput ratio, rounded (paper: 'the
    instruction form can be spread among two separate ports, because its
    throughput is one half')."""
    latency = results[0].seconds_per_op
    saturated = min(r.seconds_per_op for r in results)
    ports = max(1, round(latency / max(saturated, 1e-15)))
    return ports


@dataclass
class MeasuredForm:
    name: str
    op: Callable
    latency_s: float
    throughput_s: float
    ports: int


def build_host_machine(ops: dict[str, Callable] | None = None,
                       shape=(4,), dtype=jnp.float32,
                       frequency_hz: float = 2.0e9) -> tuple[
                           MachineModel, list[MeasuredForm]]:
    """Benchmark each op and assemble the measured host machine as a
    declarative :class:`MachineModel` (ports ``"p0" .. "pN"`` sized to
    the widest form, occupations reproducing the measured reciprocal
    throughputs).  The model serializes like any other — measured
    machines are shippable artifacts too.
    """
    if ops is None:
        ops = {
            "add": lambda x, c: x + c,
            "mul": lambda x, c: x * c,
            "fma": lambda x, c: x * c + c,
            "div": lambda x, c: x / c,
        }
    records: list[BenchRecord] = []
    measured: list[MeasuredForm] = []
    for name, op in ops.items():
        sweep = sweep_parallelism(op, shape, dtype, name=name)
        records += [BenchRecord(form=name, parallelism=r.parallelism,
                                value=r.seconds_per_op)
                    for r in sweep]
        measured.append(MeasuredForm(
            name=name, op=op,
            latency_s=sweep[0].seconds_per_op,
            throughput_s=min(r.seconds_per_op for r in sweep),
            ports=0))  # filled from the built machine below
    # pipelined=False: in the JAX harness a unit is occupied for the
    # whole op latency, so port count is latency / saturated TP
    machine = MachineModel.from_benchmarks(
        records, arch_id="host", name="host-cpu (measured)", unit="s",
        pipelined=False, frequency_hz=frequency_hz)
    # report the port counts the artifact actually carries, so the
    # benchmark rows can never disagree with the shipped model
    widths = {f.mnemonic: len(f.uops[0].ports) for f in machine.forms}
    for m in measured:
        m.ports = widths[m.name]
    return machine, measured


def build_host_model(ops: dict[str, Callable] | None = None,
                     shape=(4,), dtype=jnp.float32,
                     frequency_hz: float = 2.0e9
                     ) -> tuple[PortModel, InstructionDB,
                                list[MeasuredForm]]:
    """Back-compat wrapper around :func:`build_host_machine` returning
    the runtime views (``PortModel`` + ``InstructionDB``)."""
    machine, measured = build_host_machine(ops, shape, dtype, frequency_hz)
    return machine.port_model, machine.database(), measured
