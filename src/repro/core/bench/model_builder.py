"""Semi-automatic machine-model construction (paper Sec. II-C).

From parallelism sweeps, infer the number of independent ports an
instruction form can use (reciprocal TP = 1/ports at saturation), then
assemble a :class:`PortModel` + :class:`InstructionDB` for the host — the
same workflow the paper walks through for vfmadd132pd on Zen/Skylake.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from ..database import E, InstructionDB
from ..ports import PortModel, U
from .ibench import BenchResult, sweep_parallelism


def infer_port_count(results: list[BenchResult],
                     saturation_tol: float = 0.15) -> int:
    """Latency / saturated-throughput ratio, rounded (paper: 'the
    instruction form can be spread among two separate ports, because its
    throughput is one half')."""
    latency = results[0].seconds_per_op
    saturated = min(r.seconds_per_op for r in results)
    ports = max(1, round(latency / max(saturated, 1e-15)))
    return ports


@dataclass
class MeasuredForm:
    name: str
    op: Callable
    latency_s: float
    throughput_s: float
    ports: int


def build_host_model(ops: dict[str, Callable] | None = None,
                     shape=(4,), dtype=jnp.float32,
                     frequency_hz: float = 2.0e9
                     ) -> tuple[PortModel, InstructionDB,
                                list[MeasuredForm]]:
    """Benchmark each op, infer port counts, emit a synthetic port model
    ("h0", "h1", ...) sized to the widest form, and a database whose
    occupations reproduce the measured reciprocal throughputs."""
    if ops is None:
        ops = {
            "add": lambda x, c: x + c,
            "mul": lambda x, c: x * c,
            "fma": lambda x, c: x * c + c,
            "div": lambda x, c: x / c,
        }
    measured: list[MeasuredForm] = []
    for name, op in ops.items():
        sweep = sweep_parallelism(op, shape, dtype, name=name)
        ports = infer_port_count(sweep)
        measured.append(MeasuredForm(
            name=name, op=op,
            latency_s=sweep[0].seconds_per_op,
            throughput_s=min(r.seconds_per_op for r in sweep),
            ports=ports))
    width = max(m.ports for m in measured)
    port_names = tuple(f"h{i}" for i in range(width))
    model = PortModel(name="host-cpu (measured)", ports=port_names,
                      unit="s", frequency_hz=frequency_hz)
    db = InstructionDB("host", model)
    for m in measured:
        eligible = "|".join(port_names[:m.ports])
        # occupation in seconds: saturated per-op time * ports
        cycles = m.throughput_s * m.ports
        db.add(E(m.name, "v,v,v", [U(eligible, cycles)],
                 tp=m.throughput_s, lat=m.latency_s,
                 notes=f"measured, {m.ports} port(s)"))
    return model, db, measured
