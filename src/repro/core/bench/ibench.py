"""Latency / throughput chains for JAX ops (paper Sec. II-A).

The paper benchmarks x86 instruction forms with ibench: a dependency chain
measures latency; >=10 independent chains measure reciprocal throughput.
We reproduce the harness for JAX ops: the "instruction form" is a callable
``op(x, y)`` plus operand shape/dtype.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


@dataclass
class BenchResult:
    name: str
    parallelism: int
    seconds_per_op: float
    ops_per_second: float

    def cycles(self, frequency_hz: float) -> float:
        return self.seconds_per_op * frequency_hz

    def ibench_line(self, frequency_hz: float, tag: str = "") -> str:
        """Render like the paper's Sec. II-C ibench output."""
        label = f"{self.name}-{tag or self.parallelism}"
        return f"{label}: {self.cycles(frequency_hz):7.3f} (clk cy)"


def _timeit(fn: Callable[[], object], repeats: int = 5) -> float:
    fn()  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    # paper Sec. I-C: "we report the best value (highest performance)"
    return best


def latency_benchmark(op: Callable, shape=(4,), dtype=jnp.float32,
                      chain_len: int = 64, iters: int = 2000,
                      name: str = "op") -> BenchResult:
    """Serial dependency chain: x <- op(x, c), paper's latency benchmark."""
    c = jnp.full(shape, 1.0000001, dtype)

    @jax.jit
    def run(x0):
        def body(_, x):
            for _ in range(chain_len):
                x = op(x, c)
            return x
        return lax.fori_loop(0, iters, body, x0)

    x0 = jnp.ones(shape, dtype)
    total = _timeit(lambda: run(x0))
    overhead = _loop_overhead(shape, dtype, iters)
    per_op = max(total - overhead, 1e-12) / (chain_len * iters)
    return BenchResult(name, 1, per_op, 1.0 / per_op)


def throughput_benchmark(op: Callable, shape=(4,), dtype=jnp.float32,
                         parallelism: int = 10, chain_len: int = 16,
                         iters: int = 2000, name: str = "op") -> BenchResult:
    """`parallelism` independent chains (paper: 'multiple independent
    dependency chains ... to utilize all functional units')."""
    c = jnp.full(shape, 1.0000001, dtype)

    @jax.jit
    def run(xs):
        def body(_, xs):
            for _ in range(chain_len):
                xs = tuple(op(x, c) for x in xs)
            return xs
        return lax.fori_loop(0, iters, body, xs)

    xs0 = tuple(jnp.full(shape, 1.0 + i * 1e-3, dtype)
                for i in range(parallelism))
    total = _timeit(lambda: run(xs0))
    overhead = _loop_overhead(shape, dtype, iters)
    per_op = max(total - overhead, 1e-12) / (chain_len * iters * parallelism)
    return BenchResult(name, parallelism, per_op, 1.0 / per_op)


def sweep_parallelism(op: Callable, shape=(4,), dtype=jnp.float32,
                      levels=(1, 2, 4, 5, 8, 10, 12),
                      name: str = "op") -> list[BenchResult]:
    """Paper Sec. II-C: run the form at increasing parallelism; the level
    where per-op time saturates reveals the number of ports."""
    out = [latency_benchmark(op, shape, dtype, name=name)]
    for p in levels[1:]:
        out.append(throughput_benchmark(op, shape, dtype, parallelism=p,
                                        name=name))
    return out


def _loop_overhead(shape, dtype, iters: int) -> float:
    key = (tuple(shape), jnp.dtype(dtype).name, iters)
    if key not in _OVERHEAD_CACHE:
        @jax.jit
        def run(x0):
            return lax.fori_loop(0, iters, lambda _, x: x, x0)
        x0 = jnp.ones(shape, dtype)
        _OVERHEAD_CACHE[key] = _timeit(lambda: run(x0))
    return _OVERHEAD_CACHE[key]


_OVERHEAD_CACHE: dict = {}
