"""ibench-analogue micro-benchmarking (paper Sec. II).

Latency = dependency chain; throughput = k independent chains; port
mapping = combined (conflict) benchmarks.  Executed with JAX on the host
CPU — the *methodology* of the paper, applied to the machine we have.
"""
from .ibench import (BenchResult, latency_benchmark, sweep_parallelism,
                     throughput_benchmark)
from .conflict import conflict_benchmark
from .model_builder import (build_host_machine, build_host_model,
                            infer_port_count)
