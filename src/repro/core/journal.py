"""Crash-safe resumable sweeps: the machine-group result journal.

``AnalysisService.sweep(journal=dir)`` appends one record per
*completed* machine-group dispatch through the checkpoint store's
:class:`~repro.checkpoint.store.RecordJournal` (tmp + rename per
record, so a killed sweep never leaves a torn record).  A later
``sweep(resume_from=dir)`` replays matching records straight into the
sim cache — zero re-dispatch of journaled groups — and, because JSON
floats round-trip exactly (shortest-repr), the resumed grid is
bit-identical to an uninterrupted run.

Records are scoped by a *plan digest*: sha256 over the ordered
request keys plus the backend choice.  A journal written for one sweep
is inert for any other — changing the kernel set, the arch grid, the
mode, or the backend changes the digest and no stale group can leak in.

``SimResult.params`` is deliberately not serialized: it is derived
state (``prog.model.pipeline or DEFAULT_PARAMS``), reconstructed on
load from the same machine model the resumed sweep resolves.
"""

from __future__ import annotations

import hashlib
from typing import Mapping, Sequence

from .sim.pipeline import DEFAULT_PARAMS, SimResult

__all__ = ["SweepJournal", "plan_digest", "sim_to_record", "sim_from_record"]


def plan_digest(request_keys: Sequence[tuple], backend: str) -> str:
    """Content address of a sweep plan: the ordered request keys (each
    already carries the resolved machine digest, kernel id, mode,
    working set, ...) plus the backend choice."""
    canon = repr((tuple(request_keys), backend))
    return hashlib.sha256(canon.encode()).hexdigest()


def sim_to_record(sim: SimResult) -> dict:
    return {
        "cpi": sim.cycles_per_iteration,
        "iterations": sim.iterations,
        "converged": sim.converged,
        "bottleneck": sim.bottleneck,
        "frontend_cycles": sim.frontend_cycles,
        "port_busy": dict(sim.port_busy),
        "delivery_cycles": sim.delivery_cycles,
        "fe_mode": sim.fe_mode,
    }


def sim_from_record(rec: Mapping, params) -> SimResult:
    return SimResult(
        cycles_per_iteration=rec["cpi"],
        iterations=rec["iterations"],
        converged=rec["converged"],
        bottleneck=rec["bottleneck"],
        frontend_cycles=rec["frontend_cycles"],
        port_busy=dict(rec["port_busy"]),
        params=params if params is not None else DEFAULT_PARAMS,
        delivery_cycles=rec["delivery_cycles"],
        fe_mode=rec["fe_mode"],
    )


class SweepJournal:
    """Reader/writer for one journal directory.

    A group record is keyed ``(machine digest, ordered program
    digests)`` under a plan digest; ``sims`` is ``None`` for a group
    that degraded all the way to the analytic floor (replaying that is
    what keeps resume bit-identical even under faults).

    ``segment_size`` bounds the live loose-file count: reaching it
    folds the loose records into one sealed, digest-verified segment
    (see :class:`~repro.checkpoint.store.RecordJournal`), so a
    million-cell sweep keeps O(segments) journal files.  ``None``
    (default) never compacts — the PR 9 layout, bit-identical."""

    def __init__(self, root: str, segment_size: int | None = None):
        # local import: repro.checkpoint pulls in jax at module scope
        from ..checkpoint.store import RecordJournal
        self._journal = RecordJournal(root, segment_size=segment_size)

    def compact(self) -> int:
        """Seal the loose records into a segment now; returns how many
        were sealed."""
        return self._journal.compact()

    def stats(self) -> dict:
        """Record/segment/loose-file counts + on-disk bytes
        (``RecordJournal.stats``)."""
        return self._journal.stats()

    # -- writer -------------------------------------------------------
    def record_group(self, plan: str, machine_digest: str,
                     prog_digests: Sequence[str],
                     sims: Sequence[SimResult] | None,
                     backend_used: str, degraded: bool) -> None:
        self._journal.append({
            "plan": plan,
            "machine": machine_digest,
            "programs": list(prog_digests),
            "backend_used": backend_used,
            "degraded": degraded,
            "sims": None if sims is None else [sim_to_record(s) for s in sims],
        })

    # -- reader -------------------------------------------------------
    def load(self, plan: str) -> dict[tuple[str, tuple[str, ...]], dict]:
        """Completed group records for ``plan``, keyed
        ``(machine digest, program digests)``; later records win (a
        resumed run may have re-journaled a group)."""
        out: dict[tuple[str, tuple[str, ...]], dict] = {}
        for rec in self._journal.records():
            if rec.get("plan") != plan:
                continue
            key = (rec["machine"], tuple(rec["programs"]))
            out[key] = rec
        return out
