"""Batched multi-architecture analysis service (the unified prediction
engine).

One :class:`AnalysisService` owns every per-architecture instruction
database and serves *batches* of kernels x architectures x schedulers
through a single memoized pipeline:

* **DB construction** — architectures resolve through an
  :class:`~repro.core.arch.registry.ArchRegistry` (a private child of
  the process-wide registry, so runtime ``register()`` calls stay
  service-local); each database is built once per registry layer and
  shared across the batch.
* **Form lookups** — ``db.lookup`` results are cached per
  ``(arch, mnemonic, signature)``; a sweep re-resolving the same triad
  kernel on three schedulers pays for the progressive-generalisation
  walk only once.
* **Balanced-scheduler LP solves** — ``schedule_balanced`` is an exact
  min-max flow LP; its result depends only on the (ordered) uop spec, so
  identical kernels across the batch reuse the solve.
* **Whole results** — ``predict()`` itself is memoized on
  ``(arch, kernel, scheduler, unroll, latency_bound)``; ``render()``
  variations, table generators and tests all hit the same entry.
* **HLO analyses** — ``predict_hlo`` caches by module-text digest, so the
  serving dry-run and the roofline benchmark share one pass per program.

Entry points: :meth:`AnalysisService.predict` (one request),
:meth:`~AnalysisService.predict_batch` (many, optionally threaded),
:meth:`~AnalysisService.predict_async` (awaitable), and
:meth:`~AnalysisService.sweep` (full kernels x archs x schedulers grid).

Every analytic prediction is the *combined* bound ``max(port_bound,
LCD)`` from :func:`repro.core.analysis.analyze`; ``mode="simulate"``
requests additionally run the cycle-level pipeline simulator
(``repro.core.sim``) and report its steady state as ``bound_sim`` —
see docs/prediction-model.md and docs/simulation.md.
"""
from __future__ import annotations

import asyncio
import hashlib
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Iterable, Mapping, Sequence

from .analysis import AnalysisResult, analyze
from .arch.registry import ArchRegistry, UnknownArchError, default_registry
from .database import InstructionDB
from .isa import Instruction
from .kernel import extract_kernel
from .machine import MachineModel
from .ports import PortModel, Uop
from .scheduler import SCHEDULERS, ScheduledUop


@dataclass(frozen=True)
class AnalysisRequest:
    """One cell of a batch: a kernel analyzed on one architecture.

    Attributes:
        kernel: assembly source text (markers/loop detection handled by
            :func:`repro.core.kernel.extract_kernel`) or an already-parsed
            tuple of :class:`~repro.core.isa.Instruction`.
        arch: architecture id or alias resolved through the service's
            :class:`~repro.core.arch.registry.ArchRegistry`
            (``"skl"``/``"skylake"``, ``"zen"``/``"zen1"``/``"znver1"``,
            any shipped ``arch/models/*.json`` id, or a model registered
            via :meth:`AnalysisService.register`).
        scheduler: ``"uniform"`` or ``"balanced"``.
        unroll_factor: assembly iterations per source iteration.
        latency_bound: fold the LCD bound into the prediction (default).
        syntax: ``"att"`` or ``"intel"`` when ``kernel`` is text.
        mode: ``"analytic"`` (the combined ``max(port_bound, LCD)``
            bound, default) or ``"simulate"`` (additionally run the
            cycle-level pipeline simulator, ``repro.core.sim`` — the
            result then carries ``bound_sim``/``sim_result``, and
            ``predicted_cycles`` is the simulated steady state floored
            at the LCD bound).
    """

    kernel: str | tuple[Instruction, ...]
    arch: str = "skl"
    scheduler: str = "uniform"
    unroll_factor: int = 1
    latency_bound: bool = True
    syntax: str = "att"
    mode: str = "analytic"


@dataclass
class ServiceStats:
    """Cache-effectiveness counters for one :class:`AnalysisService`."""

    result_hits: int = 0
    result_misses: int = 0
    lookup_hits: int = 0
    lookup_misses: int = 0
    lp_hits: int = 0
    lp_misses: int = 0
    hlo_hits: int = 0
    hlo_misses: int = 0
    sim_runs: int = 0        # cycle-level simulations actually executed
    #                          (cache hits are counted in result_hits)

    def as_dict(self) -> dict[str, int]:
        return dict(vars(self))


class AnalysisService:
    """Memoizing, thread-safe front end over the prediction pipeline.

    A single instance can be shared by benchmarks, examples, the HLO
    analyzer and the serving engine; all of them then draw from the same
    database/lookup/LP/result caches.  All public methods are safe to
    call from multiple threads (``predict_batch(parallel=True)`` does).
    """

    def __init__(self, max_workers: int = 8,
                 registry: ArchRegistry | None = None):
        self._lock = threading.RLock()
        # a private child of the (shared) registry: this service's
        # register() calls shadow the parent without leaking into other
        # services, while built-in model/DB caches stay shared
        self._arch = ArchRegistry(parent=registry or default_registry())
        self._lookups: dict[str, Callable[[Instruction], object]] = {}
        self._lp_cache: dict[tuple, list[ScheduledUop]] = {}
        self._results: dict[tuple, AnalysisResult] = {}
        self._sim_cache: dict[tuple, object] = {}   # SimResult by kernel
        self._hlo_cache: dict[tuple, object] = {}
        self._max_workers = max_workers
        self.stats = ServiceStats()

    # ------------------------------------------------------------------
    # architectures
    # ------------------------------------------------------------------
    @property
    def registry(self) -> ArchRegistry:
        """This service's architecture registry (a private child of the
        process-wide :func:`repro.core.arch.registry.default_registry`)."""
        return self._arch

    def register(self, model: MachineModel, *,
                 aliases: Sequence[str] | None = None,
                 replace: bool = True) -> str:
        """Register a :class:`MachineModel` with this service.

        The model's id (and aliases) become valid ``AnalysisRequest.arch``
        values for this service only.  Re-registering an id — including
        shadowing a built-in like ``"skl"`` — drops every cached lookup
        and result for it, so subsequent predictions use the new model.
        An ``arch_id`` that is an *alias spelling* of an existing id
        (``"skylake"``) shadows the canonical id (``"skl"``) rather than
        splitting the alias from it.  Returns the canonical id.
        """
        try:
            canonical = self._arch.resolve(model.arch_id)
        except UnknownArchError:
            canonical = model.arch_id
        if canonical != model.arch_id:
            model = model.derive(canonical, aliases=model.aliases)
        key = self._arch.register(model, aliases=aliases, replace=replace)
        self._invalidate_arch(key)
        return key

    def register_db(self, name: str, db: InstructionDB) -> None:
        """Deprecated: wrap ``db`` in a :class:`MachineModel` and call
        :meth:`register` instead.  This shim does exactly that (via
        :meth:`MachineModel.from_db`) and keeps the old semantics:
        re-registering a name (or an alias spelling of it) shadows the
        built-in and drops its cached results."""
        warnings.warn(
            "AnalysisService.register_db is deprecated; use "
            "register(MachineModel.from_db(...)) or register a "
            "MachineModel directly", DeprecationWarning, stacklevel=2)
        try:
            key = self._arch.resolve(name)
        except UnknownArchError:
            key = name.lower()
        self.register(MachineModel.from_db(key, db))
        # keep the caller's exact database object (old register_db
        # semantics), not a rebuild from the extracted form table
        self._arch.prime_database(key, db)

    def _invalidate_arch(self, key: str) -> None:
        with self._lock:
            self._lookups.pop(key, None)
            for k in [k for k in self._results if k[0] == key]:
                del self._results[k]
            for k in [k for k in self._sim_cache if k[0] == key]:
                del self._sim_cache[k]

    def database(self, arch: str) -> InstructionDB:
        """The (registry-cached) instruction DB for ``arch``, built on
        first use."""
        return self._arch.database(arch)

    def _lookup_fn(self, arch: str) -> Callable[[Instruction], object]:
        """Memoized ``db.lookup`` keyed by (mnemonic, signature)."""
        key = self._arch.resolve(arch)
        with self._lock:
            fn = self._lookups.get(key)
            if fn is not None:
                return fn
            db = self.database(key)
            cache: dict[tuple, object] = {}

            def lookup(ins: Instruction):
                k = (ins.mnemonic, ins.signature)
                with self._lock:
                    if k in cache:
                        self.stats.lookup_hits += 1
                        return cache[k]
                    self.stats.lookup_misses += 1
                entry = db.lookup(ins)
                with self._lock:
                    cache[k] = entry
                return entry

            self._lookups[key] = lookup
            return lookup

    # ------------------------------------------------------------------
    # balanced-scheduler LP memoization
    # ------------------------------------------------------------------
    def _schedule_fn(self, model: PortModel, scheduler: str) -> Callable:
        base = SCHEDULERS[scheduler]
        if scheduler != "balanced":
            return base  # uniform is O(n); caching would only add overhead

        def cached(model_: PortModel,
                   uops: list[tuple[int, Uop]]) -> list[ScheduledUop]:
            # the LP solution is a deterministic function of the port
            # list + uop spec, so keying on both stays correct even when
            # two registered databases share a model name
            key = (model_.ports,
                   tuple((idx, u.ports, u.cycles) for idx, u in uops))
            with self._lock:
                hit = self._lp_cache.get(key)
                if hit is not None:
                    self.stats.lp_hits += 1
                    return hit
                self.stats.lp_misses += 1
            out = base(model_, uops)
            with self._lock:
                self._lp_cache[key] = out
            return out

        return cached

    # ------------------------------------------------------------------
    # prediction entry points
    # ------------------------------------------------------------------
    def _kernel_of(self, req: AnalysisRequest) -> tuple[Instruction, ...]:
        if isinstance(req.kernel, str):
            return tuple(extract_kernel(req.kernel, syntax=req.syntax))
        return tuple(req.kernel)

    @staticmethod
    def _kernel_id(req: AnalysisRequest) -> tuple:
        if isinstance(req.kernel, str):
            # raw source keys by (text, syntax): the same bytes parse
            # differently under AT&T vs Intel, and keying pre-parse also
            # skips extract_kernel entirely on a hit
            return ("src", req.kernel, req.syntax)
        # Instruction is a frozen dataclass: hashing the instances
        # themselves keys on the full parse (operand order included),
        # not just the source text, so e.g. the same reg-reg move
        # parsed under AT&T vs Intel order cannot collide
        return ("parsed", tuple(req.kernel))

    def predict(self, request: AnalysisRequest) -> AnalysisResult:
        """Run the prediction pipeline for one request, drawing every
        sub-step from the service caches.

        ``mode="analytic"``: the combined ``max(port_bound, LCD)``
        bound.  ``mode="simulate"``: the analytic pass (cached and
        shared with analytic requests) plus the cycle-level pipeline
        simulation; the returned result carries ``bound_sim`` and a
        three-way ``binding``.
        """
        if request.mode not in ("analytic", "simulate"):
            raise ValueError(f"unknown mode {request.mode!r} "
                             "(expected 'analytic' or 'simulate')")
        key = (self._arch.resolve(request.arch), self._kernel_id(request),
               request.scheduler, request.unroll_factor,
               request.latency_bound, request.mode)
        with self._lock:
            hit = self._results.get(key)
            if hit is not None:
                self.stats.result_hits += 1
                return hit
            self.stats.result_misses += 1
        if request.mode == "simulate":
            res = self._predict_simulated(request)
        else:
            kernel = self._kernel_of(request)
            db = self.database(request.arch)
            res = analyze(
                list(kernel), db, scheduler=request.scheduler,
                unroll_factor=request.unroll_factor,
                latency_bound=request.latency_bound,
                schedule_fn=self._schedule_fn(db.model, request.scheduler),
                lookup=self._lookup_fn(request.arch))
        with self._lock:
            self._results[key] = res
        return res

    def _predict_simulated(self, request: AnalysisRequest
                           ) -> AnalysisResult:
        """The ``mode="simulate"`` pipeline: analytic result (served
        from / stored in the shared cache) refined by the cycle-level
        simulator."""
        import dataclasses

        from .sim import compile_program, simulate

        analytic = self.predict(
            dataclasses.replace(request, mode="analytic"))
        # the simulation depends only on (arch, kernel) — not on the
        # scheduler / unroll / latency_bound knobs of the analytic pass —
        # so it is cached on its own key and shared across e.g. a
        # multi-scheduler sweep.  Like the result cache, there is no
        # in-flight deduplication: identical cold-cache cells submitted
        # concurrently may each simulate (correctly) — see predict_batch.
        sim_key = (self._arch.resolve(request.arch),
                   self._kernel_id(request))
        with self._lock:
            sim = self._sim_cache.get(sim_key)
        if sim is None:
            kernel = self._kernel_of(request)
            db = self.database(request.arch)
            with self._lock:
                self.stats.sim_runs += 1
            sim = simulate(compile_program(
                list(kernel), db, lookup=self._lookup_fn(request.arch)))
            with self._lock:
                self._sim_cache[sim_key] = sim
        bound_sim = sim.cycles_per_iteration
        analytic_bound = max(analytic.port_bound_cycles,
                             analytic.lcd_cycles)
        predicted = max(bound_sim, analytic.lcd_cycles)
        # three-way binding: "simulation" whenever the simulated steady
        # state materially deviates from the analytic bound — above it
        # (front-end / finite-window effects) or below it (discrete
        # dispatch beating the uniform averaging, paper Sec. III-B);
        # otherwise the analytic label still names the constraint that
        # produces the headline
        if abs(bound_sim - analytic_bound) > analytic_bound * 0.02 + 1e-9:
            binding = "simulation"
        else:
            binding = analytic.binding
        return dataclasses.replace(
            analytic, bound_sim=bound_sim, sim_result=sim,
            predicted_cycles=predicted, binding=binding)

    def predict_batch(self, requests: Sequence[AnalysisRequest],
                      parallel: bool = False) -> list[AnalysisResult]:
        """Predict every request; order of results matches the input.

        With ``parallel=True`` requests run on a thread pool — the LP
        solves and parsing release little of the GIL, so this mainly
        helps when requests interleave with I/O-bound callers.  Note
        there is no in-flight deduplication: identical cells submitted
        concurrently on a cold cache may each compute (correctly);
        the cache deduplicates sequential calls and later batches.
        """
        if not parallel or len(requests) <= 1:
            return [self.predict(r) for r in requests]
        with ThreadPoolExecutor(max_workers=self._max_workers) as ex:
            return list(ex.map(self.predict, requests))

    async def predict_async(self,
                            request: AnalysisRequest) -> AnalysisResult:
        """Awaitable ``predict`` (runs on the default executor)."""
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(None, self.predict, request)

    def sweep(self, kernels: Mapping[str, str | tuple[Instruction, ...]],
              archs: Iterable[str] = ("skl", "zen"),
              schedulers: Iterable[str] = ("uniform",),
              unroll_factors: Mapping[str, int] | None = None,
              parallel: bool = False,
              mode: str = "analytic",
              ) -> dict[tuple[str, str, str], AnalysisResult]:
        """Full grid: ``{(kernel_name, arch, scheduler): AnalysisResult}``.

        ``unroll_factors`` optionally maps kernel names to their unroll
        factor (default 1); ``mode="simulate"`` runs the whole grid
        through the cycle-level simulator backend.  This is the bulk
        entry point used by ``benchmarks/paper_tables.py``-style sweeps.
        """
        unroll_factors = unroll_factors or {}
        names, reqs = [], []
        for name, kern in kernels.items():
            for arch in archs:
                for sched in schedulers:
                    names.append((name, arch, sched))
                    reqs.append(AnalysisRequest(
                        kernel=kern, arch=arch, scheduler=sched,
                        unroll_factor=unroll_factors.get(name, 1),
                        mode=mode))
        results = self.predict_batch(reqs, parallel=parallel)
        return dict(zip(names, results))

    # ------------------------------------------------------------------
    # HLO (TPU) path
    # ------------------------------------------------------------------
    def predict_hlo(self, text: str, *, ici_links: float = 1.0,
                    flop_dtype: str = "bf16", mode: str = "analytic",
                    machine: "str | MachineModel | None" = None):
        """Memoized :func:`repro.core.hlo.analyzer.analyze_hlo`.

        Results carry the combined ``max(overlap, critical-path)`` bound
        (``HloAnalysis.terms.bound_combined``); ``mode="simulate"``
        additionally list-schedules the entry ops onto the TPU ports
        (``repro.core.sim.dag``) and fills ``terms.sim_s`` /
        ``terms.bound_sim``.  ``machine`` selects the accelerator model
        (an arch id/alias resolved through this service's registry, or a
        :class:`MachineModel` whose ``constants`` carry the hardware
        numbers; default ``"tpu_v5e"``).  The cache key is the
        module-text digest plus the machine digest, so the serving
        dry-run and roofline sweeps share one pass per compiled program.
        """
        if mode not in ("analytic", "simulate"):
            raise ValueError(f"unknown mode {mode!r} "
                             "(expected 'analytic' or 'simulate')")
        if machine is None:
            machine = "tpu_v5e"
        if isinstance(machine, str):
            machine = self._arch.model(machine)
        digest = hashlib.sha256(text.encode()).hexdigest()
        key = (digest, ici_links, flop_dtype, mode, machine.digest)
        with self._lock:
            hit = self._hlo_cache.get(key)
            if hit is not None:
                self.stats.hlo_hits += 1
                return hit
            self.stats.hlo_misses += 1
        from .hlo.analyzer import analyze_hlo
        res = analyze_hlo(text, ici_links=ici_links, flop_dtype=flop_dtype,
                          simulate=(mode == "simulate"), machine=machine)
        with self._lock:
            self._hlo_cache[key] = res
        return res

    # ------------------------------------------------------------------
    def cache_clear(self) -> None:
        """Drop every cache (databases are kept) and reset the stats."""
        with self._lock:
            self._lookups.clear()
            self._lp_cache.clear()
            self._results.clear()
            self._sim_cache.clear()
            self._hlo_cache.clear()
            self.stats = ServiceStats()


_DEFAULT: AnalysisService | None = None
_DEFAULT_LOCK = threading.Lock()


def default_service() -> AnalysisService:
    """Process-wide shared service (benchmarks, examples and the serving
    dry-run all use this one so their caches compose)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = AnalysisService()
        return _DEFAULT
