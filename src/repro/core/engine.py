"""Batched multi-architecture analysis service (the unified prediction
engine).

One :class:`AnalysisService` owns every per-architecture instruction
database and serves *batches* of kernels x architectures x schedulers
through a single memoized pipeline:

* **DB construction** — architectures resolve through an
  :class:`~repro.core.arch.registry.ArchRegistry` (a private child of
  the process-wide registry, so runtime ``register()`` calls stay
  service-local); each database is built once per registry layer and
  shared across the batch.
* **Form lookups** — ``db.lookup`` results are cached per
  ``(arch, mnemonic, signature)``; a sweep re-resolving the same triad
  kernel on three schedulers pays for the progressive-generalisation
  walk only once.
* **Balanced-scheduler LP solves** — ``schedule_balanced`` is an exact
  min-max flow LP; its result depends only on the (ordered) uop spec, so
  identical kernels across the batch reuse the solve.
* **Whole results** — ``predict()`` itself is memoized on
  ``(arch, kernel, scheduler, unroll, latency_bound)``; ``render()``
  variations, table generators and tests all hit the same entry.
* **HLO analyses** — ``predict_hlo`` caches by module-text digest, so the
  serving dry-run and the roofline benchmark share one pass per program.

Entry points: :meth:`AnalysisService.predict` (one request),
:meth:`~AnalysisService.predict_batch` (many, optionally threaded),
:meth:`~AnalysisService.predict_async` (awaitable), and
:meth:`~AnalysisService.sweep` (full kernels x archs x schedulers grid).

Every analytic prediction is the *combined* bound ``max(port_bound,
LCD)`` from :func:`repro.core.analysis.analyze`; ``mode="simulate"``
requests additionally run the cycle-level pipeline simulator
(``repro.core.sim``) and report its steady state as ``bound_sim`` —
see docs/prediction-model.md and docs/simulation.md.
"""
from __future__ import annotations

import asyncio
import hashlib
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Sequence

from .analysis import AnalysisResult, analyze
from .arch.registry import ArchRegistry, UnknownArchError, default_registry
from .database import InstructionDB
from .degrade import (BreakerBoard, BreakerConfig, HealthRouter,
                      ladder_from, validate_sims)
from .faults import (FaultAbort, FaultInjector, FaultPlan, InjectedFault,
                     ResultValidationError)
from .isa import Instruction
from .kernel import extract_kernel
from .machine import MachineModel
from .ports import PortModel, Uop
from .scheduler import SCHEDULERS, ScheduledUop


@dataclass(frozen=True)
class AnalysisRequest:
    """One cell of a batch: a kernel analyzed on one architecture.

    Attributes:
        kernel: assembly source text (markers/loop detection handled by
            :func:`repro.core.kernel.extract_kernel`) or an already-parsed
            tuple of :class:`~repro.core.isa.Instruction`.
        arch: architecture id or alias resolved through the service's
            :class:`~repro.core.arch.registry.ArchRegistry`
            (``"skl"``/``"skylake"``, ``"zen"``/``"zen1"``/``"znver1"``,
            any shipped ``arch/models/*.json`` id, or a model registered
            via :meth:`AnalysisService.register`).
        scheduler: ``"uniform"`` or ``"balanced"``.
        unroll_factor: assembly iterations per source iteration.
        latency_bound: fold the LCD bound into the prediction (default).
        syntax: ``"att"`` or ``"intel"`` when ``kernel`` is text.
        mode: ``"analytic"`` (the combined ``max(port_bound, LCD)``
            bound, default) or ``"simulate"`` (additionally run the
            cycle-level pipeline simulator, ``repro.core.sim`` — the
            result then carries ``bound_sim``/``sim_result``, and
            ``predicted_cycles`` is the simulated steady state floored
            at the LCD bound).
        working_set: total bytes the kernel streams over per repetition
            of its outer loop.  ``None`` (default) keeps the paper's
            infinite-L1 assumption.  A size, on an arch whose
            :class:`~repro.core.machine.MachineModel` carries a
            ``hierarchy`` block, composes the in-core bound with
            per-level cache/memory transfer terms into an ECM
            prediction (``AnalysisResult.bound_ecm`` /
            ``ecm_result``, see docs/ecm.md); on a hierarchy-less
            model the request behaves exactly like ``None``.
        traffic_model: ``"analytic"`` (streaming/layer-condition miss
            model, default) or ``"cachesim"`` (LRU set-associative
            cache simulation of the access streams).
    """

    kernel: str | tuple[Instruction, ...]
    arch: str = "skl"
    scheduler: str = "uniform"
    unroll_factor: int = 1
    latency_bound: bool = True
    syntax: str = "att"
    mode: str = "analytic"
    working_set: float | None = None
    traffic_model: str = "analytic"


@dataclass
class ServiceStats:
    """Cache-effectiveness counters for one :class:`AnalysisService`."""

    result_hits: int = 0
    result_misses: int = 0
    lookup_hits: int = 0
    lookup_misses: int = 0
    lp_hits: int = 0
    lp_misses: int = 0
    hlo_hits: int = 0
    hlo_misses: int = 0
    sim_runs: int = 0        # cycle-level simulations actually executed
    #                          (cache hits are counted in result_hits)
    edge_hits: int = 0       # memoized latency.dependency_edges
    edge_misses: int = 0
    program_hits: int = 0    # memoized sim.compile_program
    program_misses: int = 0
    classify_hits: int = 0   # memoized sim.pipeline._classify
    classify_misses: int = 0
    machine_hits: int = 0    # memoized machine-model resolution
    machine_misses: int = 0
    sim_group_dispatches: int = 0   # compiled batch dispatches issued by
    #                                 the sweep planner (one per
    #                                 machine-model group)
    traffic_hits: int = 0    # memoized ECM traffic predictions
    traffic_misses: int = 0
    degraded_results: int = 0   # results answered below the requested
    #                             backend (docs/robustness.md)
    journal_hits: int = 0    # machine groups replayed from a sweep
    #                          journal (zero re-dispatch on resume)
    journal_records: int = 0    # live records in the last journal used
    journal_segments: int = 0   # sealed segments in that journal
    journal_bytes: int = 0      # its on-disk footprint (bytes)
    rung_attempts: dict = field(default_factory=dict)
    #                          dispatch attempts actually paid per
    #                          ladder rung (a breaker-skipped or
    #                          router-skipped rung never counts here —
    #                          the routing-probe gate in service_bench)
    routed_groups: int = 0   # dispatch groups the HealthRouter started
    #                          below the requested rung
    probe_dispatches: int = 0   # scheduled half-open probe dispatches

    def as_dict(self) -> dict[str, int]:
        d = dict(vars(self))
        d["rung_attempts"] = dict(self.rung_attempts)
        return d

    def hit_rate(self, kind: str) -> float:
        """Hit rate in [0, 1] for one counter pair (``"result"``,
        ``"lookup"``, ``"lp"``, ``"hlo"``, ``"edge"``, ``"program"``,
        ``"classify"``, ``"machine"`` or ``"traffic"``); 0.0 when
        never exercised."""
        hits = getattr(self, f"{kind}_hits")
        misses = getattr(self, f"{kind}_misses")
        total = hits + misses
        return hits / total if total else 0.0


class AnalysisService:
    """Memoizing, thread-safe front end over the prediction pipeline.

    A single instance can be shared by benchmarks, examples, the HLO
    analyzer and the serving engine; all of them then draw from the same
    database/lookup/LP/result caches.  All public methods are safe to
    call from multiple threads (``predict_batch(parallel=True)`` does).
    """

    def __init__(self, max_workers: int = 8,
                 registry: ArchRegistry | None = None,
                 sim_backend: str = "auto",
                 faults: "FaultPlan | FaultInjector | None" = None,
                 breaker_config: BreakerConfig | None = None,
                 router: HealthRouter | None = None):
        self._lock = threading.RLock()
        # a private child of the (shared) registry: this service's
        # register() calls shadow the parent without leaking into other
        # services, while built-in model/DB caches stay shared
        self._arch = ArchRegistry(parent=registry or default_registry())
        self._lookups: dict[str, Callable[[Instruction], object]] = {}
        self._lp_cache: dict[tuple, list[ScheduledUop]] = {}
        self._results: dict[tuple, AnalysisResult] = {}
        self._sim_cache: dict[tuple, object] = {}   # SimResult by kernel
        self._hlo_cache: dict[tuple, object] = {}
        self._edge_cache: dict[tuple, tuple] = {}   # dependency edges
        self._program_cache: dict[tuple, object] = {}   # SimProgram
        self._classify_cache: dict[tuple, str] = {}
        self._machine_cache: dict[str, MachineModel] = {}
        self._traffic_cache: dict[tuple, tuple] = {}    # ECM traffic
        self._max_workers = max_workers
        #: batch-simulation driver for sweeps: "auto" | "numpy" | "jit"
        #: | "pallas" (see repro.core.sim.batch and docs/performance.md)
        self.sim_backend = sim_backend
        self.stats = ServiceStats()
        #: armed fault injector (None = disarmed: every hook is a single
        #: `is not None` test, so the no-plan instruction stream — and
        #: therefore the golden tables — is bit-identical to before the
        #: fault layer existed; docs/robustness.md)
        self.faults: FaultInjector | None = None
        if isinstance(faults, FaultPlan):
            self.faults = FaultInjector(faults)
        elif faults is not None:
            self.faults = faults
        #: per-(machine digest x backend) circuit breakers driving the
        #: degradation ladder pallas -> jit -> numpy -> analytic-only
        self.breakers = BreakerBoard(breaker_config)
        #: breaker-aware routing policy (None = reactive-only PR 9
        #: behavior, bit-identical: the ladder still demotes on
        #: failure but never skips a rung pre-dispatch)
        self.router = router
        # provenance for sims produced below the requested rung or via
        # a routed/probe dispatch: sim_key -> (backend_used, degraded,
        # fault event id, routed_from, probe)
        self._sim_provenance: dict[tuple, tuple[str, bool, int, str,
                                                bool]] = {}
        # registry epoch at the last cache fill: a replacing
        # registration anywhere in the layer chain bumps it, and
        # _check_epoch() then drops every arch-keyed cache
        self._arch_epoch = self._arch.epoch

    # ------------------------------------------------------------------
    # architectures
    # ------------------------------------------------------------------
    @property
    def registry(self) -> ArchRegistry:
        """This service's architecture registry (a private child of the
        process-wide :func:`repro.core.arch.registry.default_registry`)."""
        return self._arch

    def register(self, model: MachineModel, *,
                 aliases: Sequence[str] | None = None,
                 replace: bool = True) -> str:
        """Register a :class:`MachineModel` with this service.

        The model's id (and aliases) become valid ``AnalysisRequest.arch``
        values for this service only.  Re-registering an id — including
        shadowing a built-in like ``"skl"`` — drops every cached lookup
        and result for it, so subsequent predictions use the new model.
        An ``arch_id`` that is an *alias spelling* of an existing id
        (``"skylake"``) shadows the canonical id (``"skl"``) rather than
        splitting the alias from it.  Returns the canonical id.
        """
        try:
            canonical = self._arch.resolve(model.arch_id)
        except UnknownArchError:
            canonical = model.arch_id
        if canonical != model.arch_id:
            model = model.derive(canonical, aliases=model.aliases)
        key = self._arch.register(model, aliases=aliases, replace=replace)
        self._invalidate_arch(key)
        return key

    def register_db(self, name: str, db: InstructionDB) -> None:
        """Deprecated: wrap ``db`` in a :class:`MachineModel` and call
        :meth:`register` instead.  This shim does exactly that (via
        :meth:`MachineModel.from_db`) and keeps the old semantics:
        re-registering a name (or an alias spelling of it) shadows the
        built-in and drops its cached results."""
        warnings.warn(
            "AnalysisService.register_db is deprecated; use "
            "register(MachineModel.from_db(...)) or register a "
            "MachineModel directly", DeprecationWarning, stacklevel=2)
        try:
            key = self._arch.resolve(name)
        except UnknownArchError:
            key = name.lower()
        self.register(MachineModel.from_db(key, db))
        # keep the caller's exact database object (old register_db
        # semantics), not a rebuild from the extracted form table
        self._arch.prime_database(key, db)

    def _invalidate_arch(self, key: str) -> None:
        with self._lock:
            self._lookups.pop(key, None)
            # alias spellings may map to the re-registered id, so the
            # (cheap to refill) resolution cache is dropped wholesale
            self._machine_cache.clear()
            for k in [k for k in self._results if k[0] == key]:
                del self._results[k]
            for k in [k for k in self._sim_cache if k[0] == key]:
                del self._sim_cache[k]
            for k in [k for k in self._sim_provenance if k[0] == key]:
                del self._sim_provenance[k]
            # edge/program/classify caches are keyed by machine *digest*
            # (content addresses), so entries for a replaced model can
            # never be served for the new one — no invalidation needed

    def _check_epoch(self) -> None:
        """Drop arch-keyed caches if any registry layer re-registered a
        model since the last fill.

        Runs at every public prediction entry; the common case is one
        integer compare.  Digest-keyed caches (edges, programs, traffic)
        survive — a superseded model's digest can never be resolved
        again, so those entries are unreachable rather than stale."""
        ep = self._arch.epoch
        if ep == self._arch_epoch:
            return
        with self._lock:
            if ep == self._arch_epoch:
                return
            self._arch_epoch = ep
            self._lookups.clear()
            self._machine_cache.clear()
            self._results.clear()
            self._sim_cache.clear()
            self._sim_provenance.clear()
            self._hlo_cache.clear()

    def database(self, arch: str) -> InstructionDB:
        """The (registry-cached) instruction DB for ``arch``, built on
        first use."""
        return self._arch.database(arch)

    def resolve_machine(self, machine: "str | MachineModel",
                        ) -> MachineModel:
        """Memoized machine-model resolution (id/alias →
        :class:`MachineModel`).

        ``predict_hlo``, the sweep planner and
        ``ServingEngine.dryrun_estimate`` all route through this, so a
        sweep resolves each model once instead of per call; hit/miss
        counts land in ``stats.machine_hits`` / ``machine_misses``.
        """
        if isinstance(machine, MachineModel):
            return machine
        with self._lock:
            hit = self._machine_cache.get(machine)
            if hit is not None:
                self.stats.machine_hits += 1
                return hit
            self.stats.machine_misses += 1
        model = self._arch.model(machine)
        with self._lock:
            self._machine_cache[machine] = model
        return model

    def _lookup_fn(self, arch: str) -> Callable[[Instruction], object]:
        """Memoized ``db.lookup`` keyed by (mnemonic, signature)."""
        key = self._arch.resolve(arch)
        with self._lock:
            fn = self._lookups.get(key)
            if fn is not None:
                return fn
            db = self.database(key)
            cache: dict[tuple, object] = {}

            def lookup(ins: Instruction):
                k = (ins.mnemonic, ins.signature)
                with self._lock:
                    if k in cache:
                        self.stats.lookup_hits += 1
                        return cache[k]
                    self.stats.lookup_misses += 1
                entry = db.lookup(ins)
                with self._lock:
                    cache[k] = entry
                return entry

            self._lookups[key] = lookup
            return lookup

    # ------------------------------------------------------------------
    # balanced-scheduler LP memoization
    # ------------------------------------------------------------------
    def _schedule_fn(self, model: PortModel, scheduler: str) -> Callable:
        base = SCHEDULERS[scheduler]
        if scheduler != "balanced":
            return base  # uniform is O(n); caching would only add overhead

        def cached(model_: PortModel,
                   uops: list[tuple[int, Uop]]) -> list[ScheduledUop]:
            # the LP solution is a deterministic function of the port
            # list + uop spec, so keying on both stays correct even when
            # two registered databases share a model name
            key = (model_.ports,
                   tuple((idx, u.ports, u.cycles) for idx, u in uops))
            with self._lock:
                hit = self._lp_cache.get(key)
                if hit is not None:
                    self.stats.lp_hits += 1
                    return hit
                self.stats.lp_misses += 1
            out = base(model_, uops)
            with self._lock:
                self._lp_cache[key] = out
            return out

        return cached

    # ------------------------------------------------------------------
    # memoized per-uop preprocessing (shared by the single-request path
    # and the sweep planner; keys are (machine digest, kernel id) /
    # (machine digest, program digest) content addresses)
    # ------------------------------------------------------------------
    def dependency_edges(self, kernel: "str | tuple[Instruction, ...]",
                         arch: str = "skl", syntax: str = "att",
                         ) -> tuple[tuple[int, int, float, bool], ...]:
        """Memoized :func:`repro.core.latency.dependency_edges`.

        The edge list depends only on the kernel text and the machine
        model, so sweeps re-analyzing one kernel across schedulers,
        unrolls or modes pay for the read/write scan once;
        ``stats.edge_hits`` / ``edge_misses`` track effectiveness.
        """
        machine = self.resolve_machine(arch)
        req = AnalysisRequest(kernel=kernel, arch=arch, syntax=syntax)
        key = (machine.digest, self._kernel_id(req))
        with self._lock:
            hit = self._edge_cache.get(key)
            if hit is not None:
                self.stats.edge_hits += 1
                return hit
            self.stats.edge_misses += 1
        from .latency import dependency_edges as _edges
        out = tuple(_edges(list(self._kernel_of(req)),
                           self.database(arch),
                           lookup=self._lookup_fn(arch)))
        with self._lock:
            self._edge_cache[key] = out
        return out

    def _sim_program(self, request: AnalysisRequest):
        """Memoized ``sim.compile_program`` for one request, built on
        the memoized dependency edges."""
        machine = self.resolve_machine(request.arch)
        key = (machine.digest, self._kernel_id(request))
        with self._lock:
            hit = self._program_cache.get(key)
            if hit is not None:
                self.stats.program_hits += 1
                return hit
            self.stats.program_misses += 1
        if self.faults is not None:
            # armed compile faults hit real compilation work only —
            # a program-cache hit above never fires
            self.faults.fire("engine.compile", machine=machine.digest)
        from .sim import compile_program
        edges = self.dependency_edges(request.kernel, request.arch,
                                      request.syntax)
        prog = compile_program(
            list(self._kernel_of(request)), self.database(request.arch),
            lookup=self._lookup_fn(request.arch), edges=edges)
        with self._lock:
            self._program_cache[key] = prog
        return prog

    def _classify_memo(self, cpi: float, frontend: float,
                       port_bound: float, delivery: float = 0.0,
                       fe_mode: str = "ideal") -> str:
        """Memoized ``sim.pipeline._classify``: the bottleneck label is
        a pure function of (steady state, front-end bounds, port
        bound), so identical programs re-simulated across sweep
        dispatches reuse the verdict; the planner passes this as the
        batch driver's ``classify`` hook."""
        from .sim.pipeline import _classify

        key = (cpi, frontend, port_bound, delivery, fe_mode)
        with self._lock:
            hit = self._classify_cache.get(key)
            if hit is not None:
                self.stats.classify_hits += 1
                return hit
            self.stats.classify_misses += 1
        label = _classify(cpi, frontend, port_bound, delivery, fe_mode)
        with self._lock:
            self._classify_cache[key] = label
        return label

    # ------------------------------------------------------------------
    # prediction entry points
    # ------------------------------------------------------------------
    def _kernel_of(self, req: AnalysisRequest) -> tuple[Instruction, ...]:
        if isinstance(req.kernel, str):
            return tuple(extract_kernel(req.kernel, syntax=req.syntax))
        return tuple(req.kernel)

    @staticmethod
    def _kernel_id(req: AnalysisRequest) -> tuple:
        if isinstance(req.kernel, str):
            # raw source keys by (text, syntax): the same bytes parse
            # differently under AT&T vs Intel, and keying pre-parse also
            # skips extract_kernel entirely on a hit
            return ("src", req.kernel, req.syntax)
        # Instruction is a frozen dataclass: hashing the instances
        # themselves keys on the full parse (operand order included),
        # not just the source text, so e.g. the same reg-reg move
        # parsed under AT&T vs Intel order cannot collide
        return ("parsed", tuple(req.kernel))

    def predict(self, request: AnalysisRequest) -> AnalysisResult:
        """Run the prediction pipeline for one request, drawing every
        sub-step from the service caches.

        ``mode="analytic"``: the combined ``max(port_bound, LCD)``
        bound.  ``mode="simulate"``: the analytic pass (cached and
        shared with analytic requests) plus the cycle-level pipeline
        simulation; the returned result carries ``bound_sim`` and a
        three-way ``binding``.
        """
        self._check_epoch()
        key = self._result_key(request)
        with self._lock:
            hit = self._results.get(key)
            if hit is not None:
                self.stats.result_hits += 1
                return hit
            self.stats.result_misses += 1
        if request.mode == "simulate":
            res = self._predict_simulated(request)
        else:
            res = self._compute_analytic(request)
        res = self._apply_ecm(res, request)
        with self._lock:
            self._results[key] = res
        return res

    def request_key(self, request: AnalysisRequest) -> tuple:
        """Public content-address of one request.

        Like the internal result key but keyed by the *machine digest*
        instead of the arch id, so it stays valid across registries and
        can be shared by out-of-process caches
        (``repro.service.PredictionService`` keys its cross-request
        TTL cache on this).
        """
        machine = self.resolve_machine(request.arch)
        key = self._result_key(request)
        return (machine.digest,) + key[1:]

    def _result_key(self, request: AnalysisRequest) -> tuple:
        if request.mode not in ("analytic", "simulate"):
            raise ValueError(f"unknown mode {request.mode!r} "
                             "(expected 'analytic' or 'simulate')")
        if request.traffic_model not in ("analytic", "cachesim"):
            raise ValueError(f"unknown traffic_model "
                             f"{request.traffic_model!r} "
                             "(expected 'analytic' or 'cachesim')")
        if request.working_set is not None and request.working_set <= 0:
            raise ValueError("working_set must be positive (bytes) or "
                             "None")
        return (self._arch.resolve(request.arch),
                self._kernel_id(request), request.scheduler,
                request.unroll_factor, request.latency_bound,
                request.mode, request.working_set, request.traffic_model)

    def _compute_analytic(self, request: AnalysisRequest
                          ) -> AnalysisResult:
        """The uncached analytic pipeline for one request (all
        sub-steps still draw from the service caches)."""
        kernel = self._kernel_of(request)
        db = self.database(request.arch)
        edges = None
        if request.latency_bound:
            edges = list(self.dependency_edges(
                request.kernel, request.arch, request.syntax))
        return analyze(
            list(kernel), db, scheduler=request.scheduler,
            unroll_factor=request.unroll_factor,
            latency_bound=request.latency_bound,
            schedule_fn=self._schedule_fn(db.model, request.scheduler),
            lookup=self._lookup_fn(request.arch), edges=edges)

    def _predict_simulated(self, request: AnalysisRequest
                           ) -> AnalysisResult:
        """The ``mode="simulate"`` pipeline: analytic result (served
        from / stored in the shared cache) refined by the cycle-level
        simulator.

        The tick-loop driver is its own single-rung ladder: a failed
        compile or simulation (injected or real) is contained and the
        cell degrades to the analytic floor with ``degraded``
        provenance rather than failing the request — the analytic and
        simulated predictors are redundant estimates of the same
        quantity (docs/robustness.md)."""
        import dataclasses

        from .sim import simulate

        analytic = self.predict(
            dataclasses.replace(request, mode="analytic"))
        # the simulation depends only on (arch, kernel) — not on the
        # scheduler / unroll / latency_bound knobs of the analytic pass —
        # so it is cached on its own key and shared across e.g. a
        # multi-scheduler sweep.  Like the result cache, there is no
        # in-flight deduplication: identical cold-cache cells submitted
        # concurrently may each simulate (correctly) — see predict_batch.
        sim_key = (self._arch.resolve(request.arch),
                   self._kernel_id(request))
        with self._lock:
            sim = self._sim_cache.get(sim_key)
        if sim is None:
            machine = self.resolve_machine(request.arch)
            breaker = self.breakers.breaker(machine.digest, "tick")
            probe = False
            if self.router is not None:
                # tick is its own single-rung ladder: an unhealthy rung
                # routes straight to the analytic floor with no dispatch
                route = self.router.plan(self.breakers, machine.digest,
                                         ("tick",))
                probe = route.probe
                if not route.rungs:
                    with self._lock:
                        self.stats.degraded_results += 1
                    return self._analytic_floor(analytic, 0)
                if probe:
                    with self._lock:
                        self.stats.probe_dispatches += 1
            event_id = 0
            try:
                prog = self._sim_program(request)
                if not breaker.allow():
                    raise ResultValidationError(
                        "tick-rung breaker open for "
                        f"{machine.digest[:12]}")
                if self.faults is not None:
                    self.faults.fire("engine.dispatch", backend="tick",
                                     machine=machine.digest)
                with self._lock:
                    self.stats.sim_runs += 1
                    self.stats.rung_attempts["tick"] = \
                        self.stats.rung_attempts.get("tick", 0) + 1
                sim = simulate(prog)
                if self.faults is not None:
                    cpi, ev = self.faults.corrupt(
                        "engine.dispatch", sim.cycles_per_iteration,
                        backend="tick", machine=machine.digest)
                    if ev:
                        sim = dataclasses.replace(
                            sim, cycles_per_iteration=cpi)
                problems = validate_sims([sim], [prog])
                if problems:
                    raise ResultValidationError("; ".join(problems))
                breaker.record_success()
                with self._lock:
                    self._sim_cache[sim_key] = sim
                    if probe:
                        self._sim_provenance[sim_key] = (
                            "tick", False, 0, "", True)
            except FaultAbort:
                raise               # simulated process kill: never contained
            except ValueError:
                raise               # bad request, not a backend fault
            except Exception as exc:
                breaker.record_failure()
                event_id = getattr(exc, "event_id", 0)
                with self._lock:
                    self.stats.degraded_results += 1
                return self._analytic_floor(analytic, event_id)
        res = self._combine_sim(analytic, sim)
        with self._lock:
            prov = self._sim_provenance.get(sim_key)
        if prov is not None:
            if prov[1]:
                res = dataclasses.replace(
                    res, degraded=True, backend_used=prov[0],
                    fault_trace_id=prov[2])
            if prov[3] or prov[4]:
                res = dataclasses.replace(
                    res, routed_from=prov[3], probe=prov[4])
        return res

    @staticmethod
    def _analytic_floor(analytic: AnalysisResult,
                        event_id: int) -> AnalysisResult:
        """The bottom ladder rung: answer a ``mode="simulate"`` request
        with its (already computed) analytic base, flagged ``degraded``.

        Any ECM composition the base carries is stripped the same way
        :meth:`_combine_sim` does — ``predict``/``predict_batch``
        re-apply it afterwards, so the floor result equals the plain
        analytic prediction bit-for-bit."""
        import dataclasses

        if analytic.ecm_result is None:
            return dataclasses.replace(
                analytic, degraded=True, backend_used="analytic",
                fault_trace_id=event_id)
        # same binding rule as analyze(): the pre-ECM label
        binding = ("latency" if analytic.lcd_cycles
                   > analytic.port_bound_cycles + 1e-9 else "throughput")
        return dataclasses.replace(
            analytic,
            predicted_cycles=max(analytic.port_bound_cycles,
                                 analytic.lcd_cycles),
            binding=binding, bound_ecm=0.0, ecm_result=None,
            degraded=True, backend_used="analytic",
            fault_trace_id=event_id)

    def _run_ladder(self, digest: str, progs: list, start: str,
                    small: bool) -> tuple:
        """Dispatch one machine group down the degradation ladder.

        Walks the sim rungs from ``start`` (``("tick",)`` for the
        small-batch reference loop), skipping rungs whose circuit
        breaker is open, validating every rung's output, and demoting
        on any contained failure.  When a :class:`HealthRouter` is
        installed it is consulted *before* the walk: rungs with an
        open breaker are dropped without paying a dispatch and at
        most one scheduled probe per cooldown window reaches a rung
        that is due one.  Returns ``(sims | None, backend_used,
        degraded, dispatches, fault event id, routed_from, probe)`` —
        ``sims is None`` means every rung failed and the group takes
        the analytic floor.  :class:`FaultAbort` (a simulated process
        kill) and ``ValueError`` (a deterministic bad request) are
        never contained."""
        import dataclasses

        from .sim import simulate, simulate_many

        rungs = ("tick",) if small else ladder_from(start)
        routed_from, probe = "", False
        if self.router is not None:
            route = self.router.plan(self.breakers, digest, rungs)
            rungs = route.rungs
            routed_from, probe = route.routed_from, route.probe
            with self._lock:
                if routed_from:
                    self.stats.routed_groups += 1
                if probe:
                    self.stats.probe_dispatches += 1
        # a dispatch answered below the rung the caller asked for is
        # degraded provenance, whether the skip happened reactively
        # (breaker.allow() refused) or proactively (router)
        demoted = bool(routed_from)
        event_id = 0
        for rung in rungs:
            # only the first routed rung can be the scheduled probe; if
            # it does not answer, whatever answers below is not one
            if rung != rungs[0]:
                probe = False
            breaker = self.breakers.breaker(digest, rung)
            if not breaker.allow():
                demoted = True
                continue
            with self._lock:
                self.stats.rung_attempts[rung] = \
                    self.stats.rung_attempts.get(rung, 0) + 1
            try:
                if self.faults is not None:
                    self.faults.fire("engine.dispatch", backend=rung,
                                     machine=digest)
                counters = {"dispatches": 0}
                if rung == "tick":
                    sims = [simulate(p) for p in progs]
                else:
                    sims = simulate_many(progs, backend=rung,
                                         classify=self._classify_memo,
                                         counters=counters)
                if self.faults is not None:
                    poisoned = []
                    for sim in sims:
                        cpi, ev = self.faults.corrupt(
                            "engine.dispatch", sim.cycles_per_iteration,
                            backend=rung, machine=digest)
                        if ev:
                            event_id = ev
                            sim = dataclasses.replace(
                                sim, cycles_per_iteration=cpi)
                        poisoned.append(sim)
                    sims = poisoned
                problems = validate_sims(sims, progs)
                if problems:
                    raise ResultValidationError("; ".join(problems))
                breaker.record_success()
                return (sims, rung, demoted, counters["dispatches"],
                        event_id, routed_from, probe)
            except FaultAbort:
                raise
            except ValueError:
                raise
            except Exception as exc:
                breaker.record_failure()
                event_id = getattr(exc, "event_id", event_id)
                demoted = True
                continue
        # the floor answered: nothing dispatched, so no probe either
        return None, "analytic", True, 0, event_id, routed_from, False

    @staticmethod
    def _journal_lookup(session: dict | None, digest: str,
                        progs: list) -> tuple | None:
        """Replay one machine group from a sweep-journal session
        (``sweep(resume_from=...)``); None when the group is not
        journaled.  Returns ``(sims | None, backend_used, degraded,
        event id)`` — the same shape the ladder produces, so a resumed
        sweep is bit-identical with zero re-dispatch."""
        if session is None or not session.get("resume"):
            return None
        record = session["resume"].get(
            (digest, tuple(p.digest for p in progs)))
        if record is None:
            return None
        from .journal import sim_from_record
        from .sim.pipeline import DEFAULT_PARAMS
        if record["sims"] is None:
            sims = None
        else:
            sims = [sim_from_record(sr, p.model.pipeline or DEFAULT_PARAMS)
                    for sr, p in zip(record["sims"], progs)]
        return sims, record["backend_used"], record["degraded"], 0

    @staticmethod
    def _journal_record(session: dict | None, digest: str, progs: list,
                        sims, backend_used: str, degraded: bool) -> None:
        if session is None or session.get("writer") is None:
            return
        session["writer"].record_group(
            session["plan"], digest, [p.digest for p in progs],
            sims, backend_used, degraded)

    @staticmethod
    def _combine_sim(analytic: AnalysisResult, sim) -> AnalysisResult:
        """Fold a cycle-level simulation into an analytic result (the
        ``mode="simulate"`` combination rule, shared by the single
        path and the sweep planner)."""
        import dataclasses

        bound_sim = sim.cycles_per_iteration
        analytic_bound = max(analytic.port_bound_cycles,
                             analytic.lcd_cycles)
        predicted = max(bound_sim, analytic.lcd_cycles)
        # three-way binding: "simulation" whenever the simulated steady
        # state materially deviates from the analytic bound — above it
        # (front-end / finite-window effects) or below it (discrete
        # dispatch beating the uniform averaging, paper Sec. III-B);
        # otherwise the analytic label still names the constraint that
        # produces the headline
        if abs(bound_sim - analytic_bound) > analytic_bound * 0.02 + 1e-9:
            binding = "simulation"
        else:
            binding = analytic.binding
        # the analytic base may itself carry an ECM composition (its
        # cache key includes working_set); the combined result is a pure
        # in-core bound again — predict()/predict_batch re-apply ECM on
        # top of the simulated bound afterwards
        return dataclasses.replace(
            analytic, bound_sim=bound_sim, sim_result=sim,
            predicted_cycles=predicted, binding=binding,
            bound_ecm=0.0, ecm_result=None)

    # ------------------------------------------------------------------
    # ECM memory-hierarchy composition (working_set= requests)
    # ------------------------------------------------------------------
    def _traffic(self, request: AnalysisRequest, machine: MachineModel):
        """Memoized per-level traffic + T_nOL for one (machine, kernel,
        working_set, traffic_model) — the sim cache's sibling: its key
        excludes scheduler/unroll/mode, so an ECM sweep across those
        knobs predicts traffic once per working set."""
        key = (machine.digest, self._kernel_id(request),
               float(request.working_set), request.traffic_model)
        with self._lock:
            hit = self._traffic_cache.get(key)
            if hit is not None:
                self.stats.traffic_hits += 1
                return hit
            self.stats.traffic_misses += 1
        if self.faults is not None:
            self.faults.fire("engine.traffic", machine=machine.digest,
                             traffic_model=request.traffic_model)
        from .mem import (extract_streams, memory_port_occupation,
                          predict_traffic, simulate_traffic)
        kernel = self._kernel_of(request)
        streams = extract_streams(kernel)
        estimator = simulate_traffic if request.traffic_model == \
            "cachesim" else predict_traffic
        traffic = estimator(streams, machine.hierarchy,
                            float(request.working_set))
        lookup = self._lookup_fn(request.arch)
        entries = [lookup(ins) for ins in kernel]
        t_nol = memory_port_occupation(
            self.database(request.arch).model, entries)
        out = (traffic, t_nol)
        with self._lock:
            self._traffic_cache[key] = out
        return out

    def _apply_ecm(self, res: AnalysisResult,
                   request: AnalysisRequest) -> AnalysisResult:
        """Compose the in-core result with the memory-hierarchy terms.

        No-op when the request has no ``working_set`` or the machine
        has no ``hierarchy`` block — the existing bounds pass through
        bit-exactly (the documented compatibility guarantee).
        """
        if request.working_set is None:
            return res
        machine = self.resolve_machine(request.arch)
        if machine.hierarchy is None:
            return res
        import dataclasses

        from .mem import compose_ecm

        try:
            traffic, t_nol = self._traffic(request, machine)
        except FaultAbort:
            raise
        except InjectedFault as exc:
            # contained: the in-core bound stands, flagged degraded —
            # the memory-hierarchy terms are a refinement, not a
            # prerequisite (docs/robustness.md)
            with self._lock:
                self.stats.degraded_results += 1
            return dataclasses.replace(
                res, degraded=True,
                backend_used=res.backend_used or "incore",
                fault_trace_id=exc.event_id)
        # T_nOL is by definition part of the in-core time: the uniform
        # split of the memory uops alone can exceed the balanced overall
        # bottleneck on asymmetric port sets, so clamp — this also makes
        # working_set <= L1 reproduce the in-core bound bit-exactly.
        if res.port_bound_cycles > 0:
            t_nol = min(t_nol, res.port_bound_cycles)
        ecm = compose_ecm(t_incore=res.predicted_cycles, t_nol=t_nol,
                          traffic=traffic)
        binding = "memory" if ecm.cycles > res.predicted_cycles + 1e-9 \
            else res.binding
        return dataclasses.replace(
            res, bound_ecm=ecm.cycles, ecm_result=ecm,
            predicted_cycles=ecm.cycles, binding=binding)

    def predict_batch(self, requests: Sequence[AnalysisRequest],
                      parallel: bool = False,
                      backend: str | None = None,
                      _journal: dict | None = None) -> list[AnalysisResult]:
        """Predict every request; order of results matches the input.

        Batches run through a three-stage planner instead of a
        loop-over-requests:

        1. **plan** — every request resolves to its result-cache key;
           duplicates collapse to one cell, cached cells are served
           immediately.
        2. **analytic pass** — the unique analytic cells (including the
           analytic base of every ``mode="simulate"`` cell) compute
           once each, drawing parses/lookups/LP solves from the
           memoized sub-steps (``parallel=True`` spreads them over a
           thread pool).
        3. **grouped simulation** — the ``mode="simulate"`` cells that
           miss the simulation cache compile to :class:`SimProgram`\\ s
           (memoized by (machine digest, kernel)) and dispatch as *one*
           vectorized :func:`repro.core.sim.simulate_many` call per
           machine-model group (``stats.sim_group_dispatches``), on
           ``backend`` (default: the service's ``sim_backend``;
           ``"auto"`` compiles with ``jax.jit`` for large groups, see
           docs/performance.md).  A 1k-point sweep is a handful of
           compiled dispatches, not 1k tick-loop runs.

        The batch path and the single-request :meth:`predict` share all
        caches; for ``mode="simulate"`` they run different drivers of
        the same machine (vectorized dataflow recurrence vs reference
        tick loop), so whichever computes a cell first fills the cache
        for both (the drivers' agreement on the paper kernels is locked
        by ``tests/test_simulator.py`` / ``tests/test_sweep_engine.py``).

        Each machine group's dispatch walks the degradation ladder
        (requested rung, then every cheaper one whose circuit breaker
        admits it, then the analytic floor) — see docs/robustness.md;
        ``_journal`` is the private sweep-journal session plumbed
        through :meth:`sweep` for crash-safe resume.
        """
        self._check_epoch()
        if len(requests) <= 1:
            return [self.predict(r) for r in requests]

        # ---- plan: dedupe on result keys -----------------------------
        keys = [self._result_key(r) for r in requests]
        unique: dict[tuple, AnalysisRequest] = {}
        for key, req in zip(keys, requests):
            unique.setdefault(key, req)
        with self._lock:
            done = {k: self._results[k] for k in unique
                    if k in self._results}
        todo = {k: r for k, r in unique.items() if k not in done}
        with self._lock:
            self.stats.result_hits += len(requests) - len(todo)

        # ---- analytic pass (also the base of every simulate cell) ----
        analytic_reqs: dict[tuple, AnalysisRequest] = {}
        for key, req in todo.items():
            if req.mode == "simulate":
                import dataclasses
                base = dataclasses.replace(req, mode="analytic")
                analytic_reqs[self._result_key(base)] = base
            else:
                analytic_reqs[key] = req
        with self._lock:
            analytic_todo = {k: r for k, r in analytic_reqs.items()
                             if k not in self._results}
            # stats mirror the sequential path: each uncached cell is
            # one miss — including the analytic base a simulate cell
            # computes implicitly — everything else a hit
            self.stats.result_misses += len(todo) + sum(
                1 for k in analytic_todo if k not in todo)
        if parallel and len(analytic_todo) > 1:
            with ThreadPoolExecutor(max_workers=self._max_workers) as ex:
                computed = list(ex.map(self._compute_analytic,
                                       analytic_todo.values()))
        else:
            computed = [self._compute_analytic(r)
                        for r in analytic_todo.values()]
        computed = [self._apply_ecm(res, r)
                    for res, r in zip(computed, analytic_todo.values())]
        with self._lock:
            for k, res in zip(analytic_todo, computed):
                self._results.setdefault(k, res)

        # ---- grouped simulation dispatch -----------------------------
        sim_cells = {k: r for k, r in todo.items()
                     if r.mode == "simulate"}
        if sim_cells:
            sim_keys = {k: (self._arch.resolve(r.arch),
                            self._kernel_id(r))
                        for k, r in sim_cells.items()}
            # sim_key -> fault event id for cells the ladder bottomed
            # out on (compile fault or every sim rung exhausted): they
            # get the analytic floor in the combine loop below
            floor_cells: dict[tuple, int] = {}
            # sim_key -> (routed_from, probe) for floor cells the
            # router sent straight to the floor (every rung unhealthy)
            floor_route: dict[tuple, tuple[str, bool]] = {}
            with self._lock:
                missing = {sk: r for k, r in sim_cells.items()
                           if (sk := sim_keys[k]) not in self._sim_cache}
            if missing:
                from .sim import AUTO_JIT_MIN_BATCH
                from .sim.batch import _resolve_backend
                chosen = backend or self.sim_backend
                # compile per request, containing injected compile
                # faults per cell (a cell whose program cannot compile
                # degrades alone; the rest of its group still simulates)
                compiled: dict[tuple, tuple[str, object]] = {}
                for sk, r in missing.items():
                    machine = self.resolve_machine(r.arch)
                    try:
                        compiled[sk] = (machine.digest,
                                        self._sim_program(r))
                    except FaultAbort:
                        raise
                    except InjectedFault as exc:
                        floor_cells[sk] = exc.event_id
                        with self._lock:
                            self.stats.degraded_results += 1
                # the small-batch tick-loop decision and the "auto"
                # rung both resolve on the *total* missing count, as
                # the single simulate_many call they replace did
                small = (chosen == "auto"
                         and len(compiled) < AUTO_JIT_MIN_BATCH)
                start = chosen if chosen != "auto" else \
                    _resolve_backend("auto", len(compiled))
                groups: dict[str, list[tuple]] = {}
                for sk, (digest, _prog) in compiled.items():
                    groups.setdefault(digest, []).append(sk)
                for digest, sks in groups.items():
                    progs = [compiled[sk][1] for sk in sks]
                    replay = self._journal_lookup(_journal, digest, progs)
                    if replay is not None:
                        sims, backend_used, degraded, event_id = replay
                        dispatches = 0
                        routed_from, probe = "", False
                        with self._lock:
                            self.stats.journal_hits += 1
                    else:
                        sims, backend_used, degraded, dispatches, \
                            event_id, routed_from, probe = \
                            self._run_ladder(digest, progs, start, small)
                        self._journal_record(_journal, digest, progs,
                                             sims, backend_used, degraded)
                    with self._lock:
                        if sims is None:
                            # every sim rung failed or was breaker-open:
                            # the whole group takes the analytic floor
                            self.stats.degraded_results += len(sks)
                            for sk in sks:
                                floor_cells.setdefault(sk, event_id)
                                if routed_from:
                                    floor_route[sk] = (routed_from, False)
                            continue
                        if replay is None:
                            self.stats.sim_runs += len(progs)
                            self.stats.sim_group_dispatches += dispatches
                        for sk, sim in zip(sks, sims):
                            self._sim_cache.setdefault(sk, sim)
                        if degraded:
                            self.stats.degraded_results += len(sks)
                        if degraded or routed_from or probe:
                            for sk in sks:
                                self._sim_provenance[sk] = (
                                    backend_used, degraded, event_id,
                                    routed_from, probe)
            # combine analytic base + simulation per cell
            import dataclasses
            for k, req in sim_cells.items():
                base_key = self._result_key(
                    dataclasses.replace(req, mode="analytic"))
                with self._lock:
                    analytic = self._results.get(base_key)
                    sim = self._sim_cache.get(sim_keys[k])
                    prov = self._sim_provenance.get(sim_keys[k])
                if analytic is not None and sim is None \
                        and sim_keys[k] in floor_cells:
                    res = self._apply_ecm(
                        self._analytic_floor(analytic,
                                             floor_cells[sim_keys[k]]),
                        req)
                    fr = floor_route.get(sim_keys[k])
                    if fr is not None:
                        res = dataclasses.replace(
                            res, routed_from=fr[0], probe=fr[1])
                elif analytic is None or sim is None:
                    # a concurrent register()/cache_clear() dropped the
                    # cell mid-batch: recompute through the (race-free)
                    # single-request path
                    res = self.predict(req)
                else:
                    res = self._apply_ecm(self._combine_sim(analytic, sim),
                                          req)
                    if prov is not None:
                        if prov[1]:
                            res = dataclasses.replace(
                                res, degraded=True, backend_used=prov[0],
                                fault_trace_id=prov[2])
                        if prov[3] or prov[4]:
                            res = dataclasses.replace(
                                res, routed_from=prov[3], probe=prov[4])
                with self._lock:
                    self._results.setdefault(k, res)

        out = []
        for key, req in zip(keys, requests):
            with self._lock:
                res = self._results.get(key)
            # concurrent invalidation between fill and gather: recompute
            out.append(res if res is not None else self.predict(req))
        return out

    async def predict_async(self, request: AnalysisRequest, *,
                            timeout: float | None = None,
                            retries: int = 0,
                            backoff_s: float = 0.05) -> AnalysisResult:
        """Awaitable ``predict`` (runs on the default executor), with
        graceful-degradation semantics for long-lived callers:

        * ``timeout`` — seconds per attempt; a dispatch that exceeds it
          raises :class:`asyncio.TimeoutError` to the caller instead of
          hanging it (the abandoned executor thread finishes in the
          background and still fills the result cache).
        * ``retries`` — extra attempts after a timeout *or* an engine
          exception, with exponential backoff starting at
          ``backoff_s`` (doubled per retry).  Invalid-request errors
          (``ValueError``) are never retried — they are deterministic.
        * **Cancellation**: cancelling the awaiting task propagates
          :class:`asyncio.CancelledError` immediately (no retry).  An
          in-flight executor call cannot be interrupted mid-compute;
          it completes in the background and populates the caches, so
          a re-submit of the same request is a cache hit.
        """
        loop = asyncio.get_running_loop()
        delay = backoff_s
        for attempt in range(1 + max(0, retries)):
            try:
                fut = loop.run_in_executor(None, self.predict, request)
                if timeout is None:
                    return await fut
                return await asyncio.wait_for(fut, timeout)
            except (asyncio.CancelledError, ValueError):
                raise
            except Exception:      # timeout or transient engine error
                if attempt >= retries:
                    raise
                await asyncio.sleep(delay)
                delay *= 2
        raise RuntimeError("unreachable")    # pragma: no cover

    def sweep(self, kernels: Mapping[str, str | tuple[Instruction, ...]],
              archs: Iterable[str] = ("skl", "zen"),
              schedulers: Iterable[str] = ("uniform",),
              unroll_factors: Mapping[str, int] | None = None,
              parallel: bool = False,
              mode: str = "analytic",
              backend: str | None = None,
              working_set: float | None = None,
              traffic_model: str = "analytic",
              journal: str | None = None,
              resume_from: str | None = None,
              journal_segment_size: int | None = None,
              ) -> dict[tuple[str, str, str], AnalysisResult]:
        """Full grid: ``{(kernel_name, arch, scheduler): AnalysisResult}``.

        ``unroll_factors`` optionally maps kernel names to their unroll
        factor (default 1); ``mode="simulate"`` runs the whole grid
        through the cycle-level simulator backend, planned and
        dispatched in machine-model groups (see :meth:`predict_batch`;
        ``backend`` picks the batch-simulation driver).
        ``working_set`` / ``traffic_model`` apply the ECM
        memory-hierarchy composition to every cell (see
        :class:`AnalysisRequest`); the underlying analytic passes and
        simulations are cached independently of the working set, so an
        ECM sweep over an already-swept grid adds zero sim dispatches.
        This is the bulk entry point used by
        ``benchmarks/paper_tables.py``-style sweeps.

        ``journal`` names a directory to journal completed
        machine-group results into (one crash-safe record per group,
        scoped by a plan digest over the full request grid);
        ``resume_from`` replays matching records from such a directory
        so a killed sweep resumes with zero re-dispatch of journaled
        groups and bit-identical output — see docs/robustness.md.
        ``journal_segment_size`` bounds the journal's live file count:
        every time that many loose record files accumulate they are
        folded into one sealed digest-verified segment
        (docs/robustness.md#journal-segments); the journal's shape
        after the sweep is surfaced in ``stats.journal_records`` /
        ``journal_segments`` / ``journal_bytes``.
        """
        unroll_factors = unroll_factors or {}
        names, reqs = [], []
        for name, kern in kernels.items():
            for arch in archs:
                for sched in schedulers:
                    names.append((name, arch, sched))
                    reqs.append(AnalysisRequest(
                        kernel=kern, arch=arch, scheduler=sched,
                        unroll_factor=unroll_factors.get(name, 1),
                        mode=mode, working_set=working_set,
                        traffic_model=traffic_model))
        session = None
        if journal is not None or resume_from is not None:
            from .journal import SweepJournal, plan_digest
            plan = plan_digest([self.request_key(r) for r in reqs],
                               backend or self.sim_backend)
            session = {
                "plan": plan,
                "writer": SweepJournal(journal,
                                       segment_size=journal_segment_size)
                          if journal is not None else None,
                "resume": SweepJournal(resume_from).load(plan)
                          if resume_from is not None else {},
            }
        results = self.predict_batch(reqs, parallel=parallel,
                                     backend=backend, _journal=session)
        if session is not None and session["writer"] is not None:
            jstats = session["writer"].stats()
            with self._lock:
                self.stats.journal_records = jstats["records"]
                self.stats.journal_segments = jstats["segments"]
                self.stats.journal_bytes = jstats["bytes"]
        return dict(zip(names, results))

    # ------------------------------------------------------------------
    # HLO (TPU) path
    # ------------------------------------------------------------------
    def predict_hlo(self, text: str, *, ici_links: float = 1.0,
                    flop_dtype: str = "bf16", mode: str = "analytic",
                    machine: "str | MachineModel | None" = None,
                    working_set: float | None = None):
        """Memoized :func:`repro.core.hlo.analyzer.analyze_hlo`.

        Results carry the combined ``max(overlap, critical-path)`` bound
        (``HloAnalysis.terms.bound_combined``); ``mode="simulate"``
        additionally list-schedules the entry ops onto the TPU ports
        (``repro.core.sim.dag``) and fills ``terms.sim_s`` /
        ``terms.bound_sim``.  ``machine`` selects the accelerator model
        (an arch id/alias resolved through this service's registry, or a
        :class:`MachineModel` whose ``constants`` carry the hardware
        numbers; default ``"tpu_v5e"``).  ``working_set`` selects the
        memory level that prices the roofline memory term from the
        model's ``constants["mem_levels"]`` table (``None`` keeps the
        flat HBM assumption — see docs/ecm.md).  The cache key is the
        module-text digest plus the machine digest, so the serving
        dry-run and roofline sweeps share one pass per compiled program.
        """
        if mode not in ("analytic", "simulate"):
            raise ValueError(f"unknown mode {mode!r} "
                             "(expected 'analytic' or 'simulate')")
        self._check_epoch()
        machine = self.resolve_machine(machine or "tpu_v5e")
        digest = hashlib.sha256(text.encode()).hexdigest()
        key = (digest, ici_links, flop_dtype, mode, machine.digest,
               working_set)
        with self._lock:
            hit = self._hlo_cache.get(key)
            if hit is not None:
                self.stats.hlo_hits += 1
                return hit
            self.stats.hlo_misses += 1
        if self.faults is not None:
            # parse faults are *not* contained: there is no cheaper
            # predictor for an unparsed module, so the typed error
            # propagates (the service maps it to a DispatchError)
            self.faults.fire("engine.hlo_parse", module=digest[:12])
        from .hlo.analyzer import analyze_hlo
        res = analyze_hlo(text, ici_links=ici_links, flop_dtype=flop_dtype,
                          simulate=(mode == "simulate"), machine=machine,
                          working_set=working_set)
        with self._lock:
            self._hlo_cache[key] = res
        return res

    def predict_hlo_batch(self, texts: Sequence[str], *,
                          ici_links: float = 1.0,
                          flop_dtype: str = "bf16",
                          mode: str = "analytic",
                          machine: "str | MachineModel | None" = None,
                          working_set: float | None = None,
                          ) -> list:
        """Batched :meth:`predict_hlo` through the sweep planner's
        discipline: the machine model resolves *once* for the whole
        batch, duplicate modules collapse onto one cache cell, and each
        unique module analyzes once.  ``ServingEngine.dryrun_estimate``
        sends its prefill + decode programs through here, so a serving
        sweep over prompt lengths re-resolves nothing.
        """
        machine = self.resolve_machine(machine or "tpu_v5e")
        out: dict[str, object] = {}
        for text in texts:
            if text not in out:
                out[text] = self.predict_hlo(
                    text, ici_links=ici_links, flop_dtype=flop_dtype,
                    mode=mode, machine=machine, working_set=working_set)
        return [out[text] for text in texts]

    # ------------------------------------------------------------------
    def drop_results(self) -> None:
        """Drop the *volatile* caches (results, simulations, HLO
        analyses) while keeping the compiled artifacts — dependency
        edges, :class:`SimProgram`\\ s, LP solves, lookups, traffic,
        machine resolutions.

        This is the expiry operation a persistent service applies when
        result TTLs lapse: the next sweep re-simulates (fresh numbers)
        but reuses every compiled program, which is what makes
        ``stats.program_hits`` nonzero across successive sweeps —
        ``benchmarks/sweep_bench.py`` gates exactly that.
        """
        with self._lock:
            self._results.clear()
            self._sim_cache.clear()
            self._sim_provenance.clear()
            self._hlo_cache.clear()

    def cache_clear(self) -> None:
        """Drop every cache (databases are kept) and reset the stats."""
        with self._lock:
            self._lookups.clear()
            self._lp_cache.clear()
            self._results.clear()
            self._sim_cache.clear()
            self._sim_provenance.clear()
            self._hlo_cache.clear()
            self._edge_cache.clear()
            self._program_cache.clear()
            self._classify_cache.clear()
            self._machine_cache.clear()
            self._traffic_cache.clear()
            self.stats = ServiceStats()


_DEFAULT: AnalysisService | None = None
_DEFAULT_LOCK = threading.Lock()


def default_service() -> AnalysisService:
    """Process-wide shared service (benchmarks, examples and the serving
    dry-run all use this one so their caches compose)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = AnalysisService()
        return _DEFAULT
