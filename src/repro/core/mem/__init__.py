"""Memory-hierarchy model: cache levels, access streams, traffic
estimators (analytic + LRU cache simulator), and the ECM composition
that fuses them with the in-core bounds.  See ``docs/ecm.md``."""
from .cachesim import simulate_traffic
from .ecm import EcmResult, compose_ecm, memory_port_occupation
from .hierarchy import CacheLevel, MemoryHierarchy
from .streams import AccessStream, extract_streams
from .traffic import LevelTraffic, TrafficResult, predict_traffic

__all__ = [
    "AccessStream",
    "CacheLevel",
    "EcmResult",
    "LevelTraffic",
    "MemoryHierarchy",
    "TrafficResult",
    "compose_ecm",
    "extract_streams",
    "memory_port_occupation",
    "predict_traffic",
    "simulate_traffic",
]
