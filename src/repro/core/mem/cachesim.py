"""Lightweight LRU set-associative cache simulator.

The second, independent traffic estimator: instead of the analytic
streaming model it *replays* the kernel's access streams through a
stack of LRU set-associative caches (write-allocate / write-back per
level) and counts the cache lines actually crossing each link.  The
two estimators cross-check each other in ``tests/test_mem_model.py``
and must agree on streaming kernels to within 5%.

Each stream walks its own array region sized by its share of the
working set; regions are placed at decorrelated base addresses so
streams do not artificially conflict on the same sets.  Two passes are
made over the iteration space — one to warm the caches, one to count —
so the reported traffic is the steady-state per-iteration traffic, not
the cold-start one.

Large working sets are handled by proportional scale-down: hierarchy
sizes and the working set are divided by a common power of two until
the measuring pass fits a few thousand iterations.  Miss ratios only
depend on the working-set/cache-size *ratios*, which scaling preserves
(set counts are clamped to >= 1).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Sequence

from .hierarchy import MemoryHierarchy
from .streams import AccessStream
from .traffic import LevelTraffic, TrafficResult


class _LruCache:
    __slots__ = ("n_sets", "ways", "write_allocate", "sets")

    def __init__(self, size: int, ways: int, line: int,
                 write_allocate: bool) -> None:
        self.n_sets = max(1, size // (line * max(1, ways)))
        self.ways = max(1, ways)
        self.write_allocate = write_allocate
        self.sets: dict[int, OrderedDict] = {}


def simulate_traffic(streams: Sequence[AccessStream],
                     hierarchy: MemoryHierarchy,
                     working_set: float,
                     *, max_iterations: int = 8192,
                     ) -> TrafficResult:
    """Replay the streams through LRU caches and count link traffic."""
    levels = hierarchy.levels
    line = levels[0].line_bytes
    moving = [s for s in streams if s.stride > 0]
    n_links = len(levels) - 1
    loads = [0] * (n_links + 1)
    stores = [0] * (n_links + 1)

    # Layer condition short-circuit: a working set that fits in the
    # innermost level has zero steady-state traffic by definition — the
    # region padding below would otherwise leak artificial conflict
    # misses and break the W <= L1 bit-exactness contract.
    if not hierarchy.active_links(working_set):
        moving = []

    total_stride = sum(s.stride for s in moving)
    if moving and total_stride > 0:
        # Iterations needed for one full sweep of the largest region.
        n_iter = max(2, int(working_set / total_stride + 0.5))
        scale = 1
        while n_iter // scale > max_iterations:
            scale *= 2
        n_iter = max(2, n_iter // scale)
        ws = working_set / scale

        n_bounded = sum(1 for lv in levels if lv.size_bytes is not None)
        caches = [_LruCache(max(lv.line_bytes,
                                (lv.size_bytes or 0) // scale),
                            lv.ways, lv.line_bytes, lv.write_allocate)
                  for lv in levels[:n_bounded]]

        # Region layout: each stream gets its stride-share of the
        # working set, at a base offset decorrelated from the others.
        regions = []
        cursor = 0
        for s in moving:
            length = max(line, int(ws * (s.stride / total_stride)))
            # Round to a stride multiple so wrapping back to the region
            # start preserves the stream's line alignment — otherwise
            # every sweep after the first straddles extra lines.
            step = max(1, int(s.stride))
            length = max(step, length - length % step)
            regions.append((s, cursor, length))
            cursor += length + 17 * line       # odd pad decorrelates sets

        counting = False

        def touch(idx: int, la: int, write: bool) -> None:
            if idx >= n_bounded:
                return
            c = caches[idx]
            st = c.sets.setdefault(la % c.n_sets, OrderedDict())
            tag = la // c.n_sets
            if tag in st:
                st.move_to_end(tag)
                if write:
                    st[tag] = True
                return
            if write and not c.write_allocate:
                if counting:
                    stores[idx + 1] += 1
                touch(idx + 1, la, True)
                return
            if counting:
                loads[idx + 1] += 1
            touch(idx + 1, la, False)
            st[tag] = write
            st.move_to_end(tag)
            if len(st) > c.ways:
                victim, dirty = st.popitem(last=False)
                if dirty:
                    if counting:
                        stores[idx + 1] += 1
                    writeback(idx + 1, victim * c.n_sets + la % c.n_sets)

        def writeback(idx: int, la: int) -> None:
            if idx >= n_bounded:
                return
            c = caches[idx]
            st = c.sets.setdefault(la % c.n_sets, OrderedDict())
            tag = la // c.n_sets
            st[tag] = True
            st.move_to_end(tag)
            if len(st) > c.ways:
                victim, dirty = st.popitem(last=False)
                if dirty:
                    if counting:
                        stores[idx + 1] += 1
                    writeback(idx + 1, victim * c.n_sets + la % c.n_sets)

        for it in range(2 * n_iter):
            counting = it >= n_iter
            for s, base, length in regions:
                pos = (it * int(s.stride)) % length
                for k in range(s.n_accesses):
                    la = (base + (pos + k * s.width) % length) // line
                    if s.has_load:
                        touch(0, la, False)
                    if s.has_store:
                        touch(0, la, True)

        inv = 1.0 / n_iter
    else:
        inv = 0.0

    rows = []
    for i in range(1, len(levels)):
        outer = levels[i]
        ld = loads[i] * inv
        st = stores[i] * inv
        rows.append(LevelTraffic(
            level=outer.name, load_lines=ld, store_lines=st,
            load_cycles=ld * outer.load_bw, store_cycles=st * outer.store_bw))
    return TrafficResult(
        working_set=float(working_set),
        resident=hierarchy.resident_level(working_set).name,
        estimator="cachesim", levels=tuple(rows))
