"""Memory-hierarchy specification carried on :class:`MachineModel`.

A :class:`MemoryHierarchy` is an ordered tuple of :class:`CacheLevel`
entries, innermost (L1) first, outermost (main memory) last.  Each
level prices the *link into it* — the cost, in core cycles per 64-byte
cache line, of moving a line between this level and the next-inner one
(Kerncraft's ``cy/CL`` convention).  The L1 entry's bandwidths describe
the L1↔register link; that cost is already covered by the in-core
``T_nOL`` port-occupation term, so only levels past the first
contribute transfer cycles to the ECM sum.

The outermost level models main memory: its ``size_bytes`` is ``None``
(unbounded), so every working set is resident *somewhere* and
``resident_level`` is total.

Construction only coerces and sanity-checks types; semantic artifact
validation (size ordering, positive bandwidths, line-size consistency)
lives in :meth:`MemoryHierarchy.validate` so that
``tools/check_models.py`` can report *all* defects of a shipped JSON
artifact instead of dying on the first.
"""
from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field, fields


@dataclass(frozen=True)
class CacheLevel:
    """One level of the memory hierarchy.

    ``size_bytes=None`` marks the unbounded outermost level (DRAM).
    ``load_bw`` / ``store_bw`` are cycles per cache line transferred
    over the link between this level and the next-inner one.
    ``write_allocate`` describes the *inner* side of that link: when
    True, a store miss in the next-inner level first loads the line
    from here (the classic write-allocate / write-back pair).
    """

    name: str
    size_bytes: int | None
    ways: int = 8
    line_bytes: int = 64
    load_bw: float = 1.0
    store_bw: float = 1.0
    write_allocate: bool = True

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("CacheLevel.name must be non-empty")
        # Coerce JSON-borne numerics so from_dict(to_dict()) round-trips
        # to equal (and equally hashed/digested) objects.
        size = self.size_bytes
        object.__setattr__(self, "size_bytes",
                           None if size is None else int(size))
        object.__setattr__(self, "ways", int(self.ways))
        object.__setattr__(self, "line_bytes", int(self.line_bytes))
        object.__setattr__(self, "load_bw", float(self.load_bw))
        object.__setattr__(self, "store_bw", float(self.store_bw))
        object.__setattr__(self, "write_allocate", bool(self.write_allocate))

    @property
    def bounded(self) -> bool:
        return self.size_bytes is not None

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "size_bytes": self.size_bytes,
            "ways": self.ways,
            "line_bytes": self.line_bytes,
            "load_bw": self.load_bw,
            "store_bw": self.store_bw,
            "write_allocate": self.write_allocate,
        }

    @classmethod
    def from_dict(cls, data: Mapping) -> "CacheLevel":
        known = {f.name for f in fields(cls)}
        bad = set(data) - known
        if bad:
            raise ValueError(f"unknown CacheLevel fields: {sorted(bad)}")
        return cls(**dict(data))


@dataclass(frozen=True)
class MemoryHierarchy:
    """Ordered cache levels, innermost first, unbounded memory last."""

    levels: tuple[CacheLevel, ...] = field(default_factory=tuple)

    def __post_init__(self) -> None:
        coerced = tuple(
            lv if isinstance(lv, CacheLevel)
            else CacheLevel.from_dict(lv) if isinstance(lv, Mapping)
            else CacheLevel(*lv)
            for lv in self.levels)
        object.__setattr__(self, "levels", coerced)
        if not coerced:
            raise ValueError("MemoryHierarchy needs at least one level")
        names = [lv.name for lv in coerced]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate hierarchy level names: {names}")

    # ---------------------------------------------------------- access
    def resident_level(self, working_set: float) -> CacheLevel:
        """Innermost level large enough to hold ``working_set`` bytes."""
        for lv in self.levels:
            if lv.size_bytes is None or working_set <= lv.size_bytes:
                return lv
        return self.levels[-1]

    def active_links(self, working_set: float) -> tuple[int, ...]:
        """Indices ``i`` of levels whose inbound link carries traffic:
        the working set overflows every level inner to ``i``."""
        out = []
        for i in range(1, len(self.levels)):
            inner = self.levels[i - 1]
            if inner.size_bytes is not None and working_set > inner.size_bytes:
                out.append(i)
        return tuple(out)

    # --------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {"levels": [lv.to_dict() for lv in self.levels]}

    @classmethod
    def from_dict(cls, data: Mapping) -> "MemoryHierarchy":
        bad = set(data) - {"levels"}
        if bad:
            raise ValueError(f"unknown MemoryHierarchy fields: {sorted(bad)}")
        return cls(levels=tuple(data.get("levels", ())))

    # ------------------------------------------------------ validation
    def validate(self) -> list[str]:
        """Semantic artifact checks; returns human-readable defects.

        Kept out of ``__post_init__`` so ``tools/check_models.py`` can
        enumerate every problem of a malformed shipped JSON artifact.
        """
        errors: list[str] = []
        levels = self.levels
        if levels[-1].size_bytes is not None:
            errors.append(
                f"last level {levels[-1].name!r} must be unbounded "
                "(size_bytes=None) to model main memory")
        lines = {lv.line_bytes for lv in levels}
        if len(lines) > 1:
            errors.append(f"inconsistent line sizes across levels: "
                          f"{sorted(lines)}")
        prev_size = 0
        for i, lv in enumerate(levels):
            if lv.load_bw <= 0 or lv.store_bw <= 0:
                errors.append(f"level {lv.name!r}: bandwidths must be "
                              f"positive (load_bw={lv.load_bw}, "
                              f"store_bw={lv.store_bw})")
            if lv.line_bytes <= 0:
                errors.append(f"level {lv.name!r}: line_bytes must be "
                              "positive")
            if lv.size_bytes is None:
                if i != len(levels) - 1:
                    errors.append(f"unbounded level {lv.name!r} must be "
                                  "the outermost level")
                continue
            if lv.size_bytes <= prev_size:
                errors.append(f"level {lv.name!r}: size_bytes="
                              f"{lv.size_bytes} not strictly larger than "
                              f"the inner level ({prev_size})")
            if lv.ways < 1:
                errors.append(f"level {lv.name!r}: ways must be >= 1")
            elif lv.line_bytes > 0 and \
                    lv.size_bytes % (lv.line_bytes * lv.ways):
                errors.append(f"level {lv.name!r}: size_bytes="
                              f"{lv.size_bytes} not divisible by "
                              f"line_bytes*ways="
                              f"{lv.line_bytes * lv.ways}")
            prev_size = lv.size_bytes
        return errors
