"""Analytic per-level traffic prediction (streaming / layer-condition).

Given the kernel's access streams, a :class:`MemoryHierarchy`, and the
working-set size, predict the cache-line traffic crossing each
inter-level link per assembly-loop iteration, and price it with the
level bandwidths.  The model is the classic streaming one used by
Kerncraft's layer-condition analysis in its "no reuse between levels"
regime: a link carries a stream's lines iff the combined working set
overflows every level inner to the link.

Write-allocate is honoured: on a link whose inner level allocates on
write, every stored line is first loaded (allocate) and later written
back, so store streams contribute to both directions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .hierarchy import MemoryHierarchy
from .streams import AccessStream


@dataclass(frozen=True)
class LevelTraffic:
    """Traffic over the link into one hierarchy level, per asm iteration."""

    level: str
    load_lines: float
    store_lines: float
    load_cycles: float
    store_cycles: float

    @property
    def cycles(self) -> float:
        return self.load_cycles + self.store_cycles


@dataclass(frozen=True)
class TrafficResult:
    """Per-link traffic for one (kernel, hierarchy, working set)."""

    working_set: float
    resident: str              # innermost level holding the working set
    estimator: str             # "analytic" | "cachesim"
    levels: tuple[LevelTraffic, ...]

    @property
    def transfer_cycles(self) -> float:
        return sum(lv.cycles for lv in self.levels)


def predict_traffic(streams: Sequence[AccessStream],
                    hierarchy: MemoryHierarchy,
                    working_set: float,
                    ) -> TrafficResult:
    """Streaming-model traffic: every active link sees every stream."""
    rows = []
    active = set(hierarchy.active_links(working_set))
    for i in range(1, len(hierarchy.levels)):
        outer = hierarchy.levels[i]
        inner = hierarchy.levels[i - 1]
        load_lines = store_lines = 0.0
        if i in active:
            for s in streams:
                lines = s.lines_per_iteration(inner.line_bytes)
                if s.has_load or (s.has_store and inner.write_allocate):
                    load_lines += lines
                if s.has_store:
                    store_lines += lines
        rows.append(LevelTraffic(
            level=outer.name,
            load_lines=load_lines, store_lines=store_lines,
            load_cycles=load_lines * outer.load_bw,
            store_cycles=store_lines * outer.store_bw))
    return TrafficResult(
        working_set=float(working_set),
        resident=hierarchy.resident_level(working_set).name,
        estimator="analytic", levels=tuple(rows))
