"""ECM composition: in-core bounds + per-level transfer terms.

Kerncraft's ECM model writes a kernel's cycles per iteration as

    T_ECM = max(T_OL, T_nOL + T_L1L2 + T_L2L3 + T_L3Mem + ...)

where ``T_OL`` is the in-core time that overlaps with data transfers
(everything the existing analytic/simulated bounds already predict)
and ``T_nOL`` is the non-overlapping part: the cycles the load/store
ports are busy moving the kernel's data between L1 and the registers,
which cannot hide behind cache transfers.  Here ``T_OL`` is the
engine's existing in-core prediction (``max(port bound, LCD)`` or the
pipeline-simulator bound), and ``T_nOL`` is the port occupation of the
memory uops alone, computed by :func:`memory_port_occupation`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .traffic import TrafficResult

#: Uop kinds that occupy load/store ports (the T_nOL term).
_MEMORY_KINDS = ("load", "store-agu", "store-data")


@dataclass(frozen=True)
class EcmResult:
    """One ECM-composed prediction, cycles per assembly iteration."""

    working_set: float
    t_incore: float            # overlapping in-core term (T_OL)
    t_nol: float               # non-overlapping L1<->register term
    traffic: TrafficResult     # per-level transfer terms
    cycles: float              # max(T_OL, T_nOL + sum(T_link))

    @property
    def resident(self) -> str:
        return self.traffic.resident

    @property
    def transfer_cycles(self) -> float:
        return self.traffic.transfer_cycles

    def notation(self) -> str:
        """Kerncraft-style ``{T_OL || T_nOL | T_L1L2 | ...}`` string."""
        terms = " | ".join(f"{lv.cycles:.2f}" for lv in self.traffic.levels)
        return (f"{{{self.t_incore:.2f} || {self.t_nol:.2f}"
                + (f" | {terms}" if terms else "") + "}")


def compose_ecm(*, t_incore: float, t_nol: float,
                traffic: TrafficResult) -> EcmResult:
    cycles = max(t_incore, t_nol + traffic.transfer_cycles)
    return EcmResult(working_set=traffic.working_set,
                     t_incore=t_incore, t_nol=t_nol,
                     traffic=traffic, cycles=cycles)


def memory_port_occupation(model, entries: Sequence) -> float:
    """T_nOL: max per-port occupation of the memory uops alone.

    Uses the same uniform split and hidden-load accounting as the
    analytic scheduler, restricted to load/store uops.  Callers clamp
    the result to the kernel's overall port bound: the uniform split
    of the memory uops in isolation can exceed the balanced bound on
    asymmetric port sets, and T_nOL is by definition a *part* of the
    in-core time.
    """
    # Imported lazily: analysis -> machine -> mem would otherwise cycle.
    from ..analysis import hidden_instruction_indices

    hidden = hidden_instruction_indices(model, entries)
    pressure: dict[str, float] = {}
    for i, entry in enumerate(entries):
        if entry is None:
            continue
        for uop in entry.uops:
            if uop.kind not in _MEMORY_KINDS:
                continue
            if i in hidden and getattr(uop, "hideable_load", False):
                continue
            share = uop.cycles / len(uop.ports)
            for port in uop.ports:
                pressure[port] = pressure.get(port, 0.0) + share
    return max(pressure.values(), default=0.0)
