"""Access-stream extraction from a parsed kernel.

The memory model needs to know, per assembly-loop iteration, which
array-like streams the kernel walks and at what byte stride.  Streams
are recovered statically, the same way the latency analyzer recovers
loop-carried dependencies: induction registers are identified from
``add``/``sub``/``inc``/``dec`` instructions with an immediate operand,
and every memory operand is grouped by its canonical
``(base, index, scale)`` address expression.  Distinct displacements
off the same expression (an unrolled body touching ``0(%r13,%rax)``,
``32(%r13,%rax)``, …) are one stream with several accesses.

A stream whose address does not advance per iteration (e.g. the
``(%rsp)`` scalar spill in the paper's ``pi -O1`` kernel) has stride 0
and generates no cache traffic: it stays resident in L1 regardless of
the working-set size.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from ..isa import Instruction, register_class

#: Bytes accessed per register class (width of the data operand).
_CLASS_WIDTH = {"zmm": 64, "ymm": 32, "xmm": 16,
                "r64": 8, "r32": 4, "r16": 2, "r8": 1}

#: Mnemonic prefixes whose memory *destination* is written without
#: being read first (plain stores).  Anything else with a memory
#: destination is treated as read-modify-write (load + store).
_STORE_ONLY_PREFIXES = ("mov", "vmov")


@dataclass(frozen=True)
class AccessStream:
    """One array-like access stream of the kernel body."""

    base: str | None
    index: str | None
    scale: int
    stride: float          # bytes advanced per assembly iteration
    width: int             # bytes per individual access
    n_accesses: int        # distinct displacements per iteration
    has_load: bool
    has_store: bool

    @property
    def key(self) -> tuple:
        return (self.base, self.index, self.scale)

    def lines_per_iteration(self, line_bytes: int) -> float:
        """Cache lines newly touched per assembly iteration.

        Dense streams (stride <= bytes spanned by the iteration's
        accesses) share lines across iterations: stride/line lines per
        iteration.  Sparse streams open at most one fresh line per
        access.  ``min(stride, n_accesses * line)`` covers both.
        """
        if self.stride <= 0:
            return 0.0
        return min(self.stride, self.n_accesses * line_bytes) / line_bytes


def _canon(reg: str) -> str:
    # Imported lazily: latency -> machine -> mem would otherwise cycle.
    from ..latency import _canon_reg
    return _canon_reg(reg)


def _induction_deltas(kernel: Sequence[Instruction]) -> dict[str, int]:
    """Per-iteration byte delta of every register the loop increments."""
    deltas: dict[str, int] = {}
    for ins in kernel:
        if not ins.operands or ins.operands[0].kind != "reg":
            continue
        reg = _canon(ins.operands[0].reg or "")
        if ins.mnemonic in ("inc", "dec"):
            deltas[reg] = deltas.get(reg, 0) + (1 if ins.mnemonic == "inc"
                                                else -1)
        elif ins.mnemonic in ("add", "sub") and len(ins.operands) > 1 \
                and ins.operands[1].kind == "imm":
            try:
                imm = int(ins.operands[1].text.lstrip("$"), 0)
            except ValueError:
                continue
            deltas[reg] = deltas.get(reg, 0) + \
                (imm if ins.mnemonic == "add" else -imm)
    return deltas


def _operand_width(ins: Instruction) -> int:
    width = 0
    for op in ins.operands:
        if op.kind == "reg" and op.reg:
            width = max(width, _CLASS_WIDTH.get(register_class(op.reg), 0))
    return width or 8


def extract_streams(kernel: Sequence[Instruction]) -> tuple[AccessStream, ...]:
    """Group the kernel's memory operands into per-iteration streams."""
    deltas = _induction_deltas(kernel)
    groups: dict[tuple, dict] = {}
    for ins in kernel:
        if ins.mnemonic == "lea":          # address arithmetic, no access
            continue
        for pos, op in enumerate(ins.operands):
            if op.kind != "mem" or not (op.base or op.index):
                continue
            is_store = pos == 0
            is_load = (not is_store) or \
                not ins.mnemonic.startswith(_STORE_ONLY_PREFIXES)
            base = _canon(op.base) if op.base else None
            index = _canon(op.index) if op.index else None
            key = (base, index, op.scale)
            g = groups.setdefault(key, {"disps": set(), "width": 0,
                                        "load": False, "store": False})
            g["disps"].add(op.displacement)
            g["width"] = max(g["width"], _operand_width(ins))
            g["load"] = g["load"] or is_load
            g["store"] = g["store"] or is_store
    streams = []
    for (base, index, scale), g in sorted(
            groups.items(), key=lambda kv: (str(kv[0][0]), str(kv[0][1]),
                                            kv[0][2])):
        stride = deltas.get(base or "", 0) + deltas.get(index or "", 0) * scale
        streams.append(AccessStream(
            base=base, index=index, scale=scale, stride=float(abs(stride)),
            width=g["width"], n_accesses=len(g["disps"]),
            has_load=g["load"], has_store=g["store"]))
    return tuple(streams)
