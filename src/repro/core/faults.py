"""Deterministic, seeded fault injection for the prediction stack.

The engine and service thread named *failure points* through their hot
paths (backend dispatch, program compilation, cache get/put, ECM
traffic estimation, HLO parse).  A :class:`FaultPlan` arms a set of
those points with :class:`FaultSpec` entries; the :class:`FaultInjector`
built from the plan decides — deterministically, from the plan's seed
and per-spec counters — when each armed point fires.

Design constraints that shape the API:

* **Zero cost when disarmed.**  Callers guard every hook with
  ``if injector is not None`` — an engine without a plan executes the
  exact same instruction stream as before this module existed, so the
  golden tables stay bit-identical.
* **Deterministic.**  ``probability`` draws come from a per-spec
  ``random.Random`` seeded from ``(plan.seed, spec index)``; counters
  are lock-protected.  Two injectors built from the same plan make the
  same decisions in the same call order.
* **Serializable.**  ``FaultPlan.to_json``/``from_json`` round-trip, so
  a chaos schedule can be shipped to a worker or pinned in CI, and
  ``FaultPlan.digest`` content-addresses it.
* **Observable.**  Every action (raise, delay, corrupt, abort) appends
  a :class:`FaultEvent` to a bounded trace with a monotonically
  increasing id; the id is surfaced as ``fault_trace_id`` provenance on
  degraded results.

Failure points currently armed by the stack (see docs/robustness.md for
the full matrix):

========================  ====================================================
point                     fired from
========================  ====================================================
``engine.compile``        ``AnalysisService._sim_program`` (per request)
``engine.dispatch``       per machine-group backend dispatch (context:
                          ``backend=``, ``machine=`` digest prefix)
``engine.traffic``        ECM traffic estimation (``AnalysisService._traffic``)
``engine.hlo_parse``      ``predict_hlo`` module parse
``cache.get``             ``TTLCache.get`` (fault -> treated as a miss)
``cache.put``             ``TTLCache.put`` (fault -> entry silently dropped)
========================  ====================================================
"""

from __future__ import annotations

import hashlib
import json
import random
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

__all__ = [
    "FAULT_POINTS", "FAULT_MODES", "CORRUPT_KINDS",
    "InjectedFault", "FaultAbort", "ResultValidationError",
    "FaultSpec", "FaultPlan", "FaultEvent", "FaultInjector",
]

# the registry of point names; fire()/corrupt() reject unknown points so
# a typo in a chaos schedule fails loudly instead of never firing
FAULT_POINTS: tuple[str, ...] = (
    "engine.compile",
    "engine.dispatch",
    "engine.traffic",
    "engine.hlo_parse",
    "cache.get",
    "cache.put",
)

FAULT_MODES: tuple[str, ...] = (
    "fail",        # raise InjectedFault every time (up to `count`)
    "fail_once",   # raise exactly once
    "fail_n",      # raise `count` times
    "latency",     # sleep(delay_s) instead of raising
    "corrupt",     # poison a float result (NaN / negative)
    "abort",       # raise FaultAbort — NOT contained by the ladder;
                   # simulates a process kill for resume testing
)

CORRUPT_KINDS: tuple[str, ...] = ("nan", "negative")


class InjectedFault(RuntimeError):
    """A fault raised by an armed :class:`FaultSpec`.

    Carries the failure ``point`` and the trace ``event_id`` so tests
    and telemetry can correlate the raise with the injector's event
    log."""

    def __init__(self, point: str, event_id: int, context: Mapping[str, object]):
        ctx = " ".join(f"{k}={v}" for k, v in sorted(context.items()))
        super().__init__(f"injected fault at {point}" + (f" ({ctx})" if ctx else ""))
        self.point = point
        self.event_id = event_id
        self.context = dict(context)


class FaultAbort(InjectedFault):
    """A simulated process kill.

    Unlike :class:`InjectedFault`, the degradation ladder never
    contains this — it propagates out of ``predict_batch``/``sweep`` so
    the crash-resume machinery can be exercised end to end."""


class ResultValidationError(RuntimeError):
    """A post-dispatch validator rejected a backend's output (non-finite
    or negative cycles, or implausible divergence from the analytic
    port bound).  The ladder treats this exactly like a dispatch
    fault."""


@dataclass(frozen=True)
class FaultSpec:
    """One armed failure point.

    ``match`` restricts firing to calls whose context carries the given
    key/value pairs (e.g. ``{"backend": "jit"}`` only faults the jit
    rung).  ``skip`` lets the first N matching calls through untouched
    — the lever for "kill the *second* machine group".  ``count`` caps
    total firings (``None`` = unlimited; forced to 1 for
    ``fail_once``).  ``probability`` < 1 makes firing a seeded coin
    flip."""

    point: str
    mode: str = "fail"
    count: int | None = None
    skip: int = 0
    match: Mapping[str, str] = field(default_factory=dict)
    delay_s: float = 0.05
    corrupt: str = "nan"
    probability: float = 1.0

    def __post_init__(self):
        if self.point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {self.point!r}; "
                             f"known: {', '.join(FAULT_POINTS)}")
        if self.mode not in FAULT_MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}")
        if self.corrupt not in CORRUPT_KINDS:
            raise ValueError(f"unknown corrupt kind {self.corrupt!r}")
        if self.skip < 0:
            raise ValueError("skip must be >= 0")
        if self.count is not None and self.count < 1:
            raise ValueError("count must be >= 1 (or None for unlimited)")
        if not 0.0 <= self.probability <= 1.0:
            raise ValueError("probability must be in [0, 1]")
        if self.delay_s < 0:
            raise ValueError("delay_s must be >= 0")
        # freeze the match mapping so specs are safely shareable
        object.__setattr__(self, "match", dict(self.match))

    @property
    def limit(self) -> int | None:
        """Maximum number of firings (None = unlimited)."""
        if self.mode == "fail_once":
            return 1
        return self.count

    def to_dict(self) -> dict:
        return {"point": self.point, "mode": self.mode, "count": self.count,
                "skip": self.skip, "match": dict(self.match),
                "delay_s": self.delay_s, "corrupt": self.corrupt,
                "probability": self.probability}

    @classmethod
    def from_dict(cls, d: Mapping) -> "FaultSpec":
        return cls(point=d["point"], mode=d.get("mode", "fail"),
                   count=d.get("count"), skip=d.get("skip", 0),
                   match=d.get("match", {}), delay_s=d.get("delay_s", 0.05),
                   corrupt=d.get("corrupt", "nan"),
                   probability=d.get("probability", 1.0))


@dataclass(frozen=True)
class FaultPlan:
    """A serializable chaos schedule: a tuple of specs plus the seed
    feeding every per-spec RNG."""

    specs: tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    def to_dict(self) -> dict:
        return {"seed": self.seed, "specs": [s.to_dict() for s in self.specs]}

    @classmethod
    def from_dict(cls, d: Mapping) -> "FaultPlan":
        return cls(specs=tuple(FaultSpec.from_dict(s) for s in d.get("specs", ())),
                   seed=d.get("seed", 0))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls.from_dict(json.loads(text))

    @property
    def digest(self) -> str:
        """Content address of the schedule (sha256 of canonical JSON)."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()


@dataclass
class FaultEvent:
    """One entry in the injector's bounded trace."""

    id: int
    point: str
    mode: str
    action: str            # "raised" | "delayed" | "corrupted" | "aborted"
    spec_index: int
    context: dict

    def as_dict(self) -> dict:
        return {"id": self.id, "point": self.point, "mode": self.mode,
                "action": self.action, "spec": self.spec_index,
                "context": dict(self.context)}


class FaultInjector:
    """Runtime for a :class:`FaultPlan`.

    ``clock`` and ``sleep`` are injectable so tests can fake latency
    spikes without wall-clock waits.  Thread-safe: per-spec counters
    and the event trace are guarded by one lock (the engine dispatches
    machine groups from worker threads)."""

    def __init__(self, plan: FaultPlan, *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 trace_capacity: int = 1024):
        self.plan = plan
        self._clock = clock
        self._sleep = sleep
        self._lock = threading.Lock()
        self._seen = [0] * len(plan.specs)
        self._fired = [0] * len(plan.specs)
        # int-arithmetic seed: stable across processes (str hashing is not)
        self._rngs = [random.Random(plan.seed * 1_000_003 + i)
                      for i in range(len(plan.specs))]
        self._events: deque[FaultEvent] = deque(maxlen=trace_capacity)
        self._next_id = 1

    # ------------------------------------------------------------------
    # decision core
    # ------------------------------------------------------------------
    @staticmethod
    def _matches(spec: FaultSpec, context: Mapping[str, object]) -> bool:
        return all(str(context.get(k)) == str(v) for k, v in spec.match.items())

    def _decide(self, i: int, spec: FaultSpec) -> bool:
        """Under the lock: advance this spec's counters and decide
        whether it fires on this call."""
        self._seen[i] += 1
        if self._seen[i] <= spec.skip:
            return False
        if spec.limit is not None and self._fired[i] >= spec.limit:
            return False
        if spec.probability < 1.0 and self._rngs[i].random() >= spec.probability:
            return False
        self._fired[i] += 1
        return True

    def _record(self, spec_index: int, spec: FaultSpec, action: str,
                context: Mapping[str, object]) -> int:
        ev = FaultEvent(id=self._next_id, point=spec.point, mode=spec.mode,
                        action=action, spec_index=spec_index,
                        context=dict(context))
        self._next_id += 1
        self._events.append(ev)
        return ev.id

    # ------------------------------------------------------------------
    # hooks
    # ------------------------------------------------------------------
    def fire(self, point: str, **context) -> None:
        """Raise / delay if a spec armed at ``point`` fires.

        Raises :class:`FaultAbort` for ``abort`` specs and
        :class:`InjectedFault` for the ``fail*`` family; ``latency``
        specs sleep and return.  ``corrupt`` specs are ignored here —
        they act through :meth:`corrupt`."""
        if point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {point!r}")
        delays: list[float] = []
        raise_exc: InjectedFault | None = None
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if spec.point != point or spec.mode == "corrupt":
                    continue
                if not self._matches(spec, context):
                    continue
                if not self._decide(i, spec):
                    continue
                if spec.mode == "latency":
                    self._record(i, spec, "delayed", context)
                    delays.append(spec.delay_s)
                elif spec.mode == "abort":
                    ev = self._record(i, spec, "aborted", context)
                    raise_exc = FaultAbort(point, ev, context)
                    break
                else:
                    ev = self._record(i, spec, "raised", context)
                    raise_exc = InjectedFault(point, ev, context)
                    break
        # sleep outside the lock so latency spikes don't serialize the pool
        for d in delays:
            self._sleep(d)
        if raise_exc is not None:
            raise raise_exc

    def corrupt(self, point: str, value: float, **context) -> tuple[float, int]:
        """Pass ``value`` through any armed ``corrupt`` spec.

        Returns ``(possibly poisoned value, event id)``; the event id is
        0 when no spec fired."""
        if point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {point!r}")
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if spec.point != point or spec.mode != "corrupt":
                    continue
                if not self._matches(spec, context):
                    continue
                if not self._decide(i, spec):
                    continue
                ev = self._record(i, spec, "corrupted", context)
                if spec.corrupt == "nan":
                    return float("nan"), ev
                return -abs(value) - 1.0, ev
        return value, 0

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def events(self) -> list[FaultEvent]:
        with self._lock:
            return list(self._events)

    def export(self) -> dict:
        """Trace + counters, JSON-ready (the CI chaos artifact)."""
        with self._lock:
            return {
                "plan": self.plan.to_dict(),
                "plan_digest": self.plan.digest,
                "fired": list(self._fired),
                "seen": list(self._seen),
                "events": [e.as_dict() for e in self._events],
            }

    def summary(self) -> dict:
        """Compact per-point firing counts for telemetry exports."""
        with self._lock:
            counts: dict[str, int] = {}
            for spec, fired in zip(self.plan.specs, self._fired):
                if fired:
                    counts[spec.point] = counts.get(spec.point, 0) + fired
            return {"events": len(self._events), "fired_by_point": counts}

    def reset(self) -> None:
        with self._lock:
            self._seen = [0] * len(self.plan.specs)
            self._fired = [0] * len(self.plan.specs)
            self._rngs = [random.Random(self.plan.seed * 1_000_003 + i)
                          for i in range(len(self.plan.specs))]
            self._events.clear()
            self._next_id = 1
