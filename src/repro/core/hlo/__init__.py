from .parser import HloOp, parse_hlo_module, collective_ops
from .analyzer import analyze_hlo, RooflineTerms, HloAnalysis
