"""HLO text parser: turns ``compiled.as_text()`` (or pre-optimization HLO)
into an *instruction stream* — the TPU analogue of the paper's marked
assembly kernel.  Each HLO op becomes an instruction form
(op kind x operand shapes x dtypes), consumed by repro.core.hlo.analyzer
exactly the way repro.core.analysis consumes x86 forms.

Post-optimization HLO prints operands by name only (no shapes), so parsing
is two-pass: first collect every instruction's result shape into a symbol
table, then resolve operand shapes by name.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction: [ROOT] %name = <result-type> opcode(...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^()]*?(?:\([^()]*\))?[^()=]*?\)?)\s*"
    r"([\w\-]+)\((.*)$")
# computation header: [ENTRY] %name (args) -> result {      (no " = ")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]?")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = frozenset({
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "ragged-all-to-all",
})


@dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def elements(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elements * _DTYPE_BYTES.get(self.dtype, 4)


@dataclass
class HloOp:
    name: str
    kind: str                      # opcode: dot, fusion, all-gather, ...
    result_shapes: list[Shape]
    operand_names: list[str]
    attrs: str
    computation: str = "ENTRY"
    operand_shapes: list[Shape] = field(default_factory=list)
    group_size: int = 1            # replica-group size for collectives
    is_root: bool = False
    operands_text: str = ""        # raw operand text (constants keep
                                   # their literal value here)

    @property
    def is_collective(self) -> bool:
        return self.kind in COLLECTIVES

    @property
    def result_bytes(self) -> int:
        return sum(s.bytes for s in self.result_shapes)

    @property
    def operand_bytes(self) -> int:
        return sum(s.bytes for s in self.operand_shapes)


def _parse_shapes(text: str) -> list[Shape]:
    return [Shape(m.group(1),
                  tuple(int(x) for x in m.group(2).split(",") if x))
            for m in _SHAPE_RE.finditer(text)
            if m.group(1) in _DTYPE_BYTES]


def _group_size(attrs: str) -> int:
    m = _GROUPS_V2_RE.search(attrs)
    if m:  # replica_groups=[n_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(attrs)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return 1


def parse_module(text: str) -> tuple[list[HloOp], str]:
    """Parse every computation; returns (ops, entry_computation_name)."""
    ops: list[HloOp] = []
    symbols: dict[str, list[Shape]] = {}
    computation = "ENTRY"
    entry_name = ""
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith(("//", "#")):
            continue
        if " = " not in stripped:
            hm = _HEADER_RE.match(stripped)
            if hm and stripped.rstrip().endswith("{"):
                computation = hm.group(2)
                if hm.group(1):
                    entry_name = computation
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_text, kind, rest = m.groups()
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands_text = rest[:idx]
        attrs = rest[idx + 1:]
        shapes = _parse_shapes(result_text)
        op = HloOp(
            name=name, kind=kind, result_shapes=shapes,
            operand_names=_OPERAND_NAME_RE.findall(operands_text),
            attrs=attrs, computation=computation,
            group_size=_group_size(attrs) if kind in COLLECTIVES else 1,
            is_root=stripped.startswith("ROOT"),
            operands_text=operands_text)
        # operands may be printed inline with shapes (pre-optimization)
        inline = _parse_shapes(operands_text)
        if inline:
            op.operand_shapes = inline
        ops.append(op)
        symbols[name] = shapes
    # second pass: resolve operand shapes by name
    for op in ops:
        if not op.operand_shapes and op.operand_names:
            resolved: list[Shape] = []
            for n in op.operand_names:
                resolved.extend(symbols.get(n, ()))
            op.operand_shapes = resolved
    return ops, entry_name


def parse_hlo_module(text: str) -> list[HloOp]:
    return parse_module(text)[0]


def collective_ops(ops: list[HloOp]) -> list[HloOp]:
    return [o for o in ops if o.is_collective]
