"""OSACA-on-HLO: throughput analysis of a compiled JAX step.

The paper predicts loop throughput as max-over-ports of summed occupation;
under assumption (A3)/"perfect overlap" the same bound for a TPU step is

    T_pred = max(MXU+VPU, HBM, ICI)   [seconds]

with per-op occupations accumulated exactly like the x86 tables.  We also
report the no-overlap sum as an upper bound; the pair brackets reality.

Key extension over ``compiled.cost_analysis()``: while-loop (lax.scan)
bodies are multiplied by their trip count, recovered from the loop-
condition computation's comparison constant.  Layer stacks, attention
chunk scans and MoE dispatch all live inside scans here, so without trip
counts the roofline would undercount by orders of magnitude.

Input is the SPMD-partitioned module text (per-device shapes), so port
totals are per-chip values.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from ..arch.tpu_v5e import CONSTANTS, VPU_OP_WEIGHT
from ..machine import MachineModel
from .parser import HloOp, parse_module

# ops that are pure metadata / no data movement of their own
_SKIP_KINDS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "async-done", "async-update", "copy-start", "copy-done",
})

_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_FUSION_CALL_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
# XLA annotates loop bounds on the while op itself:
#   backend_config={..."known_trip_count":{"n":"36"}...}
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')


@dataclass
class Cost:
    mxu_flops: float = 0.0
    vpu_flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)  # kind -> [count, bytes]

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.mxu_flops += other.mxu_flops * times
        self.vpu_flops += other.vpu_flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.ici_bytes += other.ici_bytes * times
        for k, (c, b) in other.collectives.items():
            ent = self.collectives.setdefault(k, [0.0, 0.0])
            ent[0] += c * times
            ent[1] += b * times

    def seconds(self, dtype: str = "bf16", ici_links: float = 1.0,
                constants: dict | None = None) -> dict[str, float]:
        """Per-port occupation in seconds.  ``constants`` are the
        hardware numbers (``MachineModel.constants`` of the accelerator
        model — ``peak_flops``/``vpu_flops``/``hbm_bw``/``ici_bw``);
        default: the built-in TPU v5e values."""
        c = CONSTANTS if constants is None else {**CONSTANTS, **constants}
        return {
            "MXU": self.mxu_flops / c["peak_flops"][dtype],
            "VPU": self.vpu_flops / c["vpu_flops"],
            "HBM": self.hbm_bytes / c["hbm_bw"],
            "ICI": self.ici_bytes / (c["ici_bw"] * ici_links),
        }


@dataclass
class RooflineTerms:
    """Roofline/ECM-style time bounds for one compiled step.

    ``bound_overlap`` is the paper's max-over-ports throughput bound under
    perfect overlap; ``critical_path_s`` is the dependency-chain analogue
    of the x86 loop-carried-dependency bound (ops on the entry
    computation's longest cost-weighted dependency chain cannot overlap
    with each other); ``bound_combined = max`` of the two is the headline
    estimate, mirroring ``max(port_bound, LCD)`` on the CPU side.
    """

    compute_s: float
    memory_s: float
    collective_s: float
    mxu_s: float = 0.0
    vpu_s: float = 0.0
    critical_path_s: float = 0.0
    # list-scheduled makespan (repro.core.sim.dag); 0.0 = not simulated
    sim_s: float = 0.0
    # memory level that priced memory_s when a working_set was given
    # ("" = the flat-HBM default, see docs/ecm.md)
    mem_level: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_overlap(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound_serial(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def bound_combined(self) -> float:
        """max(throughput bound, critical path) — the tighter estimate."""
        return max(self.bound_overlap, self.critical_path_s)

    @property
    def bound_sim(self) -> float:
        """The list-scheduled makespan when simulated (it satisfies
        ``bound_combined <= bound_sim <= bound_serial``), else
        ``bound_combined``."""
        return self.sim_s if self.sim_s > 0.0 else self.bound_combined

    @property
    def binding(self) -> str:
        """Which constraint produces ``bound_combined``."""
        return ("critical-path"
                if self.critical_path_s > self.bound_overlap + 1e-15
                else "throughput")


@dataclass
class HloAnalysis:
    terms: RooflineTerms
    flops: float                     # per device (MXU + VPU)
    mxu_flops: float
    hbm_bytes: float                 # per device
    ici_bytes: float                 # per device (link bytes)
    collective_breakdown: dict       # kind -> (count, bytes)
    op_rows: list                    # (text, {port: seconds})
    n_ops: int
    flop_dtype: str = "bf16"

    def render(self, top: int = 25) -> str:
        lines = [
            f"TPU v5e port-model analysis ({self.n_ops} entry ops, "
            f"dtype={self.flop_dtype})",
            f"  MXU     {self.terms.mxu_s * 1e3:12.3f} ms   "
            f"({self.mxu_flops / 1e12:.2f} TFLOP/device)",
            f"  VPU     {self.terms.vpu_s * 1e3:12.3f} ms",
            f"  HBM     {self.terms.memory_s * 1e3:12.3f} ms   "
            f"({self.hbm_bytes / 1e9:.2f} GB/device)",
            f"  ICI     {self.terms.collective_s * 1e3:12.3f} ms   "
            f"({self.ici_bytes / 1e9:.2f} GB link/device)",
            f"  bound   {self.terms.bound_overlap * 1e3:12.3f} ms "
            f"(perfect overlap) / {self.terms.bound_serial * 1e3:.3f} ms "
            f"(serial)",
            f"  chain   {self.terms.critical_path_s * 1e3:12.3f} ms "
            f"(critical path)",
            f"  predicted {self.terms.bound_combined * 1e3:10.3f} ms "
            f"= max(overlap, chain)   [{self.terms.binding}-bound]",
            f"  bottleneck: {self.terms.dominant}"
            + (f" (memory term priced at {self.terms.mem_level})"
               if self.terms.mem_level else ""),
        ]
        if self.terms.sim_s > 0.0:
            lines.insert(-1, f"  scheduled {self.terms.sim_s * 1e3:10.3f}"
                         f" ms (list-scheduled DAG simulation)")
        if self.collective_breakdown:
            lines.append("  collectives:")
            for k, (c, b) in sorted(self.collective_breakdown.items()):
                lines.append(f"    {k:24s} x{c:<8.0f} {b / 1e9:10.3f} GB")
        lines.append("  top ops by port occupation:")
        lines.append(f"  {'MXU[ms]':>9} {'VPU[ms]':>9} {'HBM[ms]':>9} "
                     f"{'ICI[ms]':>9}  op")
        for text, occ in self.op_rows[:top]:
            lines.append(
                f"  {occ.get('MXU', 0) * 1e3:9.4f} "
                f"{occ.get('VPU', 0) * 1e3:9.4f} "
                f"{occ.get('HBM', 0) * 1e3:9.4f} "
                f"{occ.get('ICI', 0) * 1e3:9.4f}  {text[:100]}")
        return "\n".join(lines)


def _dot_flops(op: HloOp) -> float:
    if not op.result_shapes or not op.operand_shapes:
        return 0.0
    m = _CONTRACT_RE.search(op.attrs)
    contract = 1
    if m and op.operand_shapes:
        lhs = op.operand_shapes[0]
        for idx in (int(x) for x in m.group(1).split(",") if x):
            if idx < len(lhs.dims):
                contract *= lhs.dims[idx]
    return 2.0 * op.result_shapes[0].elements * contract


def _elementwise_flops(op: HloOp,
                       weights: dict | None = None) -> float:
    w = (VPU_OP_WEIGHT if weights is None else weights).get(op.kind)
    if w is None:
        if op.kind in ("reduce", "reduce-window", "scatter", "gather",
                       "dynamic-update-slice", "dynamic-slice", "pad",
                       "broadcast", "reshape", "transpose", "copy",
                       "slice", "concatenate", "reverse", "clamp",
                       "map", "and", "or", "not", "xor", "abs", "negate",
                       "floor", "ceil", "sign", "is-finite", "iota",
                       "reduce-precision", "shift-left",
                       "shift-right-logical", "shift-right-arithmetic"):
            w = 1.0
        elif op.kind == "sort":
            w = 20.0  # ~log2(n) passes for typical dispatch sorts
        else:
            return 0.0
    n = op.result_shapes[0].elements if op.result_shapes else 0
    return w * n


def _collective_link_bytes(op: HloOp) -> float:
    """Ring-algorithm link bytes per device."""
    b = float(op.operand_bytes)
    g = max(op.group_size, 1)
    if g <= 1:
        return 0.0
    if op.kind.startswith("all-gather"):
        return b * (g - 1)
    if op.kind.startswith("all-reduce"):
        return 2.0 * b * (g - 1) / g
    if op.kind == "reduce-scatter":
        return b * (g - 1) / g
    if "all-to-all" in op.kind:
        return b * (g - 1) / g
    return b  # collective-permute


class _ModuleCost:
    def __init__(self, ops: list[HloOp], constants: dict | None = None):
        self.by_comp: dict[str, list[HloOp]] = {}
        self.by_name: dict[str, HloOp] = {}
        for o in ops:
            self.by_comp.setdefault(o.computation, []).append(o)
            self.by_name[o.name] = o
        self._memo: dict[tuple[str, bool], Cost] = {}
        self._weights = (constants or {}).get("vpu_op_weight",
                                              VPU_OP_WEIGHT)

    def _bf16_promoted(self, o: HloOp) -> bool:
        """XLA's CPU BFloat16Normalization promotes bf16 reducing
        collectives to f32, wrapping the operand in convert(bf16->f32).
        On the TPU target these run natively in bf16 — detect the
        wrapper and account the collective at bf16 width."""
        if not o.operand_shapes or o.operand_shapes[0].dtype != "f32":
            return False
        for nm in o.operand_names:
            prod = self.by_name.get(nm)
            if prod is not None and prod.kind == "convert" \
                    and prod.operand_shapes \
                    and prod.operand_shapes[0].dtype == "bf16":
                return True
        return False

    def while_trips(self, o: HloOp) -> float:
        """Loop bound: XLA's known_trip_count annotation when present,
        else the largest constant in the loop-condition computation
        (pre-optimization modules)."""
        m = _TRIP_RE.search(o.attrs)
        if m:
            return float(m.group(1))
        cond = _COND_RE.search(o.attrs)
        if not cond:
            return 1.0
        best = 1
        for co in self.by_comp.get(cond.group(1), ()):
            if co.kind == "constant":
                cm = re.match(r"\s*(\d+)\s*$", co.operands_text)
                if cm:
                    best = max(best, int(cm.group(1)))
            cm = _CONST_RE.search(co.attrs)
            if cm:
                best = max(best, int(cm.group(1)))
        return float(best)

    def op_cost(self, o: HloOp, in_fusion: bool) -> Cost:
        c = Cost()
        if o.kind in _SKIP_KINDS:
            return c
        if o.is_collective:
            link = _collective_link_bytes(o)
            if self._bf16_promoted(o):
                link *= 0.5     # native bf16 on the TPU target
            c.ici_bytes += link
            ent = c.collectives.setdefault(o.kind, [0.0, 0.0])
            ent[0] += 1
            ent[1] += link
            return c
        if o.kind == "dot":
            c.mxu_flops += _dot_flops(o)
            if not in_fusion:
                c.hbm_bytes += o.operand_bytes + o.result_bytes
            return c
        if o.kind == "fusion":
            m = _FUSION_CALL_RE.search(o.attrs)
            if m:
                c.add(self.comp_cost(m.group(1), in_fusion=True))
            if not in_fusion:
                c.hbm_bytes += self.fusion_io_bytes(
                    o, m.group(1) if m else None)
            return c
        if o.kind in ("dynamic-slice", "dynamic-update-slice") \
                and not in_fusion:
            # in-place slice traffic: only the slice moves, not the buffer
            if o.kind == "dynamic-slice":
                c.hbm_bytes += 2 * o.result_bytes
            else:
                upd = o.operand_shapes[1].bytes \
                    if len(o.operand_shapes) > 1 else o.result_bytes
                c.hbm_bytes += 2 * upd
            c.vpu_flops += _elementwise_flops(o, self._weights)
            return c
        if o.kind == "while":
            body = _BODY_RE.search(o.attrs)
            if body:
                c.add(self.comp_cost(body.group(1), in_fusion=False),
                      times=self.while_trips(o))
            return c
        if o.kind == "conditional":
            m = _BRANCH_RE.search(o.attrs)
            if m:
                branches = [b.strip().strip("%") for b in
                            m.group(1).split(",") if b.strip()]
                # account the most expensive branch
                costs = [self.comp_cost(b, in_fusion=False)
                         for b in branches]
                if costs:
                    c.add(max(costs, key=lambda x: x.mxu_flops
                              + x.vpu_flops + x.hbm_bytes))
            return c
        if o.kind in ("call", "custom-call", "async-start"):
            m = _FUSION_CALL_RE.search(o.attrs) or \
                re.search(r"to_apply=%?([\w.\-]+)", o.attrs)
            if m and m.group(1) in self.by_comp:
                c.add(self.comp_cost(m.group(1), in_fusion=in_fusion))
            elif not in_fusion:
                c.hbm_bytes += o.operand_bytes + o.result_bytes
            return c
        # plain op
        c.vpu_flops += _elementwise_flops(o, self._weights)
        if not in_fusion:
            c.hbm_bytes += o.operand_bytes + o.result_bytes
        return c

    def fusion_io_bytes(self, o: HloOp, body: str | None) -> float:
        """HBM traffic of a fusion: parameters consumed only via
        dynamic-slice count at slice size; a dynamic-update-slice root
        writes only the update (the target buffer is aliased in place).
        This matters enormously under lax.scan, where every layer reads
        its weights by slicing a stacked buffer and stashes residuals by
        update-slicing — naive operand+result accounting overcounts by
        the scan length."""
        if body is None or body not in self.by_comp:
            return float(o.operand_bytes + o.result_bytes)
        body_ops = self.by_comp[body]
        consumers: dict[str, list[HloOp]] = {}
        for b in body_ops:
            for nm in b.operand_names:
                consumers.setdefault(nm, []).append(b)
        total = 0.0
        root = None
        dus_targets: set[str] = set()
        for b in body_ops:
            if b.is_root:
                root = b
        if root is not None and root.kind == "dynamic-update-slice" \
                and root.operand_names:
            dus_targets.add(root.operand_names[0])
        for b in body_ops:
            if b.kind != "parameter":
                continue
            cons = consumers.get(b.name, [])
            if b.name in dus_targets and len(cons) == 1:
                continue  # aliased in-place output buffer: no read
            if cons and all(x.kind == "dynamic-slice" for x in cons):
                total += sum(x.result_bytes for x in cons)
            else:
                total += b.result_bytes
        if root is not None and root.kind == "dynamic-update-slice":
            upd = root.operand_shapes[1].bytes \
                if len(root.operand_shapes) > 1 else root.result_bytes
            total += upd
        else:
            total += o.result_bytes
        return total

    def comp_cost(self, name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        self._memo[key] = total  # break cycles
        for o in self.by_comp.get(name, ()):
            total.add(self.op_cost(o, in_fusion))
        return total


def _critical_path_seconds(mc: _ModuleCost, entry_name: str,
                           flop_dtype: str, ici_links: float,
                           constants: dict | None = None) -> float:
    """Longest cost-weighted dependency chain through the entry ops.

    The TPU analogue of the x86 loop-carried-dependency bound: each entry
    op weighs its own max-over-ports seconds (while bodies already
    multiplied by trip count), and ops chained through operands cannot
    overlap.  HLO lists definitions before uses within a computation, so
    a single forward pass suffices.
    """
    finish: dict[str, float] = {}
    best = 0.0
    for o in mc.by_comp.get(entry_name, ()):
        secs = mc.op_cost(o, in_fusion=False).seconds(
            flop_dtype, ici_links, constants)
        w = max(secs.values()) if secs else 0.0
        start = 0.0
        for nm in o.operand_names:
            start = max(start, finish.get(nm, 0.0))
        finish[o.name] = start + w
        best = max(best, finish[o.name])
    return best


def _scheduled_seconds(mc: _ModuleCost, entry_name: str,
                       flop_dtype: str, ici_links: float,
                       constants: dict | None = None) -> float:
    """List-scheduled makespan of the entry computation: the DAG
    analogue of the cycle-level x86 simulator (``repro.core.sim.dag``).
    Refines ``max(bound_overlap, critical_path)`` by modelling port
    contention *and* dependency chains at once."""
    from ..sim.dag import DagNode, schedule_dag

    nodes = []
    for o in mc.by_comp.get(entry_name, ()):
        secs = mc.op_cost(o, in_fusion=False).seconds(
            flop_dtype, ici_links, constants)
        occ = {k: v for k, v in secs.items() if v > 0.0}
        nodes.append(DagNode(name=o.name, occupation=occ,
                             deps=tuple(o.operand_names)))
    return schedule_dag(nodes).makespan


def _select_mem_level(constants: dict,
                      working_set: float) -> tuple[str, float]:
    """Innermost ``constants["mem_levels"]`` entry holding the working
    set (a ``null`` size = unbounded), as ``(name, bytes/s)``.  Falls
    back to the flat ``hbm_bw`` when the model declares no levels."""
    levels = constants.get("mem_levels") or []
    for lv in levels:
        size = lv.get("size")
        if size is None or working_set <= size:
            return str(lv["name"]), float(lv["bw"])
    if levels:                       # overflows even the last bounded level
        return str(levels[-1]["name"]), float(levels[-1]["bw"])
    return "", float(constants["hbm_bw"])


def analyze_hlo(text: str, *, ici_links: float = 1.0,
                flop_dtype: str = "bf16",
                simulate: bool = False,
                machine: "str | MachineModel | None" = None,
                working_set: float | None = None,
                ) -> HloAnalysis:
    """Port-model analysis of a compiled HLO module.

    ``machine`` selects the accelerator: an arch id/alias resolved
    through the default registry or a :class:`MachineModel` whose
    ``constants`` carry ``peak_flops`` / ``vpu_flops`` / ``hbm_bw`` /
    ``ici_bw`` (default: the built-in ``"tpu_v5e"`` model), so a
    derived or JSON-loaded accelerator variant reprices the whole
    analysis without code changes.  ``working_set`` (bytes) selects the
    memory level pricing the memory roofline term from the model's
    ``constants["mem_levels"]`` table — the accelerator-side analogue
    of ``AnalysisRequest.working_set`` (docs/ecm.md); ``None`` keeps
    the flat-HBM assumption bit-exactly.
    """
    constants = None
    if machine is not None:
        if isinstance(machine, str):
            from ..arch.registry import get_model
            machine = get_model(machine)
        # merge over the TPU defaults: a derived model overriding a
        # single constant (the documented workflow) must not KeyError
        # on the ones it didn't touch
        constants = {**CONSTANTS, **machine.constants}
    mem_level = ""
    if working_set is not None:
        constants = dict(CONSTANTS if constants is None else constants)
        mem_level, bw = _select_mem_level(constants, working_set)
        constants["hbm_bw"] = bw
    ops, entry_name = parse_module(text)
    mc = _ModuleCost(ops, constants)

    if not entry_name or entry_name not in mc.by_comp:
        # fall back: a computation nothing else calls
        called: set[str] = set()
        for o in ops:
            for rx in (_FUSION_CALL_RE, _COND_RE, _BODY_RE):
                m = rx.search(o.attrs)
                if m:
                    called.add(m.group(1))
            m = _BRANCH_RE.search(o.attrs)
            if m:
                called.update(b.strip().strip("%")
                              for b in m.group(1).split(","))
        comp_names = list(mc.by_comp)
        uncalled = [n for n in comp_names if n not in called]
        entry_name = uncalled[0] if uncalled else comp_names[0]

    total = mc.comp_cost(entry_name, in_fusion=False)
    secs = total.seconds(flop_dtype, ici_links, constants)

    # per-op rows for the report (entry level; whiles aggregated)
    rows = []
    for o in mc.by_comp.get(entry_name, ()):
        c = mc.op_cost(o, in_fusion=False)
        occ = c.seconds(flop_dtype, ici_links, constants)
        occ = {k: v for k, v in occ.items() if v > 0}
        if not occ:
            continue
        label = o.kind
        if o.kind == "while":
            label = f"while x{mc.while_trips(o):.0f}"
        rows.append((f"{label} {o.name}", occ))
    rows.sort(key=lambda r: -max(r[1].values()))

    terms = RooflineTerms(
        compute_s=secs["MXU"] + secs["VPU"], memory_s=secs["HBM"],
        collective_s=secs["ICI"], mxu_s=secs["MXU"], vpu_s=secs["VPU"],
        critical_path_s=_critical_path_seconds(
            mc, entry_name, flop_dtype, ici_links, constants),
        sim_s=_scheduled_seconds(mc, entry_name, flop_dtype, ici_links,
                                 constants)
        if simulate else 0.0,
        mem_level=mem_level)
    return HloAnalysis(
        terms=terms, flops=total.mxu_flops + total.vpu_flops,
        mxu_flops=total.mxu_flops,
        hbm_bytes=total.hbm_bytes, ici_bytes=total.ici_bytes,
        collective_breakdown={k: (v[0], v[1])
                              for k, v in total.collectives.items()},
        op_rows=rows, n_ops=len(mc.by_comp.get(entry_name, ())),
        flop_dtype=flop_dtype)
