"""Kernel extraction from assembly (paper Sec. III).

Supports the IACA byte markers::

    movl $111, %ebx        movl $222, %ebx
    .byte 100,103,144      .byte 100,103,144

and, when no markers are present, innermost-loop detection: the body between
a label and the last backward conditional jump to it.
"""
from __future__ import annotations

import re

from .isa import Instruction, is_branch, parse_assembly

_MARKER_BYTES_RE = re.compile(r"^\s*\.byte\s+100\s*,\s*103\s*,\s*144\s*$")
_MARKER_MOV_RE = re.compile(
    r"^\s*mov[lq]?\s+\$(111|222)\s*,\s*%[er]bx\s*$")


def find_marked_region(source: str) -> tuple[int, int] | None:
    """Return (start_line, end_line) (exclusive) of the IACA-marked region."""
    start = end = None
    pending: str | None = None
    for lineno, raw in enumerate(source.splitlines(), start=1):
        line = raw.split("#")[0].strip()
        mm = _MARKER_MOV_RE.match(line)
        if mm:
            pending = mm.group(1)
            continue
        if _MARKER_BYTES_RE.match(line) and pending:
            if pending == "111":
                start = lineno
            elif pending == "222":
                end = lineno - 1  # exclude the marker's own mov line
            pending = None
            continue
        pending = None
    if start is not None and end is not None and end >= start:
        return start, end
    return None


def _marked_lines(source: str) -> str | None:
    region = find_marked_region(source)
    if region is None:
        return None
    start, end = region
    lines = source.splitlines()
    body = lines[start:end - 1]  # drop the 'movl $222' line preceding end
    return "\n".join(body)


def detect_innermost_loop(instrs: list[Instruction]) -> list[Instruction]:
    """Innermost loop = shortest (label ... backward-jump-to-label) span."""
    label_pos: dict[str, int] = {}
    for idx, ins in enumerate(instrs):
        if ins.label:
            label_pos.setdefault(ins.label, idx)
    best: tuple[int, int] | None = None
    for idx, ins in enumerate(instrs):
        if not is_branch(ins.mnemonic) or not ins.operands:
            continue
        target = ins.operands[0].text.strip()
        tpos = label_pos.get(target)
        if tpos is None or tpos > idx:
            continue  # forward jump / unknown target
        span = (tpos, idx)
        if best is None or (span[1] - span[0]) < (best[1] - best[0]):
            best = span
    if best is None:
        return instrs
    return instrs[best[0]:best[1] + 1]


def extract_kernel(source: str, syntax: str = "att") -> list[Instruction]:
    """Marked region if present, else innermost detected loop."""
    marked = _marked_lines(source)
    if marked is not None:
        return parse_assembly(marked, syntax=syntax)
    return detect_innermost_loop(parse_assembly(source, syntax=syntax))
