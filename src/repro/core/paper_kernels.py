"""Assembly kernels and expected numbers from the paper (Tables I-VII).

Kernels printed verbatim in the paper: triad SKL -O3 (Table II), triad Zen
-O3 (Table IV), pi SKL -O3 (Table VI), pi SKL -O2 (Table VII), pi -O1
(Sec. III-B text).  The -O1/-O2 triad and the Zen-compiled pi -O3 listings
are not printed; they are reconstructed from GCC 7.2 codegen shape and
validated against the paper's *predicted* cycle counts (DESIGN.md Sec. 7).

All kernels are wrapped in IACA byte markers to exercise the extractor.
"""
from __future__ import annotations

MARK_START = "movl $111, %ebx\n.byte 100,103,144\n"
MARK_END = "movl $222, %ebx\n.byte 100,103,144\n"


def marked(body: str) -> str:
    return MARK_START + body.strip("\n") + "\n" + MARK_END


# --------------------------------------------------------------------- #
# Schoenauer triad: a[j] = b[j] + c[j] * d[j]     (paper Sec. III-A)
# --------------------------------------------------------------------- #

# Table II listing (compiled for Skylake, -O3, AVX, unroll 4)
TRIAD_SKL_O3 = marked("""
.L10:
        vmovapd (%r15,%rax), %ymm0
        vmovapd (%r12,%rax), %ymm3
        addl    $1, %ecx
        vfmadd132pd     0(%r13,%rax), %ymm3, %ymm0
        vmovapd %ymm0, (%r14,%rax)
        addq    $32, %rax
        cmpl    %ecx, %r10d
        ja      .L10
""")

# Table IV listing (compiled for Zen, -O3, 128-bit SSE/AVX, unroll 2)
TRIAD_ZEN_O3 = marked("""
.L10:
        vmovaps 0(%r13,%rax), %xmm0
        vmovaps (%r15,%rax), %xmm3
        incl    %esi
        vfmadd132pd     (%r14,%rax), %xmm3, %xmm0
        vmovaps %xmm0, (%r12,%rax)
        addq    $16, %rax
        cmpl    %esi, %ebx
        ja      .L10
""")

# Reconstructed scalar triad (-O1/-O2 on both compilers; unroll 1)
TRIAD_SCALAR = marked("""
.L3:
        vmovsd  (%rcx,%rax,8), %xmm0
        vmulsd  (%rdx,%rax,8), %xmm0, %xmm0
        vaddsd  (%rsi,%rax,8), %xmm0, %xmm0
        vmovsd  %xmm0, (%rdi,%rax,8)
        addq    $1, %rax
        cmpq    %rbp, %rax
        jne     .L3
""")

# --------------------------------------------------------------------- #
# pi by rectangular integration (paper Sec. III-B)
# --------------------------------------------------------------------- #

# -O1 listing (printed in Sec. III-B); the sum lives on the stack ->
# loop-carried store/load chain, port model underestimates (Table V)
PI_O1 = marked("""
.L2:
        vxorpd  %xmm0, %xmm0, %xmm0
        vcvtsi2sd       %eax, %xmm0, %xmm0
        vaddsd  %xmm4, %xmm0, %xmm0
        vmulsd  %xmm3, %xmm0, %xmm0
        vmulsd  %xmm0, %xmm0, %xmm0
        vaddsd  %xmm2, %xmm0, %xmm0
        vdivsd  %xmm0, %xmm1, %xmm0
        vaddsd  (%rsp), %xmm0, %xmm5
        vmovsd  %xmm5, (%rsp)
        addl    $1, %eax
        cmpl    $1000000000, %eax
        jne     .L2
""")

# -O2 listing (Table VII)
PI_O2 = marked("""
.L2:
        vxorpd  %xmm0, %xmm0, %xmm0
        vcvtsi2sd       %eax, %xmm0, %xmm0
        addl    $1, %eax
        vaddsd  %xmm5, %xmm0, %xmm0
        vmulsd  %xmm3, %xmm0, %xmm0
        vfmadd132sd     %xmm0, %xmm4, %xmm0
        vdivsd  %xmm0, %xmm2, %xmm0
        vaddsd  %xmm0, %xmm1, %xmm1
        cmpl    $1000000000, %eax
        jne     .L2
""")

# -O3 AVX listing compiled for Skylake (Table VI; unroll 8)
PI_SKL_O3 = marked("""
.L2:
        vextracti128    $0x1, %ymm2, %xmm1
        vcvtdq2pd       %xmm2, %ymm0
        vaddpd  %ymm7, %ymm0, %ymm0
        addl    $1, %eax
        vcvtdq2pd       %xmm1, %ymm1
        vaddpd  %ymm7, %ymm1, %ymm1
        vpaddd  %ymm8, %ymm2, %ymm2
        vmulpd  %ymm6, %ymm0, %ymm0
        vmulpd  %ymm6, %ymm1, %ymm1
        vfmadd132pd     %ymm0, %ymm5, %ymm0
        vfmadd132pd     %ymm1, %ymm5, %ymm1
        vdivpd  %ymm0, %ymm4, %ymm0
        vdivpd  %ymm1, %ymm4, %ymm1
        vaddpd  %ymm1, %ymm0, %ymm0
        vaddpd  %ymm0, %ymm3, %ymm3
        cmpl    $125000000, %eax
        jne     .L2
""")

# Reconstructed -O3 for Zen (znver1 vectorizes 128-bit; unroll 2)
PI_ZEN_O3 = marked("""
.L2:
        vcvtdq2pd       %xmm2, %xmm0
        vaddpd  %xmm6, %xmm0, %xmm0
        vpaddd  %xmm7, %xmm2, %xmm2
        addl    $1, %eax
        vmulpd  %xmm5, %xmm0, %xmm0
        vfmadd132pd     %xmm0, %xmm4, %xmm0
        vdivpd  %xmm0, %xmm3, %xmm0
        vaddpd  %xmm0, %xmm1, %xmm1
        cmpl    $500000000, %eax
        jne     .L2
""")

# --------------------------------------------------------------------- #
# Expected values from the paper
# --------------------------------------------------------------------- #

# Table I: OSACA/IACA triad predictions per *assembly* iteration.
# (compiled_for, flag): (unroll, osaca_zen, osaca_skl, iaca_skl|None)
TABLE1 = {
    ("skl", "O1"): (1, 2.00, 2.00, 2.24),
    ("skl", "O2"): (1, 2.00, 2.00, 2.00),
    ("skl", "O3"): (4, 4.00, 2.00, 2.21),
    ("zen", "O1"): (1, 2.00, 2.00, 2.24),
    ("zen", "O2"): (1, 2.00, 2.00, 2.00),
    ("zen", "O3"): (2, 2.00, 2.00, 2.21),
}

TRIAD_KERNELS = {
    ("skl", "O1"): TRIAD_SCALAR, ("skl", "O2"): TRIAD_SCALAR,
    ("skl", "O3"): TRIAD_SKL_O3,
    ("zen", "O1"): TRIAD_SCALAR, ("zen", "O2"): TRIAD_SCALAR,
    ("zen", "O3"): TRIAD_ZEN_O3,
}

# Table II: per-port totals, SKL model on TRIAD_SKL_O3
TABLE2_TOTALS = {"0": 1.25, "0DV": 0.0, "1": 1.25, "2": 2.00, "3": 2.00,
                 "4": 1.00, "5": 0.75, "6": 0.75, "7": 0.00}

# Table III: measured cy/it (executed_on, compiled_for, flag) -> cy/it
TABLE3_MEASURED = {
    ("zen", "zen", "O1"): 2.00, ("zen", "zen", "O2"): 2.00,
    ("zen", "zen", "O3"): 1.02,
    ("skl", "zen", "O1"): 2.03, ("skl", "zen", "O2"): 2.04,
    ("skl", "zen", "O3"): 1.03,
    ("zen", "skl", "O1"): 2.01, ("zen", "skl", "O2"): 2.01,
    ("zen", "skl", "O3"): 1.01,
    ("skl", "skl", "O1"): 2.04, ("skl", "skl", "O2"): 2.03,
    ("skl", "skl", "O3"): 0.53,
}

# Table IV: per-port totals, Zen model on TRIAD_ZEN_O3 (visible occupation;
# the first load's AGU uops are hidden behind the store)
TABLE4_TOTALS = {"0": 1.25, "1": 1.25, "2": 0.75, "3": 0.75, "3DV": 0.0,
                 "4": 0.75, "5": 0.75, "6": 0.75, "7": 0.75,
                 "8": 2.00, "9": 2.00}

# Table V: pi benchmark, cy per *source* iteration
# (arch, flag): (unroll, iaca, osaca, measured)
TABLE5 = {
    ("skl", "O1"): (1, 3.91, 4.75, 9.02),
    ("skl", "O2"): (1, 4.00, 4.25, 4.00),
    ("skl", "O3"): (8, 2.00, 2.00, 2.06),
    ("zen", "O1"): (1, None, 4.00, 11.48),
    ("zen", "O2"): (1, None, 4.00, 4.96),
    ("zen", "O3"): (2, None, 2.00, 2.44),
}

PI_KERNELS = {
    ("skl", "O1"): PI_O1, ("skl", "O2"): PI_O2, ("skl", "O3"): PI_SKL_O3,
    ("zen", "O1"): PI_O1, ("zen", "O2"): PI_O2, ("zen", "O3"): PI_ZEN_O3,
}

# Table VI: per-port totals, SKL model on PI_SKL_O3
TABLE6_TOTALS = {"0": 8.83, "0DV": 16.0, "1": 4.83, "2": 0.0, "3": 0.0,
                 "4": 0.0, "5": 3.83, "6": 0.50, "7": 0.0}

# Table VII: per-port totals, SKL model on PI_O2
TABLE7_TOTALS = {"0": 4.25, "0DV": 4.00, "1": 3.25, "2": 0.0, "3": 0.0,
                 "4": 0.0, "5": 1.75, "6": 0.75, "7": 0.0}

# Sec. II-C FMA example: measured latency / reciprocal TP
FMA_EXAMPLE = {
    "zen": {"latency": 5.0, "throughput": 0.5, "ports": ("0", "1", "8", "9")},
    "skl": {"latency": 4.0, "throughput": 0.5, "ports": ("0", "1", "2", "3")},
}
