"""x86 assembly parsing (AT&T and Intel syntax) into instruction forms.

The *instruction form* (paper Sec. II) is a mnemonic together with its
operand-type signature, e.g. ``vfmadd132pd (%rax),%xmm0,%xmm0`` (AT&T)
==> form ``vfmadd132pd xmm_xmm_mem`` in Intel (destination-first) order,
which is the order used by the OSACA database and by ibench.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


# --------------------------------------------------------------------------
# Registers
# --------------------------------------------------------------------------

_GPR64 = {"rax", "rbx", "rcx", "rdx", "rsi", "rdi", "rbp", "rsp",
          *(f"r{i}" for i in range(8, 16))}
_GPR32 = {"eax", "ebx", "ecx", "edx", "esi", "edi", "ebp", "esp",
          *(f"r{i}d" for i in range(8, 16))}
_GPR16 = {"ax", "bx", "cx", "dx", "si", "di", "bp", "sp",
          *(f"r{i}w" for i in range(8, 16))}
_GPR8 = {"al", "bl", "cl", "dl", "ah", "bh", "ch", "dh", "sil", "dil",
         "bpl", "spl", *(f"r{i}b" for i in range(8, 16))}


def register_class(name: str) -> str:
    """Map a register name (no ``%``) to its operand-type token."""
    n = name.lower()
    if n.startswith("zmm"):
        return "zmm"
    if n.startswith("ymm"):
        return "ymm"
    if n.startswith("xmm"):
        return "xmm"
    if n.startswith("k") and n[1:].isdigit():
        return "k"
    if n in _GPR64:
        return "r64"
    if n in _GPR32:
        return "r32"
    if n in _GPR16:
        return "r16"
    if n in _GPR8:
        return "r8"
    if n in ("rip", "eip"):
        return "rip"
    if n.startswith("st"):
        return "st"
    return "reg"


@dataclass(frozen=True)
class Operand:
    kind: str                 # "reg" | "mem" | "imm" | "label"
    text: str                 # original text
    reg: str | None = None    # register name for kind == "reg"
    # memory decomposition (paper: base/offset/index/scale detection)
    base: str | None = None
    index: str | None = None
    scale: int = 1
    displacement: int = 0

    @property
    def type_token(self) -> str:
        if self.kind == "reg":
            return register_class(self.reg or "")
        if self.kind == "mem":
            return "mem"
        if self.kind == "imm":
            return "imm"
        return "label"

    @property
    def is_simple_address(self) -> bool:
        """Base-plus-displacement only (relevant for SKL port-7 AGU)."""
        return self.kind == "mem" and self.index is None


@dataclass(frozen=True)
class Instruction:
    mnemonic: str                     # normalised (AT&T size suffix stripped)
    raw_mnemonic: str
    operands: tuple[Operand, ...]     # in *Intel* order (destination first)
    text: str                         # original source line
    line: int = 0
    label: str | None = None          # label immediately preceding

    @property
    def signature(self) -> tuple[str, ...]:
        return tuple(op.type_token for op in self.operands)

    @property
    def form(self) -> str:
        sig = "_".join(self.signature)
        return f"{self.mnemonic}-{sig}" if sig else self.mnemonic

    def reads_memory(self) -> bool:
        # Intel order: destination first; mem source = mem in non-dest slot,
        # or a dest mem for RMW instructions (handled by the DB entry).
        return any(op.kind == "mem" for op in self.operands[1:])

    def writes_memory(self) -> bool:
        return bool(self.operands) and self.operands[0].kind == "mem"


# --------------------------------------------------------------------------
# Mnemonic normalisation
# --------------------------------------------------------------------------

# AT&T size-suffixed integer mnemonics: addl/addq/cmpl/... -> add/cmp/...
_SUFFIXABLE = {
    "add", "sub", "cmp", "test", "mov", "inc", "dec", "and", "or", "xor",
    "neg", "not", "shl", "shr", "sar", "sal", "lea", "imul", "mul", "push",
    "pop", "adc", "sbb", "bt", "movz", "movs",
}

_BRANCHES = {
    "jmp", "ja", "jae", "jb", "jbe", "jc", "je", "jg", "jge", "jl", "jle",
    "jna", "jnae", "jnb", "jnbe", "jnc", "jne", "jng", "jnge", "jnl",
    "jnle", "jno", "jnp", "jns", "jnz", "jo", "jp", "js", "jz", "loop",
}


def is_branch(mnemonic: str) -> bool:
    return mnemonic in _BRANCHES


def normalise_mnemonic(raw: str) -> str:
    m = raw.lower()
    if m in _BRANCHES:
        return m
    # movzbl / movswq etc.
    if m.startswith(("movz", "movs")) and len(m) <= 6 and not m.startswith(
            ("movss", "movsd", "movsh")):
        return m[:4]
    if m and m[-1] in "bwlq":
        base = m[:-1]
        if base in _SUFFIXABLE:
            return base
    return m


# --------------------------------------------------------------------------
# Line parsing
# --------------------------------------------------------------------------

_LABEL_RE = re.compile(r"^\s*([.\w$@]+):")
_MEM_ATT_RE = re.compile(
    r"^\s*(?P<disp>[-+]?(?:0x[0-9a-fA-F]+|\d+))?\s*"
    r"\(\s*(?:%(?P<base>\w+))?\s*(?:,\s*%(?P<index>\w+)\s*(?:,\s*(?P<scale>[1248]))?)?\s*\)\s*$")
_MEM_INTEL_RE = re.compile(
    r"^\s*(?:[a-z]+\s+ptr\s+)?\[(?P<body>[^\]]+)\]\s*$", re.I)


def _parse_int(s: str) -> int:
    s = s.strip()
    neg = s.startswith("-")
    s = s.lstrip("+-")
    val = int(s, 16) if s.lower().startswith("0x") else int(s)
    return -val if neg else val


def _split_operands(text: str) -> list[str]:
    """Split on commas not inside parens/brackets."""
    out, depth, cur = [], 0, []
    for ch in text:
        if ch in "([":
            depth += 1
        elif ch in ")]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return out


def parse_operand_att(text: str) -> Operand:
    t = text.strip()
    if t.startswith("$"):
        return Operand("imm", t)
    if t.startswith("%"):
        return Operand("reg", t, reg=t[1:].rstrip(")"))
    if t.startswith("*"):  # indirect branch target
        return Operand("mem", t)
    m = _MEM_ATT_RE.match(t)
    if m:
        return Operand(
            "mem", t,
            base=m.group("base"), index=m.group("index"),
            scale=int(m.group("scale") or 1),
            displacement=_parse_int(m.group("disp")) if m.group("disp") else 0)
    if re.match(r"^[-+]?(0x[0-9a-fA-F]+|\d+)$", t):
        # bare displacement (absolute address)
        return Operand("mem", t, displacement=_parse_int(t))
    return Operand("label", t)


def parse_operand_intel(text: str) -> Operand:
    t = text.strip()
    m = _MEM_INTEL_RE.match(t)
    if m:
        body = m.group("body").replace(" ", "")
        base = index = None
        scale, disp = 1, 0
        for part in re.split(r"(?=[+-])", body):
            if not part:
                continue
            sign = -1 if part.startswith("-") else 1
            p = part.lstrip("+-")
            if "*" in p:
                r, s = p.split("*")
                index, scale = r, int(s)
            elif re.match(r"^(0x[0-9a-fA-F]+|\d+)$", p):
                disp += sign * _parse_int(p)
            elif base is None:
                base = p
            else:
                index = p
        return Operand("mem", t, base=base, index=index, scale=scale,
                       displacement=disp)
    if re.match(r"^[-+]?(0x[0-9a-fA-F]+|\d+)$", t):
        return Operand("imm", t)
    cls = register_class(t)
    if cls != "reg" or t.lower() in _GPR64 | _GPR32 | _GPR16 | _GPR8:
        return Operand("reg", t, reg=t)
    return Operand("label", t)


_DIRECTIVE_PREFIXES = (".", "#")


def parse_assembly(source: str, syntax: str = "att") -> list[Instruction]:
    """Parse an assembly listing into :class:`Instruction` objects.

    Labels and directives are retained as context; comments stripped.
    Operand order is canonicalised to Intel (destination-first) order.
    """
    instructions: list[Instruction] = []
    pending_label: str | None = None
    for lineno, raw in enumerate(source.splitlines(), start=1):
        line = raw.split("#")[0].split(";")[0].strip()
        if not line:
            continue
        lm = _LABEL_RE.match(line)
        if lm:
            pending_label = lm.group(1)
            line = line[lm.end():].strip()
            if not line:
                continue
        if line.startswith(_DIRECTIVE_PREFIXES):
            continue
        parts = line.split(None, 1)
        raw_mnemonic = parts[0].lower()
        if raw_mnemonic in ("lock", "rep", "repz", "repnz", "data16"):
            parts = parts[1].split(None, 1)
            raw_mnemonic = parts[0].lower()
        operand_text = parts[1] if len(parts) > 1 else ""
        tokens = _split_operands(operand_text) if operand_text else []
        if syntax == "att":
            ops = [parse_operand_att(t) for t in tokens]
            ops.reverse()  # AT&T source...dest -> Intel dest...source
        else:
            ops = [parse_operand_intel(t) for t in tokens]
        mnemonic = normalise_mnemonic(raw_mnemonic)
        instructions.append(Instruction(
            mnemonic=mnemonic, raw_mnemonic=raw_mnemonic,
            operands=tuple(ops), text=line, line=lineno,
            label=pending_label))
        pending_label = None
    return instructions
