"""repro.core — the paper's contribution: OSACA-style static throughput
prediction via a port model, for x86 loop kernels (faithful layer) and for
compiled JAX/HLO programs on TPU (adaptation layer, see repro.core.hlo)."""
from __future__ import annotations

from .analysis import AnalysisResult, analyze
from .database import E, InstrForm, InstructionDB, widen_double_pumped
from .engine import AnalysisRequest, AnalysisService, default_service
from .isa import Instruction, parse_assembly
from .kernel import extract_kernel
from .latency import LatencyResult, analyze_latency, dependency_edges
from .ports import PipelineParams, PortModel, U, Uop
from .sim import (SimProgram, SimResult, compile_program, simulate,
                  simulate_kernel, simulate_many)

__all__ = [
    "AnalysisRequest", "AnalysisResult", "AnalysisService", "analyze",
    "analyze_latency", "default_service", "dependency_edges",
    "extract_kernel", "parse_assembly", "Instruction", "InstructionDB",
    "InstrForm", "E", "LatencyResult", "PipelineParams", "PortModel",
    "SimProgram", "SimResult", "U", "Uop", "compile_program", "simulate",
    "simulate_kernel", "simulate_many", "widen_double_pumped",
]
