"""repro.core — the paper's contribution: OSACA-style static throughput
prediction via a port model, for x86 loop kernels (faithful layer) and for
compiled JAX/HLO programs on TPU (adaptation layer, see repro.core.hlo)."""
from __future__ import annotations

from .analysis import AnalysisResult, analyze
from .database import E, InstrForm, InstructionDB, widen_double_pumped
from .isa import Instruction, parse_assembly
from .kernel import extract_kernel
from .latency import analyze_latency
from .ports import PortModel, U, Uop

__all__ = [
    "AnalysisResult", "analyze", "analyze_latency", "extract_kernel",
    "parse_assembly", "Instruction", "InstructionDB", "InstrForm", "E",
    "PortModel", "U", "Uop", "widen_double_pumped",
]
