"""repro.core — the paper's contribution: OSACA-style static throughput
prediction via a port model, for x86 loop kernels (faithful layer) and for
compiled JAX/HLO programs on TPU (adaptation layer, see repro.core.hlo)."""
from __future__ import annotations

from .analysis import AnalysisResult, analyze
from .arch.registry import (ArchRegistry, UnknownArchError,
                            default_registry, get_model)
from .database import E, InstrForm, InstructionDB, widen_double_pumped
from .degrade import (LADDER, BreakerBoard, BreakerConfig, CircuitBreaker,
                      HealthRouter, RoutePlan, RouterConfig, validate_sims)
from .engine import AnalysisRequest, AnalysisService, default_service
from .faults import (FaultAbort, FaultInjector, FaultPlan, FaultSpec,
                     InjectedFault, ResultValidationError)
from .isa import Instruction, parse_assembly
from .kernel import extract_kernel
from .latency import LatencyResult, analyze_latency, dependency_edges
from .machine import BenchRecord, MachineModel, as_database
from .mem import (AccessStream, CacheLevel, EcmResult, MemoryHierarchy,
                  TrafficResult, compose_ecm, extract_streams,
                  predict_traffic, simulate_traffic)
from .ports import PipelineParams, PortModel, U, Uop
from .sim import (SimProgram, SimResult, compile_program, simulate,
                  simulate_kernel, simulate_many)

__all__ = [
    "AccessStream", "AnalysisRequest", "AnalysisResult",
    "AnalysisService", "analyze", "analyze_latency", "ArchRegistry",
    "as_database", "BenchRecord", "CacheLevel", "compose_ecm",
    "BreakerBoard", "BreakerConfig", "CircuitBreaker",
    "default_registry", "default_service", "dependency_edges",
    "EcmResult", "extract_kernel", "extract_streams", "FaultAbort",
    "FaultInjector", "FaultPlan", "FaultSpec", "get_model",
    "HealthRouter", "InjectedFault", "LADDER", "ResultValidationError",
    "RoutePlan", "RouterConfig", "validate_sims",
    "parse_assembly", "Instruction", "InstructionDB", "InstrForm", "E",
    "LatencyResult", "MachineModel", "MemoryHierarchy",
    "PipelineParams", "PortModel", "predict_traffic", "SimProgram",
    "SimResult", "simulate_traffic", "TrafficResult", "U",
    "UnknownArchError", "Uop", "compile_program", "simulate",
    "simulate_kernel", "simulate_many", "widen_double_pumped",
]
