"""repro.core — the paper's contribution: OSACA-style static throughput
prediction via a port model, for x86 loop kernels (faithful layer) and for
compiled JAX/HLO programs on TPU (adaptation layer, see repro.core.hlo)."""
from __future__ import annotations

from .analysis import AnalysisResult, analyze
from .arch.registry import (ArchRegistry, UnknownArchError,
                            default_registry, get_model)
from .database import E, InstrForm, InstructionDB, widen_double_pumped
from .engine import AnalysisRequest, AnalysisService, default_service
from .isa import Instruction, parse_assembly
from .kernel import extract_kernel
from .latency import LatencyResult, analyze_latency, dependency_edges
from .machine import BenchRecord, MachineModel, as_database
from .ports import PipelineParams, PortModel, U, Uop
from .sim import (SimProgram, SimResult, compile_program, simulate,
                  simulate_kernel, simulate_many)

__all__ = [
    "AnalysisRequest", "AnalysisResult", "AnalysisService", "analyze",
    "analyze_latency", "ArchRegistry", "as_database", "BenchRecord",
    "default_registry", "default_service", "dependency_edges",
    "extract_kernel", "get_model", "parse_assembly", "Instruction",
    "InstructionDB", "InstrForm", "E", "LatencyResult", "MachineModel",
    "PipelineParams", "PortModel", "SimProgram", "SimResult", "U",
    "UnknownArchError", "Uop", "compile_program", "simulate",
    "simulate_kernel", "simulate_many", "widen_double_pumped",
]
