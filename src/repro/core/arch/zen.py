"""AMD Zen (family 17h) port model + instruction database (paper Fig. 3).

Zen splits into an FP cluster (pipes 0-3), an integer cluster (ALUs 4-7) and
two AGU/load-store ports (8, 9).  Peculiarities modelled per the paper:

* FP divide uses pipe 3 plus a divider pipe ``3DV`` (paper Sec. II-C note).
* 256-bit AVX executes as two 128-bit halves -> all ymm forms are derived by
  doubling the xmm uop occupation (paper Sec. III-A).
* Only two AGUs serve loads AND stores: a store occupies both port 8 and 9
  for its address generation, but one load can execute in its shadow; OSACA
  hides the first load behind a store (paper Sec. III-A, Table IV).

Numbers from the paper's own benchmarks where stated (FMA lat 5, add lat 3,
FMA/mul on pipes 0|1, add on 2|3, loads 8|9) and AMD SOG [12] / Agner [11]
otherwise.
"""
from __future__ import annotations

import functools

from ..database import E, InstrForm, InstructionDB, widen_double_pumped
from ..machine import MachineModel
from ..mem.hierarchy import CacheLevel, MemoryHierarchy
from ..ports import PipelineParams, PortModel, U

ZEN = PortModel(
    name="AMD Zen",
    ports=("0", "1", "2", "3", "3DV", "4", "5", "6", "7", "8", "9"),
    divider_ports=frozenset({"3DV"}),
    store_hides_load=True,
    unit="cy",
    frequency_hz=1.8e9,
    # Store->load forwarding latency for the LCD analysis; calibrated so the
    # pi -O1 stack-accumulator chain (SLF + vaddsd lat 3) tracks the
    # measured 11.48 cy/it (paper Table V).
    store_forward_latency=8.5,
    # Front-end / OoO window for the cycle-level simulator (AMD SOG for
    # family 17h [12]): 6 micro-ops dispatched per cycle, 192-entry
    # retire queue, 84-entry ALU scheduling queue capacity (6 x 14),
    # retire up to 8 ops per cycle.
    # Zen front end: 4-wide predecode/decode (all four decoders take
    # multi-op instructions), 2K-op uop cache delivering 8/cycle, no
    # LSD (loop buffer is Zen 2+), branch fusion, micro-fused memory
    # ops, move elimination, ~18-cycle mispredict recovery.
    pipeline=PipelineParams(issue_width=6, rob_size=192,
                            scheduler_size=84, retire_width=8,
                            predecode_width=4, decode_width=4,
                            complex_decode_width=4,
                            dsb_width=8, dsb_size=2048, lsd_size=0,
                            macro_fusion=True, micro_fusion=True,
                            move_elimination=True,
                            mispredict_penalty=18.0),
)

_FMUL = "0|1"      # FP mul / FMA pipes
_FADD = "2|3"      # FP add pipes
_FANY = "0|1|2|3"  # FP move/logic spreads across all four pipes (Table IV)
_IALU = "4|5|6|7"
_AGU = "8|9"


def _xmm_and_ymm(entries: list[InstrForm]) -> list[InstrForm]:
    out = list(entries)
    for e in entries:
        if "xmm" in e.signature:
            out.append(widen_double_pumped(e))
    return out


def _zen_forms() -> tuple[InstrForm, ...]:
    ent: list[InstrForm] = []

    # ---- FP moves / loads / stores (Table IV rows) --------------------
    mv: list[InstrForm] = []
    for m in ("vmovapd", "vmovaps", "vmovupd", "vmovups", "vmovdqa",
              "vmovdqu", "movapd", "movaps", "vmovsd", "vmovss",
              "movsd", "movss"):
        mv.append(E(m, "xmm,mem",
                    [U(_FANY), U(_AGU, hideable_load=True, kind="load")],
                    0.5, 5, "load: FP move uop + AGU uop"))
        mv.append(E(m, "mem,xmm",
                    [U(_FANY), U("8", kind="store-agu"),
                     U("9", kind="store-agu")], 1.0, 4,
                    "store blocks both AGUs; hides one load"))
        mv.append(E(m, "xmm,xmm", [U(_FANY)], 0.25, 1))
    ent += _xmm_and_ymm(mv)

    # ---- FP arithmetic: mul/FMA on 0|1, add on 2|3 (paper Sec. II-C) --
    ar: list[InstrForm] = []
    for m in ("vaddpd", "vaddps", "vaddsd", "vaddss",
              "vsubpd", "vsubps", "vsubsd", "vsubss",
              "vmaxpd", "vminpd", "vmaxsd", "vminsd"):
        ar.append(E(m, "xmm,xmm,xmm", [U(_FADD)], 0.5, 3,
                    "paper: vaddpd lat 3 on Zen"))
        ar.append(E(m, "xmm,xmm,mem",
                    [U(_FADD), U(_AGU, hideable_load=True, kind="load")],
                    0.5, 3))
    for m in ("vmulpd", "vmulps", "vmulsd", "vmulss"):
        ar.append(E(m, "xmm,xmm,xmm", [U(_FMUL)], 0.5, 4))
        ar.append(E(m, "xmm,xmm,mem",
                    [U(_FMUL), U(_AGU, hideable_load=True, kind="load")],
                    0.5, 4))
    for m in tuple(f"vfmadd{o}{t}" for o in ("132", "213", "231")
                   for t in ("pd", "ps", "sd", "ss")) + \
            tuple(f"vfnmadd{o}pd" for o in ("132", "213", "231")):
        ar.append(E(m, "xmm,xmm,xmm", [U(_FMUL)], 0.5, 5,
                    "paper Sec. II-C: lat 5, TP 0.5, pipes 0|1"))
        ar.append(E(m, "xmm,xmm,mem",
                    [U(_FMUL), U(_AGU, hideable_load=True, kind="load")],
                    0.5, 5, "paper DB entry: 0.5, 5.0, (0.5,0.5,...,0.5,0.5)"))
    ent += _xmm_and_ymm(ar)

    # ---- divide: pipe 3 + divider (paper: 'divider pipe on port 3') ---
    dv: list[InstrForm] = []
    dv.append(E("vdivpd", "xmm,xmm,xmm", [U("3"), U("3DV", 4, kind="div")],
                4, 13, "DB value chosen as in paper (pred 2.00/it at -O3)"))
    dv.append(E("vdivsd", "xmm,xmm,xmm", [U("3"), U("3DV", 4, kind="div")],
                4, 13))
    dv.append(E("vdivps", "xmm,xmm,xmm", [U("3"), U("3DV", 3, kind="div")],
                3, 10))
    dv.append(E("vdivss", "xmm,xmm,xmm", [U("3"), U("3DV", 3, kind="div")],
                3, 10))
    dv.append(E("vsqrtpd", "xmm,xmm", [U("3"), U("3DV", 9, kind="div")],
                9, 20))
    dv.append(E("vsqrtsd", "xmm,xmm", [U("3"), U("3DV", 9, kind="div")],
                9, 20))
    ent += _xmm_and_ymm(dv)

    # ---- conversions / shuffles ---------------------------------------
    cv: list[InstrForm] = []
    cv.append(E("vcvtdq2pd", "xmm,xmm", [U("1|2")], 0.5, 4))
    cv.append(E("vcvtsi2sd", "xmm,xmm,r", [U("2|3"), U(_IALU)], 1, 7))
    cv.append(E("vcvtsi2ss", "xmm,xmm,r", [U("2|3"), U(_IALU)], 1, 7))
    cv.append(E("vcvttsd2si", "r,xmm", [U("2|3"), U(_IALU)], 1, 7))
    cv.append(E("vextracti128", "xmm,ymm,imm", [U(_FANY)], 0.25, 2))
    cv.append(E("vextractf128", "xmm,ymm,imm", [U(_FANY)], 0.25, 2))
    for m in ("vunpcklpd", "vunpckhpd", "vshufpd", "vshufps", "vpshufd"):
        cv.append(E(m, "*", [U("1|2")], 0.5, 1))
    ent += cv  # extract forms reference ymm already; no widening

    # ---- integer SIMD --------------------------------------------------
    si: list[InstrForm] = []
    for m in ("vpaddd", "vpaddq", "vpsubd", "vpand", "vpor", "vpxor",
              "vpcmpeqd"):
        si.append(E(m, "xmm,xmm,xmm", [U(_FANY)], 0.25, 1))
        si.append(E(m, "xmm,xmm,mem",
                    [U(_FANY), U(_AGU, hideable_load=True, kind="load")],
                    0.5, 1))
    ent += _xmm_and_ymm(si)

    # ---- FP logic -------------------------------------------------------
    lg: list[InstrForm] = []
    for m in ("vxorpd", "vxorps", "vandpd", "vandps", "vorpd", "vorps"):
        lg.append(E(m, "xmm,xmm,xmm", [U(_FANY)], 0.25, 0, "zero idiom"))
    for m in ("vcmppd", "vcomisd", "vucomisd"):
        lg.append(E(m, "*", [U("0|1")], 0.5, 3))
    ent += _xmm_and_ymm(lg)

    # ---- scalar integer -------------------------------------------------
    for m in ("add", "sub", "and", "or", "xor", "cmp", "test", "inc",
              "dec", "neg", "not"):
        ent.append(E(m, "r,r", [U(_IALU)], 0.25, 1,
                     "Table IV incl/addq/cmpl: 0.25 on P4-7"))
        ent.append(E(m, "r,imm", [U(_IALU)], 0.25, 1))
        ent.append(E(m, "r", [U(_IALU)], 0.25, 1))  # inc/dec/neg/not
        ent.append(E(m, "r,mem", [U(_IALU),
                                  U(_AGU, hideable_load=True, kind="load")],
                     0.5, 5))
    ent.append(E("mov", "r,r", [U(_IALU)], 0.25, 0))
    ent.append(E("mov", "r,imm", [U(_IALU)], 0.25, 1))
    ent.append(E("mov", "r,mem", [U(_AGU, hideable_load=True, kind="load")],
                 0.5, 4))
    ent.append(E("mov", "mem,r", [U("8", kind="store-agu"),
                                  U("9", kind="store-agu")], 1, 4))
    ent.append(E("movz", "*", [U(_IALU)], 0.25, 1))
    ent.append(E("movs", "*", [U(_IALU)], 0.25, 1))
    ent.append(E("lea", "r,mem", [U(_IALU)], 0.25, 1))
    ent.append(E("imul", "r,r", [U("5")], 1, 3))
    for m in ("shl", "shr", "sar", "sal"):
        ent.append(E(m, "*", [U(_IALU)], 0.25, 1))
    ent.append(E("push", "*", [U("8", kind="store-agu"),
                               U("9", kind="store-agu")], 1, 4))
    ent.append(E("pop", "*", [U(_AGU, hideable_load=True, kind="load")],
                 0.5, 4))

    # ---- branches: unported, as in the paper's tables ------------------
    from ..isa import _BRANCHES
    # sorted: form-table order must be deterministic so the serialized
    # model (and MachineModel.digest) is stable across processes
    for b in sorted(_BRANCHES):
        ent.append(E(b, "*", [], 0.5, 0, "branch: unported in paper model"))
    ent.append(E("call", "*", [], 1, 0))

    return tuple(ent)


# Zen (17h) memory hierarchy for the ECM backend (docs/ecm.md): 512 KiB
# per-core L2, victim L3; link bandwidths in cycles per 64-byte line,
# with a slower memory link than Skylake's (single-CCX client part).
ZEN_HIERARCHY = MemoryHierarchy(levels=(
    CacheLevel("L1", 32 * 1024, ways=8, line_bytes=64,
               load_bw=0.5, store_bw=1.0),
    CacheLevel("L2", 512 * 1024, ways=8, line_bytes=64,
               load_bw=1.0, store_bw=2.0),
    CacheLevel("L3", 8 * 1024 * 1024, ways=16, line_bytes=64,
               load_bw=2.5, store_bw=5.0),
    CacheLevel("MEM", None, ways=1, line_bytes=64,
               load_bw=7.0, store_bw=7.0),
))


@functools.lru_cache(maxsize=None)
def build_zen_model() -> MachineModel:
    """The Zen machine as one declarative artifact: the ``ZEN`` topology
    plus the full instruction-form table.  Registered lazily under
    ``"zen"`` (aliases ``"zen1"``/``"znver1"``) by the default
    :class:`~repro.core.arch.registry.ArchRegistry`."""
    return MachineModel.from_port_model(
        ZEN, arch_id="zen", aliases=("zen1", "znver1"),
        forms=_zen_forms(), hierarchy=ZEN_HIERARCHY)


def build_zen_db() -> InstructionDB:
    """A fresh Zen :class:`InstructionDB` (prefer the cached
    ``default_registry().database("zen")`` / ``AnalysisService``)."""
    return build_zen_model().build_db()


# Store->load forwarding latency (module alias; canonical value on ZEN).
STORE_FORWARD_LATENCY = ZEN.store_forward_latency
