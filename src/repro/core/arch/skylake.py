"""Intel Skylake port model + instruction database (paper Fig. 2, Sec. II-C).

Ports 0-7; divider pipe 0DV attached to port 0 (occupied for the full divide
duration while port 0 itself frees after one cycle — paper Sec. I-B).

Database entries follow the paper exactly where the paper prints them
(Tables II, VI, VII and the Sec. II-C FMA example); the remainder is
compiled from the public sources the paper cites: Intel's optimization
manual [8] and Agner Fog's instruction tables [11].  Signatures are in
Intel (destination-first) operand order, matching OSACA/ibench keys.
"""
from __future__ import annotations

import functools

from ..database import E, InstrForm, InstructionDB
from ..machine import MachineModel
from ..mem.hierarchy import CacheLevel, MemoryHierarchy
from ..ports import PipelineParams, PortModel, U

SKYLAKE = PortModel(
    name="Intel Skylake",
    ports=("0", "0DV", "1", "2", "3", "4", "5", "6", "7"),
    divider_ports=frozenset({"0DV"}),
    store_hides_load=False,
    unit="cy",
    frequency_hz=1.8e9,  # validation machine, paper Sec. I-C
    # Store->load forwarding latency for the LCD analysis; calibrated so the
    # pi -O1 accumulator chain (SLF + vaddsd lat 4) matches the measured
    # 9.02 cy/it (paper Table V).
    store_forward_latency=5.0,
    # Front-end / OoO window for the cycle-level simulator (Intel
    # optimization manual [8], Skylake chapter): 4-wide allocation from
    # the uop queue, 224-entry ROB, 97-entry unified scheduler.  The
    # uiCA-style front end: 5-wide predecode, 4 decoders of which one
    # handles multi-uop instructions, 1.5K-uop DSB delivering 6/cycle,
    # 64-uop LSD, macro-fusion of cmp/test+jcc, micro-fused (laminated)
    # memory uops, reg-reg move elimination, and a ~17-cycle
    # mispredict recovery on loop entry.
    pipeline=PipelineParams(issue_width=4, rob_size=224,
                            scheduler_size=97, retire_width=4,
                            predecode_width=5, decode_width=4,
                            complex_decode_width=1,
                            dsb_width=6, dsb_size=1536, lsd_size=64,
                            macro_fusion=True, micro_fusion=True,
                            move_elimination=True,
                            mispredict_penalty=17.0),
)

# Store-address uops: the paper's model sends them to ports 2|3 only
# (port-7 simple-address AGU modelling is listed as future work, Sec. IV-B;
# Table II accordingly shows P7 = 0.00).
_ST_ADDR = "2|3"
_LOAD = "2|3"
_FP = "0|1"          # FP add/mul/FMA pipes
_IALU = "0|1|5|6"    # scalar integer ALU
_SHUF = "5"          # shuffle unit


def _fp_arith(mnemonics, lat, *, tp=0.5):
    """reg-reg and mem-source forms for 2-src FP arithmetic (sd/ss/pd/ps,
    xmm/ymm share ports on SKL; AVX-512 deliberately out of scope, paper
    Sec. I-C)."""
    entries = []
    for m in mnemonics:
        for a in ("xmm", "ymm"):
            entries.append(E(m, f"{a},{a},{a}", [U(_FP)], tp, lat))
            entries.append(E(m, f"{a},{a},mem",
                             [U(_FP), U(_LOAD, kind="load")], tp, lat))
        # scalar forms (sd/ss) appear with xmm regs only — covered above.
    return entries


def _skylake_forms() -> tuple[InstrForm, ...]:
    ent: list[InstrForm] = []

    # ---- FP moves / loads / stores -----------------------------------
    for m in ("vmovapd", "vmovaps", "vmovupd", "vmovups", "vmovdqa",
              "vmovdqu", "movapd", "movaps", "movupd", "movups",
              "vmovsd", "vmovss", "movsd", "movss", "vlddqu"):
        for r in ("xmm", "ymm"):
            ent.append(E(m, f"{r},mem", [U(_LOAD, kind="load")], 0.5, 4,
                         "L1 load"))
            ent.append(E(m, f"mem,{r}",
                         [U(_ST_ADDR, kind="store-agu"),
                          U("4", kind="store-data")], 1.0, 4, "store"))
            ent.append(E(m, f"{r},{r}", [U("0|1|5")], 0.33, 1, "reg move"))
    ent.append(E("vbroadcastsd", "ymm,mem", [U(_LOAD, kind="load")], 0.5, 4))
    ent.append(E("vbroadcastsd", "ymm,xmm", [U(_SHUF)], 1.0, 3))
    ent.append(E("vbroadcastss", "ymm,mem", [U(_LOAD, kind="load")], 0.5, 4))
    ent.append(E("vmovq", "r64,xmm", [U("0")], 1.0, 2))
    ent.append(E("vmovq", "xmm,r64", [U("5")], 1.0, 2))
    ent.append(E("vmovd", "r32,xmm", [U("0")], 1.0, 2))
    ent.append(E("vmovd", "xmm,r32", [U("5")], 1.0, 2))
    ent.append(E("vmovmskpd", "r,ymm", [U("0")], 1.0, 2))

    # ---- FP arithmetic ------------------------------------------------
    ent += _fp_arith(
        ("vaddpd", "vaddps", "vaddsd", "vaddss",
         "vsubpd", "vsubps", "vsubsd", "vsubss",
         "vmulpd", "vmulps", "vmulsd", "vmulss",
         "vmaxpd", "vmaxps", "vmaxsd", "vminpd", "vminps", "vminsd"),
        lat=4)
    ent += _fp_arith(
        tuple(f"vfmadd{o}{t}" for o in ("132", "213", "231")
              for t in ("pd", "ps", "sd", "ss"))
        + tuple(f"vfnmadd{o}pd" for o in ("132", "213", "231"))
        + tuple(f"vfmsub{o}pd" for o in ("132", "213", "231")),
        lat=4)
    # addsd with mem source in 2-operand legacy-style listing (paper pi -O1
    # uses 3-op VEX with (%rsp) source: covered by _fp_arith "xmm,xmm,mem")

    # ---- divide / sqrt: port 0 + divider pipe (paper Sec. I-B) -------
    ent.append(E("vdivpd", "ymm,ymm,ymm", [U("0"), U("0DV", 8, kind="div")],
                 8, 14, "Table VI: 8 cy DV"))
    ent.append(E("vdivpd", "xmm,xmm,xmm", [U("0"), U("0DV", 4, kind="div")],
                 4, 14))
    ent.append(E("vdivsd", "xmm,xmm,xmm", [U("0"), U("0DV", 4, kind="div")],
                 4, 14, "Table VII: 4 cy DV"))
    ent.append(E("vdivps", "ymm,ymm,ymm", [U("0"), U("0DV", 5, kind="div")],
                 5, 11))
    ent.append(E("vdivss", "xmm,xmm,xmm", [U("0"), U("0DV", 3, kind="div")],
                 3, 11))
    for m, dv, lat in (("vsqrtpd", 12, 18), ("vsqrtsd", 6, 18),
                       ("vsqrtps", 6, 12), ("vsqrtss", 3, 12)):
        ent.append(E(m, "ymm,ymm" if m.endswith("ps") or m.endswith("pd")
                     else "xmm,xmm",
                     [U("0"), U("0DV", dv, kind="div")], dv, lat))

    # ---- conversions / shuffles (paper Tables VI, VII ports) ---------
    ent.append(E("vcvtdq2pd", "ymm,xmm", [U("0"), U(_SHUF)], 1, 7,
                 "Table VI: 1.0 P0 + 1.0 P5"))
    ent.append(E("vcvtdq2pd", "xmm,xmm", [U("0"), U(_SHUF)], 1, 7))
    ent.append(E("vcvtsi2sd", "xmm,xmm,r", [U(_FP), U(_SHUF)], 1, 6,
                 "Table VII: 0.5/0.5 P01 + 1.0 P5"))
    ent.append(E("vcvtsi2ss", "xmm,xmm,r", [U(_FP), U(_SHUF)], 1, 6))
    ent.append(E("vcvttsd2si", "r,xmm", [U("0"), U("1")], 1, 6))
    ent.append(E("vcvtpd2ps", "xmm,ymm", [U("1"), U(_SHUF)], 1, 7))
    ent.append(E("vextracti128", "xmm,ymm,imm", [U(_SHUF)], 1, 3,
                 "Table VI: 1.0 P5"))
    ent.append(E("vextractf128", "xmm,ymm,imm", [U(_SHUF)], 1, 3))
    ent.append(E("vinserti128", "ymm,ymm,xmm,imm", [U(_SHUF)], 1, 3))
    ent.append(E("vinsertf128", "ymm,ymm,xmm,imm", [U(_SHUF)], 1, 3))
    for m in ("vperm2f128", "vperm2i128", "vpermpd", "vpermq",
              "vunpcklpd", "vunpckhpd", "vshufpd", "vshufps",
              "vpunpcklqdq", "vpunpckhqdq", "vpshufd", "vpalignr"):
        ent.append(E(m, "*", [U(_SHUF)], 1, 1 if "unpck" in m else 3))

    # ---- integer SIMD -------------------------------------------------
    for m in ("vpaddd", "vpaddq", "vpaddb", "vpaddw", "vpsubd", "vpsubq",
              "vpand", "vpor", "vpxor", "vpcmpeqd", "vpcmpgtd"):
        for r in ("xmm", "ymm"):
            ent.append(E(m, f"{r},{r},{r}", [U("0|1|5")], 0.33, 1,
                         "Table VI vpaddd: 0.33 each on P015"))
            ent.append(E(m, f"{r},{r},mem",
                         [U("0|1|5"), U(_LOAD, kind="load")], 0.5, 1))
    for m in ("vpmulld", "vpmuludq", "vpmaddwd"):
        ent.append(E(m, "*", [U(_FP)], 0.5, 5))
    for m in ("vpsllq", "vpsrlq", "vpslld", "vpsrld", "vpsllvd", "vpsrlvd"):
        ent.append(E(m, "*", [U("0|1")], 0.5, 1))

    # ---- FP logic: paper Table VII models vxorpd on P0156 ------------
    for m in ("vxorpd", "vxorps", "vandpd", "vandps", "vorpd", "vorps",
              "vandnpd"):
        for r in ("xmm", "ymm"):
            ent.append(E(m, f"{r},{r},{r}", [U("0|1|5|6")], 0.25, 0,
                         "zero idiom ports per paper Table VII"))
    for m in ("vblendvpd", "vblendpd", "vblendps"):
        ent.append(E(m, "*", [U("0|1|5")], 0.33, 1))
    for m in ("vcmppd", "vcmpps", "vcmpsd", "vcomisd", "vucomisd"):
        ent.append(E(m, "*", [U(_FP)], 0.5, 4))
    ent.append(E("vroundpd", "*", [U(_FP)], 0.5, 8))
    ent.append(E("vrcpps", "*", [U("0")], 1, 4))
    ent.append(E("vrsqrtps", "*", [U("0")], 1, 4))

    # ---- scalar integer ----------------------------------------------
    for m in ("add", "sub", "and", "or", "xor", "cmp", "test", "inc",
              "dec", "neg", "not", "adc", "sbb"):
        ent.append(E(m, "r,r", [U(_IALU)], 0.25, 1,
                     "Table II addl: 0.25 on P0156"))
        ent.append(E(m, "r,imm", [U(_IALU)], 0.25, 1))
        ent.append(E(m, "r", [U(_IALU)], 0.25, 1))  # inc/dec/neg/not
        ent.append(E(m, "r,mem", [U(_IALU), U(_LOAD, kind="load")], 0.5, 6))
        ent.append(E(m, "mem,r",
                     [U(_IALU), U(_LOAD, kind="load"),
                      U(_ST_ADDR, kind="store-agu"),
                      U("4", kind="store-data")], 1, 7, "RMW"))
        ent.append(E(m, "mem,imm",
                     [U(_IALU), U(_LOAD, kind="load"),
                      U(_ST_ADDR, kind="store-agu"),
                      U("4", kind="store-data")], 1, 7, "RMW"))
    ent.append(E("mov", "r,r", [U(_IALU)], 0.25, 0, "move elim still occupies"))
    ent.append(E("mov", "r,imm", [U(_IALU)], 0.25, 1))
    ent.append(E("mov", "r,mem", [U(_LOAD, kind="load")], 0.5, 4))
    ent.append(E("mov", "mem,r", [U(_ST_ADDR, kind="store-agu"),
                                  U("4", kind="store-data")], 1, 4))
    ent.append(E("mov", "mem,imm", [U(_ST_ADDR, kind="store-agu"),
                                    U("4", kind="store-data")], 1, 4))
    ent.append(E("movz", "*", [U(_IALU)], 0.25, 1))
    ent.append(E("movs", "*", [U(_IALU)], 0.25, 1))
    ent.append(E("lea", "r,mem", [U("1|5")], 0.5, 1))
    ent.append(E("imul", "r,r", [U("1")], 1, 3))
    ent.append(E("imul", "r,r,imm", [U("1")], 1, 3))
    for m in ("shl", "shr", "sar", "sal", "rol", "ror"):
        ent.append(E(m, "*", [U("0|6")], 0.5, 1))
    ent.append(E("push", "*", [U(_ST_ADDR, kind="store-agu"),
                               U("4", kind="store-data")], 1, 4))
    ent.append(E("pop", "*", [U(_LOAD, kind="load")], 0.5, 4))
    ent.append(E("setc", "*", [U(_IALU)], 0.25, 1))
    ent.append(E("cmov", "*", [U("0|6")], 0.5, 1))

    # ---- branches: no port occupation in OSACA 0.2's model -----------
    # (paper Table II shows a blank row for `ja .L10`; real HW uses P6 —
    #  recorded as a model deviation in DESIGN.md)
    from ..isa import _BRANCHES
    # sorted: form-table order must be deterministic so the serialized
    # model (and MachineModel.digest) is stable across processes
    for b in sorted(_BRANCHES):
        ent.append(E(b, "*", [], 0.5, 0, "branch: unported in paper model"))
    ent.append(E("call", "*", [], 1, 0))

    return tuple(ent)


# Client Skylake memory hierarchy for the ECM backend (docs/ecm.md):
# per-level link bandwidths in cycles per 64-byte cache line, in the
# spirit of Kerncraft's SKL machine files (L1<->L2 one 64B line per
# cycle, halved per level further out; write-allocate + write-back on
# every cache level).  The L1 entry prices the L1<->register link,
# which the in-core T_nOL term already covers.
SKL_HIERARCHY = MemoryHierarchy(levels=(
    CacheLevel("L1", 32 * 1024, ways=8, line_bytes=64,
               load_bw=0.5, store_bw=1.0),
    CacheLevel("L2", 256 * 1024, ways=4, line_bytes=64,
               load_bw=1.0, store_bw=2.0),
    CacheLevel("L3", 8 * 1024 * 1024, ways=16, line_bytes=64,
               load_bw=2.0, store_bw=4.0),
    CacheLevel("MEM", None, ways=1, line_bytes=64,
               load_bw=6.0, store_bw=6.0),
))


@functools.lru_cache(maxsize=None)
def build_skylake_model() -> MachineModel:
    """The Skylake machine as one declarative artifact: the ``SKYLAKE``
    topology plus the full instruction-form table.  Registered lazily
    under ``"skl"`` (alias ``"skylake"``) by the default
    :class:`~repro.core.arch.registry.ArchRegistry`."""
    return MachineModel.from_port_model(
        SKYLAKE, arch_id="skl", aliases=("skylake",),
        forms=_skylake_forms(), hierarchy=SKL_HIERARCHY)


def build_skylake_db() -> InstructionDB:
    """A fresh Skylake :class:`InstructionDB` (prefer the cached
    ``default_registry().database("skl")`` / ``AnalysisService``)."""
    return build_skylake_model().build_db()


# Store->load forwarding latency (kept as a module alias; the canonical
# value lives on the PortModel so analyze() can default to it).
STORE_FORWARD_LATENCY = SKYLAKE.store_forward_latency
