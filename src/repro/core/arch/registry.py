"""Architecture registry: one resolution path for every machine model.

Replaces the old trio of ``arch._ALIASES`` / ``arch.canonical_arch`` /
``arch.get_db`` (an if/elif that rebuilt the whole database on every
call) with a single :class:`ArchRegistry`:

* **lazy builders** — ``register_lazy("skl", builder, aliases=...)``
  records identity without paying for the form table; the
  :class:`~repro.core.machine.MachineModel` is built on first use,
* **alias resolution** — ``resolve("znver1") -> "zen"``; unknown names
  raise one consistent :class:`UnknownArchError` listing every
  registered id and alias (the old ``canonical_arch`` silently passed
  unknown names through while ``get_db`` raised a stale message),
* **database caching** — ``database("skl")`` builds the
  ``InstructionDB`` once per registry; benchmarks that bypass
  ``AnalysisService`` no longer pay the full build repeatedly,
* **model files** — :meth:`ArchRegistry.load_file` /
  :meth:`~ArchRegistry.discover` register the JSON artifacts shipped
  under ``src/repro/core/arch/models/*.json`` (full models or
  ``base``+``overrides`` derivations — models are data),
* **layering** — a registry may have a ``parent``; lookups fall back to
  it, and local registrations shadow it.  ``AnalysisService`` gives
  every service instance a private child of the process-wide
  :func:`default_registry`, so runtime ``register()`` calls never leak
  across services.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Callable, Sequence

from ..database import InstructionDB
from ..machine import SCHEMA, MachineModel

#: directory of the JSON model artifacts shipped with the package
MODELS_DIR = Path(__file__).resolve().parent / "models"

Builder = Callable[[], MachineModel]


class UnknownArchError(ValueError, KeyError):
    """Raised for an architecture name no registry layer knows.

    Subclasses both ``ValueError`` (what the old ``get_db`` raised) and
    ``KeyError`` so existing handlers keep working.  The message lists
    every registered id and alias.
    """

    def __init__(self, name: str, ids: Sequence[str],
                 aliases: dict[str, str]):
        self.name = name
        alias_part = ", ".join(f"{a!r}->{c!r}"
                               for a, c in sorted(aliases.items()))
        msg = (f"unknown architecture {name!r}; registered ids: "
               f"{sorted(ids)}"
               + (f"; aliases: {alias_part}" if aliases else ""))
        ValueError.__init__(self, msg)

    def __str__(self) -> str:  # KeyError would repr() the args tuple
        return self.args[0]


class ArchRegistry:
    """Thread-safe id/alias resolution + model and database caching."""

    def __init__(self, parent: "ArchRegistry | None" = None):
        self._lock = threading.RLock()
        self._parent = parent
        self._builders: dict[str, Builder] = {}
        self._models: dict[str, MachineModel] = {}
        self._aliases: dict[str, str] = {}
        self._dbs: dict[str, InstructionDB] = {}
        self._epoch = 0

    @property
    def epoch(self) -> int:
        """Monotone counter bumped whenever a registration *replaces* a
        known name (or :meth:`invalidate` drops caches).  Layered: a
        child's epoch includes its parents', so an
        :class:`~repro.core.engine.AnalysisService` watching its private
        child also sees process-wide re-registrations.  Cache holders
        compare epochs to drop entries for superseded models — the
        guarantee that a re-registered model is never served stale
        predictions (docs/robustness.md)."""
        with self._lock:
            ep = self._epoch
        if self._parent is not None:
            ep += self._parent.epoch
        return ep

    # ------------------------------------------------------------------
    # registration
    # ------------------------------------------------------------------
    def register(self, model: MachineModel, *,
                 aliases: Sequence[str] | None = None,
                 replace: bool = False) -> str:
        """Register a built model under ``model.arch_id``.

        ``aliases`` defaults to ``model.aliases``; ``replace=True``
        allows re-registration (shadowing a parent entry or replacing a
        local one) and drops the cached database for the id."""
        arch_id = model.arch_id
        self.register_lazy(
            arch_id, lambda: model,
            aliases=model.aliases if aliases is None else aliases,
            replace=replace)
        with self._lock:
            self._models[arch_id] = model
        return arch_id

    def register_lazy(self, arch_id: str, builder: Builder, *,
                      aliases: Sequence[str] = (),
                      replace: bool = False) -> str:
        """Register a model *builder* called on first use — identity
        (id + aliases) is recorded now, the form table is not built."""
        arch_id = arch_id.lower()
        aliases = tuple(a.lower() for a in aliases)
        with self._lock:
            if not replace:
                clash = [n for n in (arch_id, *aliases)
                         if self._known(n, ignore_id=None)]
                if clash:
                    raise ValueError(
                        f"architecture name(s) {clash} already "
                        f"registered (pass replace=True to shadow)")
            elif any(self._known(n, ignore_id=None)
                     for n in (arch_id, *aliases)):
                # a *replacing* registration supersedes a model some
                # cache may already hold results for — bump the epoch
                self._epoch += 1
            # drop aliases previously pointing at this id, then re-add
            for a in [a for a, c in self._aliases.items() if c == arch_id]:
                del self._aliases[a]
            self._builders[arch_id] = builder
            self._models.pop(arch_id, None)
            self._dbs.pop(arch_id, None)
            for a in aliases:
                if a != arch_id:
                    self._aliases[a] = arch_id
        return arch_id

    def _known(self, name: str, ignore_id: str | None) -> bool:
        if name in self._builders or name in self._aliases:
            return True
        if self._parent is not None:
            return self._parent._known(name, ignore_id)
        return False

    def prime_database(self, arch_id: str, db: InstructionDB) -> None:
        """Seed the database cache for a registered id (used by the
        ``register_db`` migration shim to preserve object identity)."""
        arch_id = self.resolve(arch_id)
        with self._lock:
            self._dbs[arch_id] = db

    # ------------------------------------------------------------------
    # resolution
    # ------------------------------------------------------------------
    def resolve(self, name: str) -> str:
        """Canonical architecture id for ``name`` (id or alias, case-
        insensitive); raises :class:`UnknownArchError` otherwise."""
        key = name.lower()
        reg: ArchRegistry | None = self
        while reg is not None:
            with reg._lock:
                if key in reg._builders:
                    return key
                if key in reg._aliases:
                    return reg._aliases[key]
            reg = reg._parent
        raise UnknownArchError(name, self.ids(), self.alias_map())

    def __contains__(self, name: str) -> bool:
        try:
            self.resolve(name)
            return True
        except UnknownArchError:
            return False

    def ids(self) -> list[str]:
        """All registered canonical ids (parent layers included)."""
        out = dict.fromkeys(self._parent.ids()) if self._parent else {}
        with self._lock:
            out.update(dict.fromkeys(self._builders))
        return list(out)

    def alias_map(self) -> dict[str, str]:
        """alias -> canonical id over all layers (local shadows parent)."""
        out = self._parent.alias_map() if self._parent else {}
        with self._lock:
            out.update(self._aliases)
        return out

    # ------------------------------------------------------------------
    # model / database access
    # ------------------------------------------------------------------
    def model(self, name: str) -> MachineModel:
        """The (cached) :class:`MachineModel`, building lazily."""
        arch_id = self.resolve(name)
        reg: ArchRegistry | None = self
        while reg is not None:
            with reg._lock:
                hit = reg._models.get(arch_id)
                if hit is not None:
                    return hit
                builder = reg._builders.get(arch_id)
            if builder is not None:
                model = builder()
                if model.arch_id != arch_id:
                    raise ValueError(
                        f"builder for {arch_id!r} returned a model with "
                        f"arch_id {model.arch_id!r}")
                with reg._lock:
                    model = reg._models.setdefault(arch_id, model)
                return model
            reg = reg._parent
        raise UnknownArchError(name, self.ids(), self.alias_map())

    def database(self, name: str) -> InstructionDB:
        """The (cached) :class:`InstructionDB` for ``name`` — built at
        most once per registry layer and shared by every caller.

        Raises ``ValueError`` for a model without an instruction-form
        table (e.g. ``"tpu_v5e"``): instruction-stream analysis on it
        would silently match nothing; accelerator/HLO analysis lives in
        ``repro.core.hlo.analyzer`` / ``AnalysisService.predict_hlo``."""
        arch_id = self.resolve(name)
        # serve from the layer that owns the id so a local registration
        # shadows the parent's cache (and vice versa stays shared)
        reg: ArchRegistry | None = self
        while reg is not None:
            with reg._lock:
                owns = arch_id in reg._builders or arch_id in reg._models
                hit = reg._dbs.get(arch_id)
            if hit is not None:
                return hit
            if owns:
                model = reg.model(arch_id)
                if not model.forms:
                    raise ValueError(
                        f"architecture {arch_id!r} has no instruction-"
                        f"form table — it cannot serve instruction-"
                        f"stream analysis (accelerator/HLO analysis "
                        f"lives in repro.core.hlo.analyzer / "
                        f"AnalysisService.predict_hlo)")
                db = model.database()
                with reg._lock:
                    db = reg._dbs.setdefault(arch_id, db)
                return db
            reg = reg._parent
        raise UnknownArchError(name, self.ids(), self.alias_map())

    # ------------------------------------------------------------------
    # model files
    # ------------------------------------------------------------------
    def load_file(self, path: str | Path, *,
                  replace: bool = False) -> str:
        """Register one JSON model file; returns the registered id.

        Two layouts are accepted (``tools/check_models.py`` validates
        both for every shipped file):

        * full model: ``{"schema": ..., "model": {<to_dict() output>}}``
          (or the ``to_dict()`` output directly at top level),
        * derivation: ``{"schema": ..., "base": "skl", "overrides":
          {"arch_id": "clx", ...}}`` — resolved against this registry
          and applied via :meth:`MachineModel.derive` on first use.
        """
        path = Path(path)
        data = json.loads(path.read_text(encoding="utf-8"))
        schema = data.get("schema", SCHEMA)
        if schema != SCHEMA:
            raise ValueError(f"{path}: unsupported schema {schema!r}")
        if "base" in data:
            overrides = dict(data.get("overrides", {}))
            try:
                arch_id = overrides.pop("arch_id")
            except KeyError:
                raise ValueError(
                    f"{path}: derived model needs overrides.arch_id")
            base = data["base"]
            aliases = tuple(overrides.get("aliases", ()))
            return self.register_lazy(
                arch_id,
                lambda: self.model(base).derive(arch_id, **overrides),
                aliases=aliases, replace=replace)
        payload = data.get("model", data)
        model = MachineModel.from_dict(payload)
        return self.register(model, replace=replace)

    def discover(self, directory: str | Path | None = None,
                 *, replace: bool = False) -> list[str]:
        """Register every ``*.json`` model file in ``directory``
        (default: the shipped :data:`MODELS_DIR`), sorted by name."""
        directory = Path(directory) if directory else MODELS_DIR
        if not directory.is_dir():
            return []
        return [self.load_file(p, replace=replace)
                for p in sorted(directory.glob("*.json"))]

    # ------------------------------------------------------------------
    def invalidate(self, name: str | None = None) -> None:
        """Drop cached models/databases (all, or one id) so the next
        access rebuilds; registrations are kept."""
        with self._lock:
            self._epoch += 1
            if name is None:
                self._models.clear()
                self._dbs.clear()
                return
            arch_id = self.resolve(name)
            self._models.pop(arch_id, None)
            self._dbs.pop(arch_id, None)


# --------------------------------------------------------------------------
# The process-wide registry: built-in architectures + shipped model files
# --------------------------------------------------------------------------

_DEFAULT: ArchRegistry | None = None
_DEFAULT_LOCK = threading.Lock()


def _builtin_registry() -> ArchRegistry:
    reg = ArchRegistry()

    def _skl() -> MachineModel:
        from .skylake import build_skylake_model
        return build_skylake_model()

    def _zen() -> MachineModel:
        from .zen import build_zen_model
        return build_zen_model()

    def _tpu() -> MachineModel:
        from .tpu_v5e import build_tpu_v5e_model
        return build_tpu_v5e_model()

    reg.register_lazy("skl", _skl, aliases=("skylake",))
    reg.register_lazy("zen", _zen, aliases=("zen1", "znver1"))
    reg.register_lazy("tpu_v5e", _tpu, aliases=("tpu", "v5e"))
    reg.discover()
    return reg


def default_registry() -> ArchRegistry:
    """The process-wide shared registry: lazy builders for the built-in
    Skylake / Zen / TPU v5e models plus every shipped
    ``arch/models/*.json`` artifact."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = _builtin_registry()
        return _DEFAULT


def get_model(arch: str) -> MachineModel:
    """Convenience: ``default_registry().model(arch)``."""
    return default_registry().model(arch)
