"""Per-architecture port models and instruction databases."""
from __future__ import annotations

from .skylake import build_skylake_db, SKYLAKE
from .zen import build_zen_db, ZEN


def get_db(arch: str):
    arch = arch.lower()
    if arch in ("skl", "skylake"):
        return build_skylake_db()
    if arch in ("zen", "zen1", "znver1"):
        return build_zen_db()
    raise ValueError(f"unknown architecture {arch!r} "
                     "(TPU analysis lives in repro.core.hlo.analyzer)")
