"""Per-architecture port models and instruction databases."""
from __future__ import annotations

from .skylake import build_skylake_db, SKYLAKE
from .zen import build_zen_db, ZEN


# alias -> canonical id; shared by get_db and the AnalysisService caches
_ALIASES = {"skl": "skl", "skylake": "skl",
            "zen": "zen", "zen1": "zen", "znver1": "zen"}


def canonical_arch(arch: str) -> str:
    """Canonical architecture id: aliases collapse ("skylake" -> "skl",
    "znver1" -> "zen"); unknown names pass through lowercased (they may
    be custom AnalysisService registrations)."""
    a = arch.lower()
    return _ALIASES.get(a, a)


def get_db(arch: str):
    arch = canonical_arch(arch)
    if arch == "skl":
        return build_skylake_db()
    if arch == "zen":
        return build_zen_db()
    raise ValueError(f"unknown architecture {arch!r} "
                     "(TPU analysis lives in repro.core.hlo.analyzer)")
