"""Per-architecture machine models, instruction databases and the
registry that resolves them.

The declarative spec lives in :mod:`repro.core.machine`
(:class:`MachineModel`); this package holds the hand-written built-in
models (``skylake``, ``zen``, ``tpu_v5e``), the JSON model artifacts
shipped under ``models/*.json``, and the
:class:`~repro.core.arch.registry.ArchRegistry` front end
(:func:`default_registry`, :func:`get_model`).

``canonical_arch`` and ``get_db`` are kept as thin registry shims for
older callers; new code should use the registry (or simply pass an arch
id / :class:`MachineModel` to any analysis entry point — see
``repro.core.machine.as_database``).
"""
from __future__ import annotations

from ..machine import MachineModel
from .registry import (ArchRegistry, UnknownArchError, default_registry,
                       get_model)
from .skylake import SKYLAKE, build_skylake_db, build_skylake_model
from .tpu_v5e import TPU_V5E, build_tpu_v5e_model
from .zen import ZEN, build_zen_db, build_zen_model

__all__ = [
    "ArchRegistry", "MachineModel", "SKYLAKE", "TPU_V5E",
    "UnknownArchError", "ZEN", "build_skylake_db", "build_skylake_model",
    "build_tpu_v5e_model", "build_zen_db", "build_zen_model",
    "canonical_arch", "default_registry", "get_db", "get_model",
]


def canonical_arch(arch: str) -> str:
    """Canonical architecture id: ``"skylake" -> "skl"``,
    ``"znver1" -> "zen"``.  Registry shim — unlike the pre-registry
    version this no longer passes unknown names through silently; it
    raises :class:`UnknownArchError` listing every registered id and
    alias."""
    return default_registry().resolve(arch)


def get_db(arch: str):
    """The (registry-cached) :class:`InstructionDB` for ``arch``.

    Registry shim: the database is now built once per process instead
    of on every call, and unknown names raise one consistent
    :class:`UnknownArchError`."""
    return default_registry().database(arch)
