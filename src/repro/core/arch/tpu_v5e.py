"""TPU v5e port model — the paper's port abstraction mapped onto TPU
functional pipes (DESIGN.md Sec. 2, Layer B).

Ports:
  MXU  — systolic matmul units; occupation = flops / peak(dtype)
  VPU  — vector units (elementwise / reductions / softmax exp ...)
  HBM  — memory pipe; occupation = bytes_accessed / bandwidth
  ICI  — inter-chip links; occupation = link bytes / link bandwidth

Hardware constants (per chip) as given in the assignment brief:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import functools

from ..machine import MachineModel
from ..ports import PortModel

TPU_V5E = PortModel(
    name="TPU v5e",
    ports=("MXU", "VPU", "HBM", "ICI"),
    unit="s",
)

PEAK_FLOPS = {          # per chip, by accumulation dtype
    "bf16": 197e12,
    "f32": 98.5e12,     # half rate through the MXU
    "f16": 197e12,
    "s8": 394e12,
}
VPU_FLOPS = 2.0e12      # 8x128 vector lanes x FMA x ~1 GHz (estimate)
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link
ICI_LINKS_PER_AXIS = 1  # conservative: one logical link per mesh axis
HBM_PER_CHIP = 16 * 2**30
VMEM_PER_CHIP = 128 * 2**20   # on-chip vector memory
VMEM_BW = 22e12               # bytes/s (~VPU-datapath rate estimate)

# memory levels for working-set-aware roofline pricing (docs/ecm.md):
# innermost first, final level unbounded — the accelerator analogue of
# MachineModel.hierarchy.  analyze_hlo(working_set=...) prices the
# memory term with the innermost level that holds the working set.
MEM_LEVELS = [
    {"name": "vmem", "size": VMEM_PER_CHIP, "bw": VMEM_BW},
    {"name": "hbm", "size": HBM_PER_CHIP, "bw": HBM_BW},
    {"name": "host", "size": None, "bw": 64e9},   # PCIe/DMA spill
]

# transcendental / heavy elementwise weights (VPU cycles per element,
# relative to one FMA) — the analogue of the x86 divider-pipe entries
VPU_OP_WEIGHT = {
    "exponential": 4.0, "log": 4.0, "tanh": 6.0, "divide": 4.0,
    "sqrt": 4.0, "rsqrt": 4.0, "power": 8.0, "erf": 6.0,
    "add": 1.0, "subtract": 1.0, "multiply": 1.0, "maximum": 1.0,
    "minimum": 1.0, "compare": 1.0, "select": 1.0, "convert": 1.0,
    "exponential-minus-one": 4.0, "logistic": 6.0,
}

# the serializable machine-model view of the constants above; the HLO
# analyzer reads these keys from MachineModel.constants, so a derived /
# JSON-loaded TPU variant can rescale them without code changes
CONSTANTS = {
    "peak_flops": PEAK_FLOPS,
    "vpu_flops": VPU_FLOPS,
    "hbm_bw": HBM_BW,
    "ici_bw": ICI_BW,
    "ici_links_per_axis": ICI_LINKS_PER_AXIS,
    "hbm_per_chip": HBM_PER_CHIP,
    "vpu_op_weight": VPU_OP_WEIGHT,
    "mem_levels": MEM_LEVELS,
}


@functools.lru_cache(maxsize=None)
def build_tpu_v5e_model() -> MachineModel:
    """The TPU v5e machine as one declarative artifact: the ``TPU_V5E``
    pipe topology plus the hardware constants (no instruction-form
    table — HLO op costs are computed, not looked up).  Registered
    lazily under ``"tpu_v5e"`` (aliases ``"tpu"``/``"v5e"``) by the
    default :class:`~repro.core.arch.registry.ArchRegistry`."""
    return MachineModel.from_port_model(
        TPU_V5E, arch_id="tpu_v5e", aliases=("tpu", "v5e"),
        constants=CONSTANTS)
