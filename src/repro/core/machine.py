"""Declarative, serializable machine-model spec (paper Sec. II).

The paper's central workflow is *building a machine model from
documentation and semi-automatic benchmarking*; its outlook is carrying
that model to new architectures.  This module makes the model a first-
class artifact: one :class:`MachineModel` value unifies everything that
used to be split across :class:`~repro.core.ports.PortModel`,
:class:`~repro.core.ports.PipelineParams` and imperative
``build_*_db()`` functions —

* identity: canonical ``arch_id`` plus lookup ``aliases``,
* port topology: port list, divider pipes, the Zen store-hides-load
  pairing, the store->load forwarding latency,
* front-end / out-of-order window parameters for the cycle-level
  simulator,
* the full instruction-form table (:class:`~repro.core.database.InstrForm`
  entries), and
* free-form ``constants`` for non-x86 machines (the TPU model carries
  its peak-FLOPs / bandwidth numbers here).

Because the model is data, it round-trips through JSON
(``MachineModel.from_dict(m.to_dict()) == m``), is cacheable by
:attr:`~MachineModel.digest`, shippable to workers, diffable in review,
and cheap to vary (:meth:`~MachineModel.derive`).  Models register with
the :class:`~repro.core.arch.registry.ArchRegistry`, which resolves
aliases and caches built databases for every consumer.

Construction paths, mirroring the paper:

* hand-written (documentation-driven): the ``repro.core.arch`` modules,
* :meth:`MachineModel.from_benchmarks` (semi-automatic, paper Sec. II-B):
  infer port counts and occupations from ibench-style latency /
  parallelism-sweep measurements,
* :meth:`MachineModel.from_db`: wrap an already-built
  :class:`~repro.core.database.InstructionDB` (migration path for the
  deprecated ``AnalysisService.register_db``),
* :meth:`MachineModel.from_json` / registry model files
  (``src/repro/core/arch/models/*.json``).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field, fields, replace
from typing import Iterable, Mapping, Sequence

from .database import InstrForm, InstructionDB
from .mem.hierarchy import MemoryHierarchy
from .ports import PipelineParams, PortModel, Uop

#: schema tag written into every serialized model / model file
SCHEMA = "repro.machine-model/v1"


# --------------------------------------------------------------------------
# Benchmark records (semi-automatic model construction, paper Sec. II)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class BenchRecord:
    """One ibench-style measurement: an instruction form executed as a
    dependency chain (``parallelism=1`` — the latency benchmark) or as
    ``parallelism`` independent chains (the throughput benchmark).

    ``value`` is the per-operation time in model units (cycles for CPUs,
    seconds for measured hosts) — exactly what
    ``repro.core.bench.ibench`` reports.
    """

    form: str                     # mnemonic, e.g. "vfmadd132pd"
    parallelism: int              # 1 = latency chain; >=2 = throughput
    value: float                  # per-op time in model units
    signature: str = "v,v,v"      # operand-type signature


# --------------------------------------------------------------------------
# Serialization helpers (module-level so tools can reuse them)
# --------------------------------------------------------------------------

def _uop_to_dict(u: Uop) -> dict:
    # numeric fields are emitted as floats so the canonical JSON (and
    # therefore MachineModel.digest) is identical before and after a
    # round trip even when a hand-written table used int literals
    d: dict = {"ports": list(u.ports)}
    if u.cycles != 1.0:
        d["cycles"] = float(u.cycles)
    if u.hideable_load:
        d["hideable_load"] = True
    if u.kind:
        d["kind"] = u.kind
    return d


def _uop_from_dict(d: Mapping) -> Uop:
    return Uop(ports=tuple(d["ports"]),
               cycles=float(d.get("cycles", 1.0)),
               hideable_load=bool(d.get("hideable_load", False)),
               kind=str(d.get("kind", "")))


def _form_to_dict(f: InstrForm) -> dict:
    d: dict = {
        "mnemonic": f.mnemonic,
        "signature": list(f.signature),
        "uops": [_uop_to_dict(u) for u in f.uops],
        "throughput": float(f.throughput),
        "latency": float(f.latency),
    }
    if f.notes:
        d["notes"] = f.notes
    return d


def _form_from_dict(d: Mapping) -> InstrForm:
    return InstrForm(
        mnemonic=d["mnemonic"], signature=tuple(d["signature"]),
        uops=tuple(_uop_from_dict(u) for u in d["uops"]),
        throughput=float(d["throughput"]), latency=float(d["latency"]),
        notes=str(d.get("notes", "")))


# --------------------------------------------------------------------------
# The spec
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MachineModel:
    """One architecture as a single declarative value (see module doc)."""

    arch_id: str                          # canonical lowercase id ("skl")
    name: str                             # display name ("Intel Skylake")
    ports: tuple[str, ...]
    aliases: tuple[str, ...] = ()         # lowercase lookup aliases
    divider_ports: tuple[str, ...] = ()   # "<p> - DV" divider pipes
    store_hides_load: bool = False        # Zen AGU pairing (Sec. III-A)
    unit: str = "cy"                      # occupation unit (cy | s)
    frequency_hz: float | None = None
    store_forward_latency: float = 0.0
    pipeline: PipelineParams | None = None
    # memory hierarchy for ECM predictions (None = the paper's
    # infinite-L1 assumption; every bound stays in-core)
    hierarchy: MemoryHierarchy | None = None
    forms: tuple[InstrForm, ...] = ()     # the instruction-form table
    constants: Mapping[str, object] = field(default_factory=dict)

    def __post_init__(self) -> None:
        # normalize sequence fields so JSON-sourced lists compare equal
        # to hand-written tuples (and the value stays hashless-frozen);
        # constants are canonicalized to plain JSON types for the same
        # reason (a tuple-valued constant would round-trip to a list
        # and break from_dict(m.to_dict()) == m)
        for f in ("ports", "aliases", "divider_ports", "forms"):
            object.__setattr__(self, f, tuple(getattr(self, f)))
        object.__setattr__(self, "constants", _plain(dict(self.constants)))
        # JSON derivation files pass hierarchy overrides as plain dicts
        # through derive() -> replace(); coerce here so every path ends
        # at the same frozen value
        hz = self.hierarchy
        if hz is not None and not isinstance(hz, MemoryHierarchy):
            object.__setattr__(
                self, "hierarchy",
                MemoryHierarchy.from_dict(hz) if isinstance(hz, Mapping)
                else MemoryHierarchy(levels=tuple(hz)))
        if not self.arch_id:
            raise ValueError("arch_id must be non-empty")
        if self.arch_id != self.arch_id.lower():
            raise ValueError(f"arch_id must be lowercase: {self.arch_id!r}")
        if len(set(self.ports)) != len(self.ports):
            raise ValueError(f"duplicate ports in model {self.arch_id!r}")
        undeclared = set(self.divider_ports) - set(self.ports)
        if undeclared:
            raise ValueError(
                f"divider ports {sorted(undeclared)} not in the port list "
                f"of model {self.arch_id!r}")
        seen = {self.arch_id}
        for a in self.aliases:
            if a != a.lower():
                raise ValueError(f"alias must be lowercase: {a!r}")
            if a in seen:
                raise ValueError(
                    f"alias {a!r} duplicates the id or another alias of "
                    f"model {self.arch_id!r}")
            seen.add(a)
        known = set(self.ports)
        for f in self.forms:
            for u in f.uops:
                unknown = set(u.ports) - known
                if unknown:
                    raise ValueError(
                        f"form {f.mnemonic!r} references unknown ports "
                        f"{sorted(unknown)} (model {self.arch_id!r} has "
                        f"{self.ports})")

    # ------------------------------------------------------------------
    # runtime views (engine-facing objects, built once per instance)
    # ------------------------------------------------------------------
    @property
    def port_model(self) -> PortModel:
        """The engine-facing :class:`PortModel` view of this spec."""
        pm = self.__dict__.get("_port_model")
        if pm is None:
            pm = PortModel(
                name=self.name, ports=self.ports,
                divider_ports=frozenset(self.divider_ports),
                store_hides_load=self.store_hides_load, unit=self.unit,
                frequency_hz=self.frequency_hz,
                store_forward_latency=self.store_forward_latency,
                pipeline=self.pipeline)
            self.__dict__["_port_model"] = pm
        return pm

    def build_db(self) -> InstructionDB:
        """A *fresh* :class:`InstructionDB` from the form table (callers
        that mutate their copy get isolation; :meth:`database` caches)."""
        return InstructionDB(self.arch_id, self.port_model, self.forms)

    def database(self) -> InstructionDB:
        """The memoized instruction database of this model — built once
        per :class:`MachineModel` instance and shared by every consumer
        (the registry adds a per-``arch_id`` layer on top)."""
        db = self.__dict__.get("_db")
        if db is None:
            db = self.build_db()
            self.__dict__["_db"] = db
        return db

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema": SCHEMA,
            "arch_id": self.arch_id,
            "name": self.name,
            "aliases": list(self.aliases),
            "ports": list(self.ports),
            "divider_ports": list(self.divider_ports),
            "store_hides_load": self.store_hides_load,
            "unit": self.unit,
            "frequency_hz": None if self.frequency_hz is None
            else float(self.frequency_hz),
            "store_forward_latency": float(self.store_forward_latency),
            "pipeline": None if self.pipeline is None else {
                "issue_width": self.pipeline.issue_width,
                "rob_size": self.pipeline.rob_size,
                "scheduler_size": self.pipeline.scheduler_size,
                "retire_width": self.pipeline.retire_width,
                "predecode_width": self.pipeline.predecode_width,
                "decode_width": self.pipeline.decode_width,
                "complex_decode_width":
                    self.pipeline.complex_decode_width,
                "dsb_width": self.pipeline.dsb_width,
                "dsb_size": self.pipeline.dsb_size,
                "lsd_size": self.pipeline.lsd_size,
                "macro_fusion": self.pipeline.macro_fusion,
                "micro_fusion": self.pipeline.micro_fusion,
                "move_elimination": self.pipeline.move_elimination,
                "mispredict_penalty":
                    float(self.pipeline.mispredict_penalty),
            },
            "hierarchy": None if self.hierarchy is None
            else self.hierarchy.to_dict(),
            "constants": _plain(self.constants),
            "forms": [_form_to_dict(f) for f in self.forms],
        }

    @classmethod
    def from_dict(cls, data: Mapping) -> "MachineModel":
        schema = data.get("schema", SCHEMA)
        if schema != SCHEMA:
            raise ValueError(f"unsupported machine-model schema {schema!r} "
                             f"(expected {SCHEMA!r})")
        pl = data.get("pipeline")
        return cls(
            arch_id=data["arch_id"], name=data["name"],
            ports=tuple(data["ports"]),
            aliases=tuple(data.get("aliases", ())),
            divider_ports=tuple(data.get("divider_ports", ())),
            store_hides_load=bool(data.get("store_hides_load", False)),
            unit=str(data.get("unit", "cy")),
            frequency_hz=data.get("frequency_hz"),
            store_forward_latency=float(
                data.get("store_forward_latency", 0.0)),
            pipeline=None if pl is None else PipelineParams(
                issue_width=int(pl["issue_width"]),
                rob_size=int(pl["rob_size"]),
                scheduler_size=int(pl["scheduler_size"]),
                retire_width=int(pl["retire_width"]),
                # front-end block: absent in pre-front-end model files,
                # which load as "stage not modelled" (the same defaults
                # PipelineParams declares)
                predecode_width=int(pl.get("predecode_width", 0)),
                decode_width=int(pl.get("decode_width", 0)),
                complex_decode_width=int(
                    pl.get("complex_decode_width", 1)),
                dsb_width=int(pl.get("dsb_width", 0)),
                dsb_size=int(pl.get("dsb_size", 0)),
                lsd_size=int(pl.get("lsd_size", 0)),
                macro_fusion=bool(pl.get("macro_fusion", False)),
                micro_fusion=bool(pl.get("micro_fusion", False)),
                move_elimination=bool(
                    pl.get("move_elimination", False)),
                mispredict_penalty=float(
                    pl.get("mispredict_penalty", 0.0))),
            hierarchy=data.get("hierarchy"),
            constants=dict(data.get("constants", {})),
            forms=tuple(_form_from_dict(f)
                        for f in data.get("forms", ())))

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    @classmethod
    def from_json(cls, text: str) -> "MachineModel":
        return cls.from_dict(json.loads(text))

    @property
    def digest(self) -> str:
        """sha256 of the canonical JSON form — a content address for
        shipping the model to workers / keying distributed caches."""
        d = self.__dict__.get("_digest")
        if d is None:
            canon = json.dumps(self.to_dict(), sort_keys=True,
                               separators=(",", ":"))
            d = hashlib.sha256(canon.encode()).hexdigest()
            self.__dict__["_digest"] = d
        return d

    # ------------------------------------------------------------------
    # variants
    # ------------------------------------------------------------------
    def derive(self, arch_id: str, **overrides) -> "MachineModel":
        """A variant architecture sharing this model's tables.

        ``aliases`` reset to ``()`` unless overridden (a derived model
        must not steal its base's names); everything else defaults to
        the base value.  The (usually large) ``forms`` tuple is shared
        by reference, so variants are cheap::

            clx = skl.derive("clx", name="Intel Cascade Lake",
                             frequency_hz=2.4e9)
        """
        overrides.setdefault("aliases", ())
        bad = set(overrides) - {f.name for f in fields(self)}
        if bad:
            raise TypeError(f"unknown MachineModel fields: {sorted(bad)}")
        return replace(self, arch_id=arch_id, **overrides)

    # ------------------------------------------------------------------
    # alternative constructors
    # ------------------------------------------------------------------
    @classmethod
    def from_port_model(cls, pm: PortModel, *, arch_id: str,
                        aliases: Sequence[str] = (),
                        forms: Sequence[InstrForm] = (),
                        constants: Mapping[str, object] | None = None,
                        hierarchy: MemoryHierarchy | None = None,
                        ) -> "MachineModel":
        """Lift an existing :class:`PortModel` literal (single source of
        truth for the topology in the hand-written arch modules) into a
        full spec."""
        model = cls(
            arch_id=arch_id, name=pm.name, ports=pm.ports,
            aliases=tuple(aliases),
            divider_ports=tuple(sorted(pm.divider_ports)),
            store_hides_load=pm.store_hides_load, unit=pm.unit,
            frequency_hz=pm.frequency_hz,
            store_forward_latency=pm.store_forward_latency,
            pipeline=pm.pipeline, hierarchy=hierarchy,
            forms=tuple(forms),
            constants=dict(constants or {}))
        # preserve identity with the source literal (db.model is pm)
        model.__dict__["_port_model"] = pm
        return model

    @classmethod
    def from_db(cls, arch_id: str, db: InstructionDB,
                aliases: Sequence[str] = ()) -> "MachineModel":
        """Wrap an already-built database (the ``register_db`` migration
        path): topology from ``db.model``, forms from ``db.entries()``."""
        return cls.from_port_model(
            db.model, arch_id=arch_id, aliases=aliases,
            forms=tuple(db.entries()))

    @classmethod
    def from_benchmarks(cls, records: Iterable[BenchRecord], *,
                        arch_id: str, name: str | None = None,
                        unit: str = "cy", pipelined: bool = True,
                        frequency_hz: float | None = None,
                        ) -> "MachineModel":
        """Semi-automatic model construction (paper Sec. II-B/II-C).

        For every instruction form, the ``parallelism=1`` record is the
        latency (dependency-chain) measurement and the fastest record of
        the sweep is the saturated reciprocal throughput.  Port count
        follows the paper's argument — *"the instruction form can be
        spread among two separate ports, because its throughput is one
        half"*:

        * ``pipelined=True`` (x86-style fully pipelined units): a form
          with reciprocal throughput ``rtp <= 1`` occupies
          ``round(1/rtp)`` ports for ~1 unit each; ``rtp > 1`` means a
          divider-style unpipelined unit — one port occupied for the
          full ``rtp``.
        * ``pipelined=False`` (the JAX host harness, where occupation
          equals latency): port count is ``round(latency / rtp)``.

        Ports are named ``"p0" .. "pN"`` and shared greedily from port 0,
        matching ``repro.core.bench.model_builder``.  The result
        validates against the hand-written Skylake/Zen tables in
        ``tests/test_machine_model.py``.
        """
        by_form: dict[tuple[str, str], list[BenchRecord]] = {}
        for r in records:
            by_form.setdefault((r.form, r.signature), []).append(r)
        if not by_form:
            raise ValueError("no benchmark records given")
        inferred: list[tuple[str, str, float, float, int, float]] = []
        for (form, sig), recs in by_form.items():
            lat_recs = [r for r in recs if r.parallelism == 1]
            if not lat_recs:
                raise ValueError(
                    f"form {form!r} has no parallelism=1 (latency) record")
            latency = min(r.value for r in lat_recs)
            rtp = min(r.value for r in recs)
            if rtp <= 0:
                raise ValueError(f"form {form!r} has non-positive timing")
            if pipelined:
                n_ports = max(1, round(1.0 / rtp)) if rtp < 1.0 else 1
            else:
                n_ports = max(1, round(latency / rtp))
            occupation = rtp * n_ports
            inferred.append((form, sig, latency, rtp, n_ports, occupation))
        width = max(n for _, _, _, _, n, _ in inferred)
        port_names = tuple(f"p{i}" for i in range(width))
        forms = tuple(
            InstrForm(
                mnemonic=form,
                signature=tuple(s for s in sig.split(",") if s),
                uops=(Uop(port_names[:n_ports], occupation),),
                throughput=rtp, latency=latency,
                notes=f"measured: {n_ports} port(s)")
            for form, sig, latency, rtp, n_ports, occupation in inferred)
        return cls(arch_id=arch_id,
                   name=name or f"{arch_id} (measured)",
                   ports=port_names, unit=unit,
                   frequency_hz=frequency_hz, forms=forms)


def _plain(value):
    """Deep-copy a constants tree into plain JSON-serializable types."""
    if isinstance(value, Mapping):
        return {str(k): _plain(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    return value


# --------------------------------------------------------------------------
# Coercion used across the pipeline entry points
# --------------------------------------------------------------------------

def as_database(source) -> InstructionDB:
    """Coerce any machine description into an :class:`InstructionDB`.

    Accepts an already-built database (pass-through), a
    :class:`MachineModel` (its memoized :meth:`~MachineModel.database`),
    or an architecture id / alias (resolved through the default
    :class:`~repro.core.arch.registry.ArchRegistry`).  Every analysis
    entry point (``analyze``, ``analyze_latency``, ``compile_program``,
    ``simulate_kernel``) funnels through this, so the whole pipeline is
    parameterized by one model object.
    """
    if isinstance(source, InstructionDB):
        return source
    if isinstance(source, MachineModel):
        if not source.forms:
            raise ValueError(
                f"machine model {source.arch_id!r} has no instruction-"
                f"form table — it cannot serve instruction-stream "
                f"analysis (accelerator/HLO analysis lives in "
                f"repro.core.hlo.analyzer)")
        return source.database()
    if isinstance(source, str):
        from .arch.registry import default_registry
        return default_registry().database(source)
    raise TypeError(
        f"expected InstructionDB, MachineModel or arch id, got "
        f"{type(source).__name__}")
