"""Critical-path / loop-carried-dependency analysis (beyond-paper).

The paper lists latency modeling as future work (Sec. IV-B) and shows why it
matters: the pi benchmark at -O1 keeps the accumulator on the stack, and the
store->load forwarded read-modify-write chain makes measurement ~2x the
port-bound prediction (paper Sec. III-B, Table V).  We implement it:

* dependency graph over architectural registers and memory locations
  (stack slots identified by their canonical operand text),
* intra-iteration edges weighted with producer latency,
* wrap (loop-carried) edges for values produced in iteration i and consumed
  in iteration i+1,
* LCD = the heaviest dependency cycle through one wrap edge; the runtime
  prediction is then max(throughput_bound, LCD).

Store->load forwarding latency is an architecture constant calibrated like
any other DB number (paper Sec. II methodology).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from .database import InstructionDB
from .isa import Instruction, Operand
from .machine import as_database

# mnemonics whose first (Intel-order) operand is read AND written
_RMW = {"add", "sub", "inc", "dec", "and", "or", "xor", "neg", "not",
        "shl", "shr", "sar", "adc", "sbb", "imul"}

# dependency-breaking zeroing idioms (paper Sec. I-B: "move elimination and
# zeroing idioms ... circumvent false data dependencies")
_ZERO_IDIOMS = {"xor", "vxorpd", "vxorps", "vpxor", "pxor", "xorps",
                "xorpd", "sub"}


def _is_zero_idiom(ins: Instruction) -> bool:
    if ins.mnemonic not in _ZERO_IDIOMS:
        return False
    regs = [op.reg for op in ins.operands if op.kind == "reg"]
    return len(regs) == len(ins.operands) and len(set(regs)) == 1


def _mem_key(op: Operand) -> str:
    return f"mem:{op.base}+{op.index}*{op.scale}+{op.displacement}"


def _reads_writes(ins: Instruction) -> tuple[list[str], list[str]]:
    """Return (reads, writes) as dependence keys, Intel operand order."""
    reads: list[str] = []
    writes: list[str] = []
    ops = ins.operands
    if not ops:
        return reads, writes
    if _is_zero_idiom(ins):
        # writes the destination with a constant; reads nothing
        return reads, [f"reg:{_canon_reg(ops[0].reg or '')}"]

    def key(op: Operand) -> str | None:
        if op.kind == "reg":
            return f"reg:{_canon_reg(op.reg or '')}"
        if op.kind == "mem":
            return _mem_key(op)
        return None

    # destination
    dst = ops[0]
    dk = key(dst)
    if dk is not None:
        writes.append(dk)
        # x86 VEX 2-source ops overwrite dst; legacy/int RMW also read it.
        if ins.mnemonic in _RMW or (dst.kind == "mem"):
            if dst.kind == "mem":
                pass  # stores don't read the slot
            else:
                reads.append(dk)
    # memory address registers are reads
    for op in ops:
        if op.kind == "mem":
            for r in (op.base, op.index):
                if r:
                    reads.append(f"reg:{_canon_reg(r)}")
    # sources
    for op in ops[1:]:
        k = key(op)
        if k is not None:
            reads.append(k)
    # cmp/test write nothing (flags ignored at this granularity)
    if ins.mnemonic in ("cmp", "test"):
        writes.clear()
        k0 = key(ops[0])
        if k0:
            reads.append(k0)
    return reads, writes


_ALIAS_64 = {"eax": "rax", "ebx": "rbx", "ecx": "rcx", "edx": "rdx",
             "esi": "rsi", "edi": "rdi", "ebp": "rbp", "esp": "rsp"}


def _canon_reg(name: str) -> str:
    n = name.lower()
    if n in _ALIAS_64:
        return _ALIAS_64[n]
    if n.endswith("d") and n[:-1].startswith("r") and n[1:-1].isdigit():
        return n[:-1]
    return n


@dataclass
class LatencyResult:
    loop_carried_cycles: float
    chain: list[Instruction]          # instructions on the critical cycle
    per_edge: list[tuple[int, int, float]]

    def render(self) -> str:
        lines = [f"Loop-carried dependency: "
                 f"{self.loop_carried_cycles:.2f} cy/iteration"]
        for ins in self.chain:
            lines.append(f"  | {ins.text}")
        return "\n".join(lines)


def dependency_edges(kernel: list[Instruction], db: InstructionDB,
                     store_forward_latency: float | None = None,
                     lookup: "Callable[[Instruction], object] | None" = None,
                     ) -> list[tuple[int, int, float, bool]]:
    """Dependency edges of one assembly iteration: ``(src, dst, weight,
    wrap)`` where ``weight`` is the producer latency (or the store->load
    forwarding latency for forwarded memory reads) and ``wrap`` marks
    loop-carried edges (value produced in iteration ``i``, consumed in
    ``i+1``).  Shared by :func:`analyze_latency` (LCD bound) and the
    cycle-level simulator's wakeup logic (``repro.core.sim``)."""
    db = as_database(db)
    if store_forward_latency is None:
        store_forward_latency = db.model.store_forward_latency
    if lookup is None:
        lookup = db.lookup
    n = len(kernel)
    lat: list[float] = []
    rw: list[tuple[list[str], list[str]]] = []
    store_like: list[bool] = []
    for ins in kernel:
        entry = lookup(ins)
        lat.append(entry.latency if entry is not None else 1.0)
        rw.append(_reads_writes(ins))
        store_like.append(ins.writes_memory())

    # last writer per key, scanning two unrolled iterations; edges crossing
    # the boundary are wrap edges.
    edges: list[tuple[int, int, float, bool]] = []  # (src, dst, w, wrap)
    writer: dict[str, tuple[int, int]] = {}  # key -> (iteration, index)
    for it in range(2):
        for i in range(n):
            reads, writes = rw[i]
            for k in reads:
                w = writer.get(k)
                if w is None:
                    continue
                wit, widx = w
                weight = lat[widx]
                if k.startswith("mem:") and store_like[widx]:
                    weight = store_forward_latency or lat[widx]
                if wit == it:
                    if widx < i:
                        edges.append((widx, i, weight, False))
                else:
                    edges.append((widx, i, weight, True))
            for k in writes:
                writer[k] = (it, i)
    return edges


def analyze_latency(kernel: list[Instruction], db: InstructionDB,
                    store_forward_latency: float | None = None,
                    lookup: "Callable[[Instruction], object] | None" = None,
                    edges: "list[tuple[int, int, float, bool]] | None"
                    = None) -> LatencyResult:
    """Loop-carried-dependency bound of one assembly iteration.

    Args:
        kernel: instructions of one assembly loop iteration.
        db: instruction-form database whose latencies weight the edges.
        store_forward_latency: store->load forwarding latency in model
            units; ``None`` defaults to ``db.model.store_forward_latency``.
        lookup: optional replacement for ``db.lookup`` (the batched
            ``AnalysisService`` passes a memoized one).
        edges: precomputed :func:`dependency_edges` result to analyze
            instead of re-deriving it (the batched ``AnalysisService``
            passes its memoized edge list).

    Returns:
        :class:`LatencyResult` with the heaviest dependency cycle through
        one wrap (iteration ``i`` -> ``i+1``) edge, per assembly iteration.
    """
    n = len(kernel)
    if edges is None:
        edges = dependency_edges(
            kernel, db, store_forward_latency=store_forward_latency,
            lookup=lookup)

    # LCD: for each wrap edge (u -> v), heaviest intra-iteration DAG path
    # v ->* u, plus the wrap weight, plus lat consumed at u? (edge weights
    # already carry producer latency).
    intra = [[] for _ in range(n)]
    for u, v, w, wrap in edges:
        if not wrap and u < v:
            intra[u].append((v, w))

    import functools

    @functools.lru_cache(maxsize=None)
    def longest_to(target: int, node: int) -> float:
        if node == target:
            return 0.0
        best = float("-inf")
        for v, w in intra[node]:
            if v <= target:
                sub = longest_to(target, v)
                if sub > float("-inf"):
                    best = max(best, w + sub)
        return best

    best_cycle = 0.0
    best_pair: tuple[int, int, float] | None = None
    for u, v, w, wrap in edges:
        if not wrap:
            continue
        path = longest_to(u, v) if v <= u else float("-inf")
        if v == u:
            path = 0.0
        if path > float("-inf") and w + path > best_cycle:
            best_cycle = w + path
            best_pair = (u, v, w)

    chain: list[Instruction] = []
    if best_pair is not None:
        u, v, _ = best_pair
        chain = [kernel[i] for i in range(v, u + 1)]
    return LatencyResult(best_cycle, chain,
                         [(u, v, w) for u, v, w, _ in edges])
