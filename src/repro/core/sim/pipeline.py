"""Cycle-level out-of-order pipeline simulator (the third backend).

The analytic port model (``repro.core.analysis``) assumes a perfectly
parallel front end and an infinite scheduler window; uiCA (PAPERS.md,
"Accurate Throughput Prediction of Basic Blocks on Recent Intel
Microarchitectures") shows those assumptions are exactly where analytic
predictions diverge from measurement.  This module simulates the missing
machinery cycle by cycle:

* **front end** — an explicit uiCA-style fetch/decode/delivery model
  (see :func:`frontend_schedule` and docs/frontend.md): instructions
  predecode and decode at configurable widths (multi-uop instructions
  are restricted to the complex decoders), small loops deliver from the
  uop cache (DSB) or lock down in the loop stream detector (LSD),
  cmp/test+branch pairs macro-fuse into one decode unit, micro-fused
  (laminated) uop pairs share one issue slot but keep two scheduler
  entries, reg-reg moves are eliminated at rename, and a
  branch-mispredict recovery penalty delays loop entry.  Up to
  ``PipelineParams.issue_width`` issue *slots* enter the backend per
  cycle, strictly in program order; zero-uop instructions (branches in
  the paper's model) consume no slot.  With every front-end field at
  its disabled default, one slot is one uop and delivery is
  unconstrained — bit-identical to the pre-front-end simulator,
* **finite windows** — every in-flight uop holds one ROB entry from
  issue to retirement and one scheduler entry from issue to dispatch;
  a full window stalls the front end,
* **dispatch** — per-cycle *oldest-ready-first* port arbitration over
  the same :class:`~repro.core.ports.Uop` port sets the analytic
  schedulers use; divider/double-pumped uops occupy their port for
  ``uop.cycles`` cycles,
* **wakeup** — a uop becomes ready when every producer instruction has
  begun execution and its latency (the edge weights of
  :func:`repro.core.latency.dependency_edges`, including store->load
  forwarding) has elapsed,
* **retirement** — up to ``retire_width`` completed uops leave the ROB
  per cycle, in order.

The simulator runs the loop body repeatedly and reports the steady-state
cycles per assembly iteration (periodic-delta detection: a steady state
that alternates, e.g. 4/5 cycles, is reported as its periodic mean 4.5
rather than never converging).

``simulate()`` is the reference implementation used by
``AnalysisService`` with ``mode="simulate"``;
``repro.core.sim.batch`` provides the vectorized struct-of-arrays
driver for bulk sweeps.  See docs/simulation.md.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Sequence

from ..analysis import hidden_instruction_indices
from ..database import InstructionDB
from ..isa import _BRANCHES, Instruction
from ..latency import dependency_edges
from ..machine import as_database
from ..ports import PipelineParams, PortModel

#: fallback window parameters for models that don't declare any
DEFAULT_PARAMS = PipelineParams()


@dataclass(frozen=True)
class SimUop:
    """One micro-op of the compiled loop body.

    ``ports`` may be empty: hidden uops (Zen store/load AGU pairing)
    execute without a port — they still take an issue slot and a ROB
    entry, but skip the scheduler.
    """

    instr_index: int
    ports: tuple[str, ...]
    cycles: float = 1.0


@dataclass(frozen=True)
class SimProgram:
    """A loop body compiled for simulation: struct-of-arrays friendly
    uop list + per-instruction latencies + dependency edges.

    The three ``*_prev`` / ``eliminable`` tuples are *capabilities*
    detected at compile time (which uop pairs can laminate, which
    instructions can macro-fuse, which moves can be eliminated); whether
    they take effect is decided per simulation by the
    :class:`~repro.core.ports.PipelineParams` feature flags — see
    :func:`frontend_schedule`.  Empty tuples mean "no capability"
    (programs compiled before the front-end model behave identically).
    """

    model: PortModel
    n_instructions: int
    uops: tuple[SimUop, ...]                          # program order
    latency: tuple[float, ...]                        # per instruction
    edges: tuple[tuple[int, int, float, bool], ...]   # (src, dst, w, wrap)
    # per uop: micro-fuses (laminates) with the previous uop
    fuse_prev: tuple[bool, ...] = ()
    # per uop: rename-eliminated when move_elimination is enabled
    eliminable: tuple[bool, ...] = ()
    # per instruction: macro-fuses with the previous instruction
    macro_prev: tuple[bool, ...] = ()

    @property
    def digest(self) -> str:
        """Content address of the compiled program (uops, latencies,
        edges, fusion capabilities, port list): two programs with equal
        digests simulate identically on equal pipeline parameters.
        Useful for deduplicating or labelling compiled programs; the
        service-level caches key on (machine digest, kernel) one stage
        earlier, so the kernel never compiles twice in the first
        place."""
        d = self.__dict__.get("_digest")
        if d is None:
            import hashlib
            canon = repr((self.model.name, self.model.ports,
                          self.n_instructions, self.uops, self.latency,
                          self.edges, self.fuse_prev, self.eliminable,
                          self.macro_prev))
            d = hashlib.sha256(canon.encode()).hexdigest()
            object.__setattr__(self, "_digest", d)
        return d

    @property
    def frontend_cycles(self) -> float:
        """Front-end lower bound per iteration under the model's own
        pipeline parameters: the issue-bandwidth bound (slots /
        issue_width) or the delivery bound of the selected front-end
        mode, whichever is larger."""
        params = self.model.pipeline or DEFAULT_PARAMS
        fe = frontend_schedule(self, params)
        return max(fe.n_slots / params.issue_width, fe.cpi)

    @property
    def port_bound_cycles(self) -> float:
        """Static uniform-scheduler port bound of one iteration (the
        analytic model's number, recomputed from the compiled uops)."""
        occ = {p: 0.0 for p in self.model.ports}
        for u in self.uops:
            if u.ports:
                share = u.cycles / len(u.ports)
                for p in u.ports:
                    occ[p] += share
        return max(occ.values(), default=0.0)


# --------------------------------------------------------------------------
# Front end: fusion slots + static delivery schedule
# --------------------------------------------------------------------------

#: bottleneck labels, most- to least-upstream (shared by the reference
#: tick loop, the batch drivers and the engine's memoized classifier)
BOTTLENECKS = ("empty", "decode", "dsb", "frontend", "ports",
               "dependencies")

#: human-readable names of the delivery modes (``FrontendSchedule.mode``)
FE_MODE_NAMES = {
    "ideal": "ideal delivery",
    "lsd": "LSD lock-down",
    "dsb": "DSB uop cache",
    "mite": "MITE decoders",
}


@dataclass(frozen=True)
class FrontendSchedule:
    """The front end of one (program, params) pair, resolved to a
    static per-iteration schedule.

    The loop body ends in a taken branch, so fetch/decode/delivery
    restart at the loop head every iteration: the cycle at which slot
    ``s`` of iteration ``it`` becomes *deliverable* is simply
    ``it * cpi + phase[s]`` — a static lower bound the issue stage
    takes a ``max`` against.  ``cpi == 0`` means delivery is
    unconstrained (ideal front end, or the loop locked down in the
    LSD).
    """

    slot_of: tuple[int, ...]        # per uop -> issue-slot index
    slot_start: tuple[bool, ...]    # per uop: first uop of its slot
    n_slots: int                    # issue slots per iteration
    eliminated: tuple[bool, ...]    # per uop: rename-eliminated
    mode: str                       # "ideal" | "lsd" | "dsb" | "mite"
    phase: tuple[float, ...]        # per slot: delivery offset (cycles)
    cpi: float                      # delivery cycles per iteration


def frontend_schedule(prog: SimProgram,
                      params: PipelineParams) -> FrontendSchedule:
    """Resolve ``prog``'s compiled fusion capabilities against
    ``params``'s feature flags into slots and a delivery schedule.

    Mode selection (first match wins):

    * ``lsd``  — the whole body fits in the loop stream detector:
      locked down past fetch and decode, delivery unconstrained.
    * ``dsb``  — the body fits in the uop cache: ``dsb_width`` uops per
      cycle, restarting at the loop head each iteration.
    * ``mite`` — legacy decode: ``predecode_width`` instructions
      length-marked and ``decode_width`` decoded per cycle, multi-slot
      instructions restricted to the ``complex_decode_width`` complex
      decoders, macro-fused pairs decoding as one unit.
    * ``ideal`` — no delivery stage modelled (the pre-front-end
      behavior).
    """
    n = len(prog.uops)
    fuse_prev = prog.fuse_prev or (False,) * n
    eliminable = prog.eliminable or (False,) * n
    eliminated = tuple(params.move_elimination and e
                       for e in eliminable)

    slot_of: list[int] = []
    s = -1
    for i in range(n):
        if not (params.micro_fusion and fuse_prev[i] and s >= 0):
            s += 1
        slot_of.append(s)
    n_slots = s + 1
    slot_start = tuple(i == 0 or slot_of[i] != slot_of[i - 1]
                       for i in range(n))

    mode, phase, cpi = "ideal", (0.0,) * n_slots, 0.0
    if n_slots:
        if params.lsd_size and n_slots <= params.lsd_size:
            mode = "lsd"
        elif params.dsb_width and params.dsb_size \
                and n_slots <= params.dsb_size:
            mode = "dsb"
            phase = tuple(float(i // params.dsb_width)
                          for i in range(n_slots))
            cpi = float(-(-n_slots // params.dsb_width))
        elif params.decode_width:
            mode = "mite"
            phase, cpi = _decode_walk(prog, params, slot_of,
                                      slot_start, n_slots)
    return FrontendSchedule(slot_of=tuple(slot_of),
                            slot_start=slot_start, n_slots=n_slots,
                            eliminated=eliminated, mode=mode,
                            phase=phase, cpi=cpi)


def _decode_walk(prog: SimProgram, params: PipelineParams,
                 slot_of: list[int], slot_start: tuple[bool, ...],
                 n_slots: int) -> tuple[tuple[float, ...], float]:
    """Static MITE walk of one loop body: which cycle does each issue
    slot leave the decoders?

    Decode units are instructions, with macro-fused cmp/test+branch
    pairs merged into one unit.  Per cycle, up to ``decode_width``
    units decode, of which at most ``complex_decode_width`` may be
    *complex* (produce more than one issue slot); a unit cannot decode
    before its instructions are length-marked by the predecoder
    (``predecode_width`` raw instructions per cycle).  Zero-slot units
    (branches, unmatched forms) still occupy a decoder.
    """
    slots_of_instr: list[list[int]] = \
        [[] for _ in range(prog.n_instructions)]
    for i, u in enumerate(prog.uops):
        if slot_start[i]:
            slots_of_instr[u.instr_index].append(slot_of[i])
    macro_prev = prog.macro_prev or (False,) * prog.n_instructions

    units: list[tuple[int, list[int]]] = []   # (raw instrs, slots)
    for idx in range(prog.n_instructions):
        if params.macro_fusion and macro_prev[idx] and units:
            raw, slots = units[-1]
            units[-1] = (raw + 1, slots + slots_of_instr[idx])
        else:
            units.append((1, list(slots_of_instr[idx])))

    pw = params.predecode_width
    cw = max(1, params.complex_decode_width)
    phase = [0.0] * n_slots
    raw_done = 0            # raw instructions predecoded before this unit
    cyc = 0                 # current decode cycle
    used = complex_used = 0
    for raw, slots in units:
        # a unit decodes no earlier than the cycle its *last* raw
        # instruction is length-marked
        pre = (raw_done + raw - 1) // pw if pw else 0
        raw_done += raw
        is_complex = len(slots) > 1
        while True:
            if cyc < pre:
                cyc, used, complex_used = pre, 0, 0
            if used >= params.decode_width or \
                    (is_complex and complex_used >= cw):
                cyc, used, complex_used = cyc + 1, 0, 0
                continue
            break
        used += 1
        complex_used += is_complex
        for s in slots:
            phase[s] = float(cyc)
    return tuple(phase), float(cyc + 1)


@dataclass
class SimResult:
    """Steady-state simulation outcome for one kernel.

    ``cycles_per_iteration`` is per *assembly* iteration, directly
    comparable with ``AnalysisResult.port_bound_cycles`` / ``lcd_cycles``.
    If not even one iteration retired within ``max_cycles``
    (``iterations == 0``, ``converged=False``), it degrades to the
    elapsed-cycle lower bound on a single iteration.
    """

    cycles_per_iteration: float
    iterations: int                   # loop bodies retired
    converged: bool
    bottleneck: str                   # one of BOTTLENECKS
    frontend_cycles: float            # issue-bandwidth bound per iteration
    port_busy: dict[str, float] = field(default_factory=dict)
    #                                 ^ busy cycles per iteration (average)
    params: PipelineParams = DEFAULT_PARAMS
    delivery_cycles: float = 0.0      # fetch/decode bound per iteration
    fe_mode: str = "ideal"            # delivery mode (FE_MODE_NAMES key)

    def render(self, precision: int = 2) -> str:
        p = precision
        lines = [f"Simulated: {self.cycles_per_iteration:.{p}f} "
                 f"cy/asm-it over {self.iterations} iterations "
                 f"({'steady state' if self.converged else 'NOT converged'},"
                 f" bottleneck: {self.bottleneck})"]
        # per-stage front-end attribution: the issue stage and the
        # delivery stage each get their own bound, with the binding one
        # marked (instead of the old single lumped issue-bandwidth line)
        issue_binds = self.bottleneck == "frontend"
        deliv_binds = self.bottleneck in ("decode", "dsb")
        lines.append(
            f"  issue: {self.frontend_cycles:.{p}f} cy/it at width "
            f"{self.params.issue_width}"
            + ("  <- binds" if issue_binds else ""))
        mode = FE_MODE_NAMES.get(self.fe_mode, self.fe_mode)
        if self.fe_mode != "ideal":
            bound = (f"{self.delivery_cycles:.{p}f} cy/it"
                     if self.delivery_cycles else "unconstrained")
            lines.append(f"  delivery [{mode}]: {bound}"
                         + ("  <- binds" if deliv_binds else ""))
        lines.append(f"  windows: ROB {self.params.rob_size}, "
                     f"scheduler {self.params.scheduler_size}")
        busy = {pt: c for pt, c in sorted(self.port_busy.items())
                if c > 1e-9}
        if busy:
            lines.append("  port busy [cy/it]: " + "  ".join(
                f"{pt}={c:.{p}f}" for pt, c in busy.items()))
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Compilation: kernel -> SimProgram
# --------------------------------------------------------------------------

def compile_program(kernel: Sequence[Instruction], db: InstructionDB,
                    lookup: Callable[[Instruction], object] | None = None,
                    edges: Sequence[tuple[int, int, float, bool]] | None
                    = None) -> SimProgram:
    """Match instruction forms and flatten one loop body into a
    :class:`SimProgram`.

    Mirrors the matching/hiding steps of
    :func:`repro.core.analysis.analyze`: unmatched or ignorable
    instructions contribute no uops (but keep a 1-cycle latency for the
    dependency edges), and on store-hides-load models the first hideable
    load per store executes port-less in the store's shadow.  ``db``
    accepts an :class:`InstructionDB`, a
    :class:`~repro.core.machine.MachineModel`, or an arch id/alias.
    ``edges`` optionally injects precomputed dependency edges (the
    batched ``AnalysisService`` passes its memoized
    :func:`repro.core.latency.dependency_edges` result).

    Besides the uop stream, compilation records the front-end fusion
    *capabilities* (which uop pairs laminate, which instruction pairs
    macro-fuse, which moves are eliminable); :func:`frontend_schedule`
    decides per simulation whether they take effect.
    """
    db = as_database(db)
    model = db.model
    if lookup is None:
        lookup = db.lookup
    kernel = list(kernel)
    entries = [lookup(ins) for ins in kernel]
    hidden_instrs = hidden_instruction_indices(model, entries)

    uops: list[SimUop] = []
    fuse_prev: list[bool] = []
    eliminable: list[bool] = []
    lat: list[float] = []
    for idx, e in enumerate(entries):
        lat.append(e.latency if e is not None else 1.0)
        if e is None:
            continue
        elim = _is_eliminable_move(kernel[idx])
        prev_kind: str | None = None
        prev_fused = False
        for uop in e.uops:
            hidden = idx in hidden_instrs and uop.hideable_load
            fused = (prev_kind is not None and not prev_fused
                     and _laminates(prev_kind, uop.kind))
            uops.append(SimUop(
                instr_index=idx,
                ports=() if hidden else tuple(uop.ports),
                cycles=max(1.0, uop.cycles)))
            fuse_prev.append(fused)
            eliminable.append(elim)
            prev_kind, prev_fused = uop.kind, fused

    macro_prev = tuple(
        idx > 0 and kernel[idx].mnemonic in _BRANCHES
        and kernel[idx - 1].mnemonic in ("cmp", "test")
        for idx in range(len(kernel)))

    if edges is None:
        edges = dependency_edges(kernel, db, lookup=lookup)
    return SimProgram(model=model, n_instructions=len(kernel),
                      uops=tuple(uops), latency=tuple(lat),
                      edges=tuple(edges), fuse_prev=tuple(fuse_prev),
                      eliminable=tuple(eliminable),
                      macro_prev=macro_prev)


#: uop kinds that never initiate a micro-fused pair on their own
_MEMORY_KINDS = ("load", "store-agu", "store-data", "div")


def _laminates(prev_kind: str, kind: str) -> bool:
    """May a uop of ``kind`` share an issue slot with the directly
    preceding uop of ``prev_kind`` (same instruction)?  The pairs are
    the classic laminated forms: load+op (either order), store
    address+data (and the Zen dual-AGU store), and an execute uop with
    its divider-pipe companion."""
    compute_prev = prev_kind not in _MEMORY_KINDS
    if kind == "load":
        return compute_prev
    if prev_kind == "load":
        return kind not in _MEMORY_KINDS
    if kind == "div":
        return compute_prev
    if prev_kind == "store-agu":
        return kind in ("store-agu", "store-data")
    return False


def _is_eliminable_move(ins: Instruction) -> bool:
    """Reg-reg moves are move-elimination candidates (executed at
    rename, no execution port).  Zero/sign-extending moves are not."""
    m = ins.mnemonic
    if not (m == "mov" or m.startswith("vmov") or
            m in ("movapd", "movaps", "movupd", "movups",
                  "movsd", "movss", "movdqa", "movdqu")):
        return False
    return (len(ins.operands) == 2
            and all(o.kind == "reg" for o in ins.operands))


# --------------------------------------------------------------------------
# The cycle loop
# --------------------------------------------------------------------------

class _Instance:
    """One dynamic instance of a static instruction (iteration, index)."""

    __slots__ = ("remaining", "exec_start", "ready")

    def __init__(self, n_uops: int):
        self.remaining = n_uops       # uops not yet dispatched
        self.exec_start = -1.0        # cycle its last uop dispatched
        self.ready: float | None = None   # memoized operand-ready cycle


def simulate(program: SimProgram,
             params: PipelineParams | None = None, *,
             max_iterations: int = 128,
             warmup_iterations: int = 2,
             max_period: int = 6,
             max_cycles: int = 50_000) -> SimResult:
    """Run ``program`` repeatedly and return the steady-state
    cycles/iteration.

    Args:
        program: compiled loop body (see :func:`compile_program`).
        params: pipeline parameters; defaults to
            ``program.model.pipeline`` (or :data:`DEFAULT_PARAMS`).
        max_iterations: iteration cap if no steady state is found.
        warmup_iterations: iterations excluded from convergence checks
            (window fill-up transient).
        max_period: longest periodic cycles/iteration pattern detected
            (e.g. 2 for an 11/12-cycle alternation).
        max_cycles: hard safety cap on simulated cycles.
    """
    if params is None:
        params = program.model.pipeline or DEFAULT_PARAMS
    n_uops = len(program.uops)
    n_instr = program.n_instructions
    if n_uops == 0:
        return SimResult(0.0, 0, True, "empty", 0.0, {}, params)
    fe = frontend_schedule(program, params)
    uop_ports = tuple(() if fe.eliminated[i] else u.ports
                      for i, u in enumerate(program.uops))

    uops_per_instr = [0] * n_instr
    for u in program.uops:
        uops_per_instr[u.instr_index] += 1
    in_edges: list[list[tuple[int, float, int]]] = \
        [[] for _ in range(n_instr)]
    for src, dst, w, wrap in program.edges:
        in_edges[dst].append((src, w, 1 if wrap else 0))

    ports = program.model.ports
    port_free = {p: 0.0 for p in ports}     # cycle the port frees up
    port_busy_total = {p: 0.0 for p in ports}
    dispatch_count = 0                      # port uops dispatched so far
    n_port_uops = sum(1 for p in uop_ports if p)
    # (port busy totals, dispatch count) at each iteration-retire boundary
    busy_snapshots: list[tuple[dict[str, float], int]] = []

    instances: dict[tuple[int, int], _Instance] = {}

    def instance(it: int, idx: int) -> _Instance:
        key = (it, idx)
        inst = instances.get(key)
        if inst is None:
            inst = instances[key] = _Instance(uops_per_instr[idx])
        return inst

    def exec_start_of(it: int, idx: int) -> float | None:
        """Cycle instance (it, idx) began executing; None if unknown yet.
        Zero-uop instructions (branches, unmatched forms) never occupy a
        port — they "execute" the moment their own operands are ready."""
        if uops_per_instr[idx] == 0:
            return ready_cycle(it, idx)
        inst = instance(it, idx)
        if inst.remaining > 0 or inst.exec_start < 0:
            return None
        return inst.exec_start

    def ready_cycle(it: int, idx: int) -> float | None:
        """Operand-ready cycle of instance (it, idx); None while some
        producer has not started executing."""
        inst = instance(it, idx)
        if inst.ready is not None:
            return inst.ready
        t_ready = 0.0
        for src, w, wrap in in_edges[idx]:
            pit = it - wrap
            if pit < 0:
                continue          # before the first iteration: no producer
            start = exec_start_of(pit, src)
            if start is None:
                return None
            t_ready = max(t_ready, start + w)
        inst.ready = t_ready
        return t_ready

    # steady-state detection history: only the last 2 * max_period
    # retirement deltas are ever compared, so the scan window is capped
    # instead of re-deriving the full delta pattern from iter_end on
    # every retirement (which made long non-periodic runs quadratic)
    deltas: deque[float] = deque(maxlen=2 * max_period)

    scheduler: list[int] = []     # global uop ids, in issue order
    # ROB entries are allocated at issue, in program order, and indexed
    # by global uop id; the value is the completion cycle (None while
    # the uop waits in the scheduler or executes).
    completion: list[float | None] = []
    rob_head = 0                  # uops retired so far

    next_global = 0               # next uop of the infinite stream
    target_uops = max_iterations * n_uops
    iter_end: list[float] = []    # retire cycle of each iteration's last uop

    t = 0
    result_cpi = 0.0
    converged = False
    last_progress = 0
    while t < max_cycles:
        progressed = False

        # ---- retire (frees ROB entries, in program order; bandwidth
        # counts fused-domain slots — a micro-fused pair's continuation
        # uop leaves with its slot for free) -------------------------
        retired = 0
        retired_uops = 0
        while rob_head < next_global:
            slot = fe.slot_start[rob_head % n_uops]
            if slot and retired >= params.retire_width:
                break
            done = completion[rob_head]
            if done is None or done > t:
                break
            rob_head += 1
            retired += slot
            retired_uops += 1
            if rob_head % n_uops == 0:    # an iteration fully retired
                iter_end.append(float(t))
                if len(iter_end) >= warmup_iterations + 2:
                    deltas.append(iter_end[-1] - iter_end[-2])
                busy_snapshots.append((dict(port_busy_total),
                                       dispatch_count))
        if retired_uops:
            progressed = True

        # ---- periodic steady-state detection (bounded window; the
        # average slope since warmup vetoes matches found inside the
        # window-fill transient, where a few equal deltas can appear
        # before the scheduler backlog reaches its steady occupancy) --
        if retired_uops and deltas:
            recent = list(deltas)
            a_i, b_i = warmup_iterations, len(iter_end) - 1
            slope = (iter_end[b_i] - iter_end[a_i]) / max(1, b_i - a_i)
            for p in range(1, max_period + 1):
                if len(recent) >= 2 * p and \
                        recent[-p:] == recent[-2 * p:-p]:
                    cand = sum(recent[-p:]) / p
                    if abs(cand - slope) > 0.25 + 0.02 * abs(slope):
                        continue
                    result_cpi = cand
                    converged = True
                    break
            if converged:
                break

        # ---- dispatch: per-port oldest-ready-first arbitration -------
        if scheduler:
            dispatched: set[int] = set()
            for port in ports:
                if port_free[port] > t:
                    continue
                for si, g in enumerate(scheduler):
                    if g in dispatched:
                        continue
                    it, local = divmod(g, n_uops)
                    uop = program.uops[local]
                    if port not in uop_ports[local]:
                        continue
                    r = ready_cycle(it, uop.instr_index)
                    if r is None or r > t:
                        continue
                    # scheduler is issue-ordered: first match = oldest
                    dispatched.add(g)
                    port_free[port] = t + uop.cycles
                    port_busy_total[port] += uop.cycles
                    inst = instance(it, uop.instr_index)
                    inst.remaining -= 1
                    inst.exec_start = max(inst.exec_start, float(t))
                    completion[g] = t + max(
                        1.0, program.latency[uop.instr_index])
                    break
            if dispatched:
                scheduler = [g for g in scheduler if g not in dispatched]
                dispatch_count += len(dispatched)
                progressed = True

        # ---- issue (in order, bounded by width/delivery/ROB/sched) ---
        # the width counts issue *slots* (micro-fused pairs share one);
        # a slot additionally waits for its front-end delivery cycle
        # and, at stream start, for the mispredict recovery penalty
        issued = 0
        issued_slots = 0
        while next_global < target_uops:
            it, local = divmod(next_global, n_uops)
            uop = program.uops[local]
            ports_u = uop_ports[local]
            if fe.slot_start[local]:
                if issued_slots >= params.issue_width:
                    break
                # the delivery schedule is anchored after the recovery
                # penalty: fetch only restarts once the mispredicted
                # loop branch resolves
                if fe.cpi and t < (params.mispredict_penalty
                                   + it * fe.cpi
                                   + fe.phase[fe.slot_of[local]]):
                    break
                if next_global == 0 and t < params.mispredict_penalty:
                    break
            if (next_global - rob_head) >= params.rob_size:
                break
            if ports_u and len(scheduler) >= params.scheduler_size:
                break
            if ports_u:
                completion.append(None)
                scheduler.append(next_global)
            else:
                # port-less uop (hidden load / eliminated move):
                # executes in another uop's shadow or at rename,
                # completing off its instruction's latency
                inst = instance(it, uop.instr_index)
                inst.remaining -= 1
                inst.exec_start = max(inst.exec_start, float(t))
                completion.append(
                    t + max(1.0, program.latency[uop.instr_index]))
            issued_slots += fe.slot_start[local]
            next_global += 1
            issued += 1
        if issued:
            progressed = True

        # ---- termination guards --------------------------------------
        if next_global >= target_uops and rob_head >= next_global:
            break                 # stream fully retired, no steady state
        if progressed:
            last_progress = t
        elif t - last_progress > 1024:
            break                 # deadlock guard (should not happen)
        t += 1

    if not converged:
        # fall back to the average slope over the simulated tail
        if len(iter_end) >= warmup_iterations + 2:
            a, b = warmup_iterations, len(iter_end) - 1
            result_cpi = (iter_end[b] - iter_end[a]) / (b - a)
        else:
            result_cpi = float(t) / max(1, len(iter_end))

    # steady-state port busy: dispatch-rate delta between the warmup
    # iteration boundary and the last one, normalised by how many
    # iterations' worth of uops were actually *dispatched* in that
    # window (the front end runs ahead of retirement, so counting
    # retired iterations would inflate the rates)
    if len(busy_snapshots) > warmup_iterations + 1 and n_port_uops:
        (first, d0) = busy_snapshots[warmup_iterations]
        (last, d1) = busy_snapshots[-1]
        span = max(1e-9, (d1 - d0) / n_port_uops)
        port_busy = {p: (last[p] - first[p]) / span for p in ports}
    else:
        port_busy = {p: c / max(1, len(iter_end))
                     for p, c in port_busy_total.items()}
    frontend = fe.n_slots / params.issue_width
    return SimResult(
        cycles_per_iteration=result_cpi,
        iterations=len(iter_end), converged=converged,
        bottleneck=_classify(result_cpi, frontend,
                             program.port_bound_cycles, fe.cpi,
                             fe.mode),
        frontend_cycles=frontend, port_busy=port_busy, params=params,
        delivery_cycles=fe.cpi, fe_mode=fe.mode)


def _classify(cpi: float, frontend: float, port_bound: float,
              delivery: float = 0.0, fe_mode: str = "ideal") -> str:
    """Name the binding constraint of a steady state (one of
    :data:`BOTTLENECKS`): fetch/decode delivery saturated ("decode" on
    the MITE path, "dsb" on the uop-cache path), issue bandwidth
    saturated ("frontend"), the static port requirement reached
    ("ports"), or neither resource explains the pace — the wakeup chain
    and finite windows do ("dependencies")."""
    if cpi <= 0:
        return "empty"
    if cpi <= max(frontend, delivery) * 1.02 + 0.51:
        if delivery > frontend * 1.02:
            return "decode" if fe_mode == "mite" else "dsb"
        return "frontend"
    if cpi <= port_bound * 1.05 + 0.51:
        return "ports"
    return "dependencies"


def simulate_kernel(kernel: Sequence[Instruction], db: InstructionDB,
                    params: PipelineParams | None = None,
                    lookup: Callable[[Instruction], object] | None = None,
                    **kwargs) -> SimResult:
    """Convenience: :func:`compile_program` + :func:`simulate`."""
    return simulate(compile_program(kernel, db, lookup=lookup),
                    params=params, **kwargs)
