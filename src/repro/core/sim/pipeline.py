"""Cycle-level out-of-order pipeline simulator (the third backend).

The analytic port model (``repro.core.analysis``) assumes a perfectly
parallel front end and an infinite scheduler window; uiCA (PAPERS.md,
"Accurate Throughput Prediction of Basic Blocks on Recent Intel
Microarchitectures") shows those assumptions are exactly where analytic
predictions diverge from measurement.  This module simulates the missing
machinery cycle by cycle:

* **front end** — up to ``PipelineParams.issue_width`` uops enter the
  backend per cycle, strictly in program order; zero-uop instructions
  (branches in the paper's model, macro-fused compares) consume no slot,
* **finite windows** — every in-flight uop holds one ROB entry from
  issue to retirement and one scheduler entry from issue to dispatch;
  a full window stalls the front end,
* **dispatch** — per-cycle *oldest-ready-first* port arbitration over
  the same :class:`~repro.core.ports.Uop` port sets the analytic
  schedulers use; divider/double-pumped uops occupy their port for
  ``uop.cycles`` cycles,
* **wakeup** — a uop becomes ready when every producer instruction has
  begun execution and its latency (the edge weights of
  :func:`repro.core.latency.dependency_edges`, including store->load
  forwarding) has elapsed,
* **retirement** — up to ``retire_width`` completed uops leave the ROB
  per cycle, in order.

The simulator runs the loop body repeatedly and reports the steady-state
cycles per assembly iteration (periodic-delta detection: a steady state
that alternates, e.g. 4/5 cycles, is reported as its periodic mean 4.5
rather than never converging).

``simulate()`` is the reference implementation used by
``AnalysisService`` with ``mode="simulate"``;
``repro.core.sim.batch`` provides the vectorized struct-of-arrays
driver for bulk sweeps.  See docs/simulation.md.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Sequence

from ..analysis import hidden_instruction_indices
from ..database import InstructionDB
from ..isa import Instruction
from ..latency import dependency_edges
from ..machine import as_database
from ..ports import PipelineParams, PortModel

#: fallback window parameters for models that don't declare any
DEFAULT_PARAMS = PipelineParams()


@dataclass(frozen=True)
class SimUop:
    """One micro-op of the compiled loop body.

    ``ports`` may be empty: hidden uops (Zen store/load AGU pairing)
    execute without a port — they still take an issue slot and a ROB
    entry, but skip the scheduler.
    """

    instr_index: int
    ports: tuple[str, ...]
    cycles: float = 1.0


@dataclass(frozen=True)
class SimProgram:
    """A loop body compiled for simulation: struct-of-arrays friendly
    uop list + per-instruction latencies + dependency edges."""

    model: PortModel
    n_instructions: int
    uops: tuple[SimUop, ...]                          # program order
    latency: tuple[float, ...]                        # per instruction
    edges: tuple[tuple[int, int, float, bool], ...]   # (src, dst, w, wrap)

    @property
    def digest(self) -> str:
        """Content address of the compiled program (uops, latencies,
        edges, port list): two programs with equal digests simulate
        identically on equal pipeline parameters.  Useful for
        deduplicating or labelling compiled programs; the service-level
        caches key on (machine digest, kernel) one stage earlier, so
        the kernel never compiles twice in the first place."""
        d = self.__dict__.get("_digest")
        if d is None:
            import hashlib
            canon = repr((self.model.name, self.model.ports,
                          self.n_instructions, self.uops, self.latency,
                          self.edges))
            d = hashlib.sha256(canon.encode()).hexdigest()
            object.__setattr__(self, "_digest", d)
        return d

    @property
    def frontend_cycles(self) -> float:
        """Issue-bandwidth lower bound: uops / issue_width per iteration."""
        params = self.model.pipeline or DEFAULT_PARAMS
        return len(self.uops) / params.issue_width

    @property
    def port_bound_cycles(self) -> float:
        """Static uniform-scheduler port bound of one iteration (the
        analytic model's number, recomputed from the compiled uops)."""
        occ = {p: 0.0 for p in self.model.ports}
        for u in self.uops:
            if u.ports:
                share = u.cycles / len(u.ports)
                for p in u.ports:
                    occ[p] += share
        return max(occ.values(), default=0.0)


@dataclass
class SimResult:
    """Steady-state simulation outcome for one kernel.

    ``cycles_per_iteration`` is per *assembly* iteration, directly
    comparable with ``AnalysisResult.port_bound_cycles`` / ``lcd_cycles``.
    If not even one iteration retired within ``max_cycles``
    (``iterations == 0``, ``converged=False``), it degrades to the
    elapsed-cycle lower bound on a single iteration.
    """

    cycles_per_iteration: float
    iterations: int                   # loop bodies retired
    converged: bool
    bottleneck: str                   # "frontend" | "ports" |
    #                                   "dependencies" | "empty"
    frontend_cycles: float            # issue-bandwidth bound per iteration
    port_busy: dict[str, float] = field(default_factory=dict)
    #                                 ^ busy cycles per iteration (average)
    params: PipelineParams = DEFAULT_PARAMS

    def render(self, precision: int = 2) -> str:
        lines = [f"Simulated: {self.cycles_per_iteration:.{precision}f} "
                 f"cy/asm-it over {self.iterations} iterations "
                 f"({'steady state' if self.converged else 'NOT converged'},"
                 f" bottleneck: {self.bottleneck})",
                 f"  front end: {self.frontend_cycles:.{precision}f} cy/it "
                 f"at issue width {self.params.issue_width}, "
                 f"ROB {self.params.rob_size}, "
                 f"scheduler {self.params.scheduler_size}"]
        busy = {p: c for p, c in sorted(self.port_busy.items()) if c > 1e-9}
        if busy:
            lines.append("  port busy [cy/it]: " + "  ".join(
                f"{p}={c:.{precision}f}" for p, c in busy.items()))
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Compilation: kernel -> SimProgram
# --------------------------------------------------------------------------

def compile_program(kernel: Sequence[Instruction], db: InstructionDB,
                    lookup: Callable[[Instruction], object] | None = None,
                    edges: Sequence[tuple[int, int, float, bool]] | None
                    = None) -> SimProgram:
    """Match instruction forms and flatten one loop body into a
    :class:`SimProgram`.

    Mirrors the matching/hiding steps of
    :func:`repro.core.analysis.analyze`: unmatched or ignorable
    instructions contribute no uops (but keep a 1-cycle latency for the
    dependency edges), and on store-hides-load models the first hideable
    load per store executes port-less in the store's shadow.  ``db``
    accepts an :class:`InstructionDB`, a
    :class:`~repro.core.machine.MachineModel`, or an arch id/alias.
    ``edges`` optionally injects precomputed dependency edges (the
    batched ``AnalysisService`` passes its memoized
    :func:`repro.core.latency.dependency_edges` result).
    """
    db = as_database(db)
    model = db.model
    if lookup is None:
        lookup = db.lookup
    kernel = list(kernel)
    entries = [lookup(ins) for ins in kernel]
    hidden_instrs = hidden_instruction_indices(model, entries)

    uops: list[SimUop] = []
    lat: list[float] = []
    for idx, e in enumerate(entries):
        lat.append(e.latency if e is not None else 1.0)
        if e is None:
            continue
        for uop in e.uops:
            hidden = idx in hidden_instrs and uop.hideable_load
            uops.append(SimUop(
                instr_index=idx,
                ports=() if hidden else tuple(uop.ports),
                cycles=max(1.0, uop.cycles)))

    if edges is None:
        edges = dependency_edges(kernel, db, lookup=lookup)
    return SimProgram(model=model, n_instructions=len(kernel),
                      uops=tuple(uops), latency=tuple(lat),
                      edges=tuple(edges))


# --------------------------------------------------------------------------
# The cycle loop
# --------------------------------------------------------------------------

class _Instance:
    """One dynamic instance of a static instruction (iteration, index)."""

    __slots__ = ("remaining", "exec_start", "ready")

    def __init__(self, n_uops: int):
        self.remaining = n_uops       # uops not yet dispatched
        self.exec_start = -1.0        # cycle its last uop dispatched
        self.ready: float | None = None   # memoized operand-ready cycle


def simulate(program: SimProgram,
             params: PipelineParams | None = None, *,
             max_iterations: int = 128,
             warmup_iterations: int = 2,
             max_period: int = 4,
             max_cycles: int = 50_000) -> SimResult:
    """Run ``program`` repeatedly and return the steady-state
    cycles/iteration.

    Args:
        program: compiled loop body (see :func:`compile_program`).
        params: pipeline parameters; defaults to
            ``program.model.pipeline`` (or :data:`DEFAULT_PARAMS`).
        max_iterations: iteration cap if no steady state is found.
        warmup_iterations: iterations excluded from convergence checks
            (window fill-up transient).
        max_period: longest periodic cycles/iteration pattern detected
            (e.g. 2 for an 11/12-cycle alternation).
        max_cycles: hard safety cap on simulated cycles.
    """
    if params is None:
        params = program.model.pipeline or DEFAULT_PARAMS
    n_uops = len(program.uops)
    n_instr = program.n_instructions
    if n_uops == 0:
        return SimResult(0.0, 0, True, "empty", 0.0, {}, params)

    uops_per_instr = [0] * n_instr
    for u in program.uops:
        uops_per_instr[u.instr_index] += 1
    in_edges: list[list[tuple[int, float, int]]] = \
        [[] for _ in range(n_instr)]
    for src, dst, w, wrap in program.edges:
        in_edges[dst].append((src, w, 1 if wrap else 0))

    ports = program.model.ports
    port_free = {p: 0.0 for p in ports}     # cycle the port frees up
    port_busy_total = {p: 0.0 for p in ports}
    dispatch_count = 0                      # port uops dispatched so far
    n_port_uops = sum(1 for u in program.uops if u.ports)
    # (port busy totals, dispatch count) at each iteration-retire boundary
    busy_snapshots: list[tuple[dict[str, float], int]] = []

    instances: dict[tuple[int, int], _Instance] = {}

    def instance(it: int, idx: int) -> _Instance:
        key = (it, idx)
        inst = instances.get(key)
        if inst is None:
            inst = instances[key] = _Instance(uops_per_instr[idx])
        return inst

    def exec_start_of(it: int, idx: int) -> float | None:
        """Cycle instance (it, idx) began executing; None if unknown yet.
        Zero-uop instructions (branches, unmatched forms) never occupy a
        port — they "execute" the moment their own operands are ready."""
        if uops_per_instr[idx] == 0:
            return ready_cycle(it, idx)
        inst = instance(it, idx)
        if inst.remaining > 0 or inst.exec_start < 0:
            return None
        return inst.exec_start

    def ready_cycle(it: int, idx: int) -> float | None:
        """Operand-ready cycle of instance (it, idx); None while some
        producer has not started executing."""
        inst = instance(it, idx)
        if inst.ready is not None:
            return inst.ready
        t_ready = 0.0
        for src, w, wrap in in_edges[idx]:
            pit = it - wrap
            if pit < 0:
                continue          # before the first iteration: no producer
            start = exec_start_of(pit, src)
            if start is None:
                return None
            t_ready = max(t_ready, start + w)
        inst.ready = t_ready
        return t_ready

    # steady-state detection history: only the last 2 * max_period
    # retirement deltas are ever compared, so the scan window is capped
    # instead of re-deriving the full delta pattern from iter_end on
    # every retirement (which made long non-periodic runs quadratic)
    deltas: deque[float] = deque(maxlen=2 * max_period)

    scheduler: list[int] = []     # global uop ids, in issue order
    # ROB entries are allocated at issue, in program order, and indexed
    # by global uop id; the value is the completion cycle (None while
    # the uop waits in the scheduler or executes).
    completion: list[float | None] = []
    rob_head = 0                  # uops retired so far

    next_global = 0               # next uop of the infinite stream
    target_uops = max_iterations * n_uops
    iter_end: list[float] = []    # retire cycle of each iteration's last uop

    t = 0
    result_cpi = 0.0
    converged = False
    last_progress = 0
    while t < max_cycles:
        progressed = False

        # ---- retire (frees ROB entries, in program order) ------------
        retired = 0
        while rob_head < next_global and retired < params.retire_width:
            done = completion[rob_head]
            if done is None or done > t:
                break
            rob_head += 1
            retired += 1
            if rob_head % n_uops == 0:    # an iteration fully retired
                iter_end.append(float(t))
                if len(iter_end) >= warmup_iterations + 2:
                    deltas.append(iter_end[-1] - iter_end[-2])
                busy_snapshots.append((dict(port_busy_total),
                                       dispatch_count))
        if retired:
            progressed = True

        # ---- periodic steady-state detection (bounded window) --------
        if retired and deltas:
            recent = list(deltas)
            for p in range(1, max_period + 1):
                if len(recent) >= 2 * p and \
                        recent[-p:] == recent[-2 * p:-p]:
                    result_cpi = sum(recent[-p:]) / p
                    converged = True
                    break
            if converged:
                break

        # ---- dispatch: per-port oldest-ready-first arbitration -------
        if scheduler:
            dispatched: set[int] = set()
            for port in ports:
                if port_free[port] > t:
                    continue
                for si, g in enumerate(scheduler):
                    if g in dispatched:
                        continue
                    it, local = divmod(g, n_uops)
                    uop = program.uops[local]
                    if port not in uop.ports:
                        continue
                    r = ready_cycle(it, uop.instr_index)
                    if r is None or r > t:
                        continue
                    # scheduler is issue-ordered: first match = oldest
                    dispatched.add(g)
                    port_free[port] = t + uop.cycles
                    port_busy_total[port] += uop.cycles
                    inst = instance(it, uop.instr_index)
                    inst.remaining -= 1
                    inst.exec_start = max(inst.exec_start, float(t))
                    completion[g] = t + max(
                        1.0, program.latency[uop.instr_index])
                    break
            if dispatched:
                scheduler = [g for g in scheduler if g not in dispatched]
                dispatch_count += len(dispatched)
                progressed = True

        # ---- issue (in order, bounded by width/ROB/scheduler) --------
        issued = 0
        while issued < params.issue_width and next_global < target_uops:
            it, local = divmod(next_global, n_uops)
            uop = program.uops[local]
            if (next_global - rob_head) >= params.rob_size:
                break
            if uop.ports and len(scheduler) >= params.scheduler_size:
                break
            if uop.ports:
                completion.append(None)
                scheduler.append(next_global)
            else:
                # port-less uop (hidden load): executes in another uop's
                # shadow, completing off its instruction's latency
                inst = instance(it, uop.instr_index)
                inst.remaining -= 1
                inst.exec_start = max(inst.exec_start, float(t))
                completion.append(
                    t + max(1.0, program.latency[uop.instr_index]))
            next_global += 1
            issued += 1
        if issued:
            progressed = True

        # ---- termination guards --------------------------------------
        if next_global >= target_uops and rob_head >= next_global:
            break                 # stream fully retired, no steady state
        if progressed:
            last_progress = t
        elif t - last_progress > 1024:
            break                 # deadlock guard (should not happen)
        t += 1

    if not converged:
        # fall back to the average slope over the simulated tail
        if len(iter_end) >= warmup_iterations + 2:
            a, b = warmup_iterations, len(iter_end) - 1
            result_cpi = (iter_end[b] - iter_end[a]) / (b - a)
        else:
            result_cpi = float(t) / max(1, len(iter_end))

    # steady-state port busy: dispatch-rate delta between the warmup
    # iteration boundary and the last one, normalised by how many
    # iterations' worth of uops were actually *dispatched* in that
    # window (the front end runs ahead of retirement, so counting
    # retired iterations would inflate the rates)
    if len(busy_snapshots) > warmup_iterations + 1 and n_port_uops:
        (first, d0) = busy_snapshots[warmup_iterations]
        (last, d1) = busy_snapshots[-1]
        span = max(1e-9, (d1 - d0) / n_port_uops)
        port_busy = {p: (last[p] - first[p]) / span for p in ports}
    else:
        port_busy = {p: c / max(1, len(iter_end))
                     for p, c in port_busy_total.items()}
    frontend = n_uops / params.issue_width
    return SimResult(
        cycles_per_iteration=result_cpi,
        iterations=len(iter_end), converged=converged,
        bottleneck=_classify(result_cpi, frontend,
                             program.port_bound_cycles),
        frontend_cycles=frontend, port_busy=port_busy, params=params)


def _classify(cpi: float, frontend: float, port_bound: float) -> str:
    """Name the binding constraint of a steady state: issue bandwidth
    saturated ("frontend"), the static port requirement reached
    ("ports"), or neither resource explains the pace — the wakeup chain
    and finite windows do ("dependencies")."""
    if cpi <= 0:
        return "empty"
    if cpi <= frontend * 1.02 + 0.51:
        return "frontend"
    if cpi <= port_bound * 1.05 + 0.51:
        return "ports"
    return "dependencies"


def simulate_kernel(kernel: Sequence[Instruction], db: InstructionDB,
                    params: PipelineParams | None = None,
                    lookup: Callable[[Instruction], object] | None = None,
                    **kwargs) -> SimResult:
    """Convenience: :func:`compile_program` + :func:`simulate`."""
    return simulate(compile_program(kernel, db, lookup=lookup),
                    params=params, **kwargs)
