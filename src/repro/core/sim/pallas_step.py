"""Pallas implementation of the port-arbitration inner step.

The hottest sub-step of the vectorized sweep recurrence
(``repro.core.sim.batch``) is port arbitration: mask the per-port
capacity accumulators with the uop's eligibility set, pick the
least-loaded port (first index on ties, matching ``np.argmin``), and
book the uop's cycles onto it.  ``backend="pallas"`` swaps the ``lax``
formulation for this kernel — worthwhile on TPU fleets where the
shard's ``[lanes, ports]`` capacity block lives in VMEM next to the
rest of the compiled recurrence; everywhere else the kernel runs in
interpreter mode (exact, float64-capable, slow), which is what the
parity tests exercise.

The kernel processes one whole shard (``JIT_SHARD`` lanes × ``P``
ports, a few KB) as a single block.  On real TPU hardware the float64
sweep dtype is unavailable — run the ``jit`` driver there, or accept
float32 (see docs/performance.md).
"""
from __future__ import annotations


def make_arbitration_step(n_ports: int):
    """Build the arbitration step for a ``n_ports``-wide machine.

    Returns ``step(port_cap, elig, cyc_upd) -> (new_cap, pmin)`` for a
    ``[lanes, n_ports]`` shard: ``pmin`` is each lane's least booked
    eligible capacity (``inf`` when no port is eligible) and
    ``new_cap`` books ``cyc_upd`` onto the winning port (``cyc_upd`` is
    0 for slots that occupy no port, so the booking is a no-op there).
    Semantically identical to the inline ``lax`` version in
    ``batch._compiled_run`` (the parity suite asserts it).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    interpret = jax.default_backend() != "tpu"

    def kernel(cap_ref, elig_ref, cyc_ref, cap_out, pmin_out):
        cap = cap_ref[...]
        pf = jnp.where(elig_ref[...], cap, jnp.inf)
        pmin_out[...] = jnp.min(pf, axis=1)
        choice = jnp.argmin(pf, axis=1)         # first index on ties
        oh = jax.lax.broadcasted_iota(
            jnp.int32, cap.shape, 1) == choice[:, None].astype(jnp.int32)
        cap_out[...] = cap + jnp.where(oh, cyc_ref[...][:, None], 0.0)

    def step(port_cap, elig, cyc_upd):
        return pl.pallas_call(
            kernel,
            out_shape=(
                jax.ShapeDtypeStruct(port_cap.shape, port_cap.dtype),
                jax.ShapeDtypeStruct((port_cap.shape[0],),
                                     port_cap.dtype),
            ),
            interpret=interpret,
        )(port_cap, elig, cyc_upd)

    return step
