"""Vectorized batch driver for the pipeline simulator.

``pipeline.simulate`` steps one kernel cycle by cycle — the reference
semantics.  This module simulates *many* kernels at once in a
struct-of-arrays pass: every per-uop quantity (issue cycle, operand
readiness, dispatch cycle, retire cycle) becomes a ``[batch]`` numpy
vector, and the driver sweeps the padded uop slots of all kernels in
lockstep, iteration by iteration.  The arrays are plain numpy and
jnp-compatible; the recurrences are the JAX-friendly formulation of the
same machine (timestamp algebra instead of a tick loop).

The reformulation replaces the per-cycle oldest-ready arbitration with
its program-order dataflow equivalent: each uop books the eligible port
with the least cumulative occupation, and a port's occupation total acts
as its earliest back-to-back start time (``start = max(ready,
cap[port])``, ``cap[port] += cycles``).  This models every port as
perfectly packable — gaps left by dependency-delayed uops can be filled
by younger work, which is what the tick loop's out-of-order dispatch
achieves explicitly.  The cost of that simplification is a longer
transient on kernels whose dependency chain initially outpaces a
saturated port (idle port time is "banked" until the backlog catches
up), so the driver runs more iterations than the reference simulator
and requires the delta pattern to repeat three times before declaring a
steady state; ``tests/test_simulator.py`` locks the two drivers'
agreement on the paper kernels.  Front-end width, ROB and scheduler
occupancy, and retirement bandwidth are modelled identically, as
ring-buffer recurrences:

    issue[g]  >= issue[g - issue_width] + 1          (front end)
    issue[g]  >= retire[g - rob_size]                (finite ROB)
    issue[g]  >= dispatch[g' - scheduler_size]       (finite scheduler)
    retire[g] >= retire[g - retire_width] + 1        (retire bandwidth)

Batches mixing architectures are grouped by machine model internally;
each group runs as one vectorized pass.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..ports import PipelineParams
from .pipeline import DEFAULT_PARAMS, SimProgram, SimResult, _classify

_NEG = -1e18


@dataclass
class _Group:
    """Programs sharing one machine model, padded to common shapes."""

    programs: list[SimProgram]
    indices: list[int]                # positions in the caller's batch


def _composed_edges(prog: SimProgram) -> list[tuple[int, int, float, bool]]:
    """Dependency edges with zero-uop producers composed away.

    The slot sweep only learns execution times at uop slots, so an edge
    whose producer compiled to zero uops (unmatched form) would read the
    uninitialised sentinel and silently vanish.  The reference simulator
    treats such producers as executing the moment their own operands are
    ready; the dataflow equivalent is edge composition: ``s -w1-> z
    -w2-> d`` with zero-uop ``z`` becomes ``s -(w1+w2)-> d``.  Wrap hops
    saturate at one iteration (the consumer looks back exactly one
    iteration, which can only over-delay — conservative), and self-loops
    on zero-uop nodes are dropped to keep the rewrite finite.
    """
    has_uops = [False] * prog.n_instructions
    for u in prog.uops:
        has_uops[u.instr_index] = True
    edges = [(s, d, w, bool(h)) for s, d, w, h in prog.edges]
    for _ in range(prog.n_instructions):
        if all(has_uops[s] for s, _, _, _ in edges):
            break
        in_by: dict[int, list[tuple[int, int, float, bool]]] = {}
        for e in edges:
            in_by.setdefault(e[1], []).append(e)
        out: dict[tuple[int, int, bool], float] = {}

        def keep(s: int, d: int, w: float, h: bool) -> None:
            k = (s, d, h)
            out[k] = max(out.get(k, float("-inf")), w)

        for s, d, w, h in edges:
            if has_uops[s]:
                keep(s, d, w, h)
                continue
            for s2, _, w2, h2 in in_by.get(s, ()):
                if s2 == s:
                    continue          # zero-uop self-loop: drop
                keep(s2, d, w + w2, h or h2)
        edges = [(s, d, w, h) for (s, d, h), w in out.items()]
    return [e for e in edges if has_uops[e[0]]]


def simulate_many(programs: list[SimProgram],
                  params: PipelineParams | None = None, *,
                  n_iterations: int = 96,
                  warmup_iterations: int = 4,
                  max_period: int = 4) -> list[SimResult]:
    """Simulate every program; results match the input order.

    Args:
        programs: compiled loop bodies (see
            :func:`repro.core.sim.pipeline.compile_program`); mixed
            architectures are allowed.
        params: pipeline parameters forced for the whole batch;
            default: each program's own ``model.pipeline``.
        n_iterations: loop bodies simulated per kernel (fixed, unlike
            the reference simulator's adaptive convergence loop — the
            vectorized pass has no early exit).
        warmup_iterations: iterations excluded from the steady-state
            slope.
        max_period: longest periodic delta pattern accepted as
            convergence.
    """
    groups: dict[tuple, _Group] = {}
    for pos, prog in enumerate(programs):
        p = params or prog.model.pipeline or DEFAULT_PARAMS
        key = (prog.model.ports, p)
        g = groups.setdefault(key, _Group([], []))
        g.programs.append(prog)
        g.indices.append(pos)

    out: list[SimResult | None] = [None] * len(programs)
    for (ports, p), g in groups.items():
        results = _simulate_group(g.programs, ports, p, n_iterations,
                                  warmup_iterations, max_period)
        for pos, res in zip(g.indices, results):
            out[pos] = res
    return out  # type: ignore[return-value]


def _simulate_group(programs: list[SimProgram], ports: tuple[str, ...],
                    params: PipelineParams, n_iterations: int,
                    warmup: int, max_period: int) -> list[SimResult]:
    B = len(programs)
    P = len(ports)
    pindex = {p: i for i, p in enumerate(ports)}
    U = max((len(p.uops) for p in programs), default=0)
    I = max((p.n_instructions for p in programs), default=0)
    edge_lists = [_composed_edges(p) for p in programs]
    E = max((len(es) for es in edge_lists), default=0)
    if U == 0:
        return [SimResult(0.0, 0, True, "empty", 0.0, {}, params)
                for _ in programs]

    # ---- pack struct-of-arrays ---------------------------------------
    active = np.zeros((B, U), bool)         # real (non-padding) slots
    is_first = np.zeros((B, U), bool)       # first slot of its instruction
    instr_of = np.zeros((B, U), np.int64)
    has_port = np.zeros((B, U), bool)
    elig = np.zeros((B, U, P), bool)
    cyc = np.ones((B, U))                   # port occupation cycles
    lat = np.ones((B, U))                   # instruction latency
    e_valid = np.zeros((B, E), bool)
    e_src = np.zeros((B, E), np.int64)
    e_dst = np.zeros((B, E), np.int64)
    e_w = np.zeros((B, E))
    e_wrap = np.zeros((B, E), bool)
    for b, prog in enumerate(programs):
        seen: set[int] = set()
        for u, uop in enumerate(prog.uops):
            active[b, u] = True
            instr_of[b, u] = uop.instr_index
            if uop.instr_index not in seen:
                seen.add(uop.instr_index)
                is_first[b, u] = True
            if uop.ports:
                has_port[b, u] = True
                for pt in uop.ports:
                    elig[b, u, pindex[pt]] = True
            cyc[b, u] = max(1.0, uop.cycles)
            lat[b, u] = max(1.0, prog.latency[uop.instr_index])
        for e, (src, dst, w, wrap) in enumerate(edge_lists[b]):
            e_valid[b, e] = True
            e_src[b, e], e_dst[b, e], e_w[b, e] = src, dst, w
            e_wrap[b, e] = wrap

    n_uops = active.sum(axis=1)             # [B]
    rng = np.arange(B)

    # ---- state -------------------------------------------------------
    port_cap = np.zeros((B, P))     # cumulative booked cycles per port
    exec_prev = np.full((B, max(I, 1)), _NEG)
    last_issue = np.zeros(B)
    last_retire = np.zeros(B)
    issue_ring = np.full((B, params.issue_width), _NEG)
    retire_ring = np.full((B, params.rob_size), _NEG)
    disp_ring = np.full((B, params.scheduler_size), _NEG)
    rw_ring = np.full((B, params.retire_width), _NEG)
    g_ctr = np.zeros(B, np.int64)           # uops issued (ROB/front end)
    gp_ctr = np.zeros(B, np.int64)          # port uops issued (scheduler)
    iter_end = np.zeros((B, n_iterations))

    for it in range(n_iterations):
        exec_cur = np.full((B, max(I, 1)), _NEG)
        ready_cur = np.zeros((B, max(I, 1)))
        for u in range(U):
            a = active[:, u]
            if not a.any():
                continue
            i_b = instr_of[:, u]

            # -- issue: in-order, front-end width, finite ROB/scheduler
            t = np.maximum(last_issue, 0.0)
            t = np.maximum(t, issue_ring[rng, g_ctr % params.issue_width]
                           + 1.0)
            t = np.maximum(t, retire_ring[rng, g_ctr % params.rob_size])
            sched_gate = disp_ring[rng, gp_ctr % params.scheduler_size]
            t = np.maximum(t, np.where(has_port[:, u], sched_gate, _NEG))
            t = np.ceil(t)
            issue_t = np.where(a, t, last_issue)

            # -- operand readiness (first slot of each instruction)
            need = a & is_first[:, u]
            if need.any() and E:
                m = e_valid & (e_dst == i_b[:, None]) & need[:, None]
                src_exec = np.where(
                    e_wrap,
                    np.take_along_axis(exec_prev, e_src, axis=1),
                    np.take_along_axis(exec_cur, e_src, axis=1))
                contrib = np.where(m, src_exec + e_w, 0.0)
                contrib = np.maximum(contrib, 0.0)   # pit < 0: no producer
                ready = contrib.max(axis=1)
                ready_cur[need, i_b[need]] = ready[need]
            ready_t = ready_cur[rng, i_b]

            # -- dispatch: least-loaded eligible port; the port's booked
            #    capacity is its earliest back-to-back start time
            pf = np.where(elig[:, u], port_cap, np.inf)
            choice = pf.argmin(axis=1)
            lb = np.maximum(issue_t + 1.0, np.ceil(ready_t))
            start = np.maximum(lb, pf[rng, choice])
            start = np.where(has_port[:, u], start, issue_t)
            disp = np.where(a, start, 0.0)
            upd = a & has_port[:, u]
            port_cap[rng[upd], choice[upd]] += cyc[:, u][upd]
            new_exec = np.maximum(exec_cur[rng, i_b], disp)
            exec_cur[rng[a], i_b[a]] = new_exec[a]

            # -- retire: in-order, bounded bandwidth
            complete = disp + lat[:, u]
            r = np.maximum(complete, last_retire)
            r = np.maximum(r, rw_ring[rng, g_ctr % params.retire_width]
                           + 1.0)
            retire_t = np.where(a, r, last_retire)

            # -- commit state for active elements
            issue_ring[rng[a], (g_ctr % params.issue_width)[a]] = \
                issue_t[a]
            retire_ring[rng[a], (g_ctr % params.rob_size)[a]] = retire_t[a]
            rw_ring[rng[a], (g_ctr % params.retire_width)[a]] = retire_t[a]
            disp_ring[rng[upd], (gp_ctr % params.scheduler_size)[upd]] = \
                disp[upd]
            last_issue = np.where(a, issue_t, last_issue)
            last_retire = np.where(a, retire_t, last_retire)
            g_ctr = g_ctr + a
            gp_ctr = gp_ctr + upd
        iter_end[:, it] = last_retire
        exec_prev = exec_cur

    # ---- steady-state cycles/iteration -------------------------------
    deltas = np.diff(iter_end[:, warmup:], axis=1)
    span = deltas.shape[1]
    cpi = deltas[:, span // 2:].mean(axis=1) if span else last_retire
    converged = np.zeros(B, bool)
    for p in range(1, max_period + 1):
        if span >= 3 * p:
            # require the pattern to repeat three times: the capacity
            # accumulator can plateau mid-transient, and a 2x match
            # would mistake that plateau for the steady state
            match = np.all(
                (deltas[:, -p:] == deltas[:, -2 * p:-p])
                & (deltas[:, -p:] == deltas[:, -3 * p:-2 * p]), axis=1)
            new = match & ~converged
            if new.any():   # converged at period p: periodic mean
                cpi = np.where(new, deltas[:, -p:].mean(axis=1), cpi)
            converged |= match

    results = []
    for b, prog in enumerate(programs):
        if not prog.uops:
            results.append(SimResult(0.0, 0, True, "empty", 0.0, {},
                                     params))
            continue
        fe = len(prog.uops) / params.issue_width
        results.append(SimResult(
            cycles_per_iteration=float(cpi[b]),
            iterations=n_iterations, converged=bool(converged[b]),
            bottleneck=_classify(float(cpi[b]), fe,
                                 prog.port_bound_cycles),
            frontend_cycles=fe, port_busy={}, params=params))
    return results
