"""Vectorized batch drivers for the pipeline simulator.

``pipeline.simulate`` steps one kernel cycle by cycle — the reference
semantics.  This module simulates *many* kernels at once in a
struct-of-arrays pass: every per-uop quantity (issue cycle, operand
readiness, dispatch cycle, retire cycle) becomes a ``[batch]`` vector,
and the driver sweeps the padded uop slots of all kernels in lockstep,
iteration by iteration.  Padding is explicit: every slot, edge and
instruction row carries a validity *mask* (``active`` / ``e_valid`` /
the ``valid_*`` execution masks), and window constraints gate on the
issued-uop counters instead of sentinel timestamps, so the recurrence
is a pure, shape-static function of the packed arrays.

Two interchangeable backends run that function (``backend=``):

* ``"numpy"`` — the reference slot sweep, a Python loop over uop slots
  with ``[batch]``-vectorized numpy ops per slot.
* ``"jit"`` — the same recurrence compiled with ``jax.jit``:
  ``lax.scan`` over iterations and over uop slots, operating on
  ``[shard, ...]`` arrays in float64 (``enable_x64``) so the two
  backends agree to 1e-9 (``tests/test_sweep_engine.py`` locks this).
  Batches are cut into fixed-size, cache-resident shards (padded with
  empty lanes), so one compiled executable per (shape bucket, machine)
  serves every sweep size, and shards run concurrently on a small
  thread pool (XLA releases the GIL).  Three structural facts make the
  compiled step cheap: the uop counters — hence every ring index and
  window-gate boolean — depend only on the static active-slot pattern
  and are precomputed host-side; ROB/scheduler ring traffic hoists out
  of the slot loop (their windows exceed one iteration's uops, so all
  reads hit previous iterations: one gather at iteration start, one
  masked scatter at iteration end); and same-instruction slots are
  contiguous, so per-instruction execute/ready state collapses to
  running scalars plus an incrementally-maintained per-edge source
  vector (no gather/scatter in the inner step at all).
* ``"pallas"`` — the jit driver with the port-arbitration inner step
  swapped for a Pallas kernel (``sim/pallas_step.py``); built for TPU
  fleets, interpreted (slow, exact) elsewhere.

The reformulation replaces the per-cycle oldest-ready arbitration with
its program-order dataflow equivalent: each uop books the eligible port
with the least cumulative occupation, and a port's occupation total acts
as its earliest back-to-back start time (``start = max(ready,
cap[port])``, ``cap[port] += cycles``).  This models every port as
perfectly packable — gaps left by dependency-delayed uops can be filled
by younger work, which is what the tick loop's out-of-order dispatch
achieves explicitly.  The cost of that simplification is a longer
transient on kernels whose dependency chain initially outpaces a
saturated port (idle port time is "banked" until the backlog catches
up), so the driver runs more iterations than the reference simulator
and requires the delta pattern to repeat three times before declaring a
steady state; ``tests/test_simulator.py`` locks the two drivers'
agreement on the paper kernels.  Front-end width, ROB and scheduler
occupancy, and retirement bandwidth are modelled identically, as
ring-buffer recurrences:

    issue[s]  >= issue[s - issue_width] + 1          (issue slots)
    issue[s]  >= it * fe_cpi + fe_phase[s]           (fetch/decode)
    issue[g]  >= retire[g - rob_size]                (finite ROB)
    issue[g]  >= dispatch[g' - scheduler_size]       (finite scheduler)
    retire[g] >= retire[g - retire_width] + 1        (retire bandwidth)

where ``s`` counts issue *slots* (micro-fused uop pairs share one; with
the front end disabled every uop is its own slot and the delivery term
vanishes, reproducing the pre-front-end recurrence exactly) and the
delivery term is the static per-iteration schedule computed by
:func:`repro.core.sim.pipeline.frontend_schedule` — the loop body ends
in a taken branch, so fetch restarts at the loop head each iteration.
ROB and retirement stay in the uop domain; a laminated pair keeps its
two scheduler entries.  Rename-eliminated moves become port-less uops
(issue slot + ROB entry, no scheduler entry); the branch-mispredict
recovery penalty delays the first issue of the stream, which cancels
out of every steady-state delta.

Batches mixing architectures are grouped by machine model internally;
each group runs as one vectorized pass.  Kernels whose delta pattern
never repeats within ``n_iterations`` are reported with an explicit
``converged=False`` (the ``cycles_per_iteration`` then is the mean
slope of the simulated tail, a documented fallback — not a silently
promoted plateau).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..ports import PipelineParams
from .pipeline import (DEFAULT_PARAMS, SimProgram, SimResult, _classify,
                       frontend_schedule)

#: smallest per-group batch for which ``backend="auto"`` picks the
#: compiled driver (below it, numpy's per-slot loop is cheaper than a
#: compile-cache lookup + device transfer)
AUTO_JIT_MIN_BATCH = 16


def has_jax() -> bool:
    """True when the compiled (``"jit"`` / ``"pallas"``) backends can
    run in this process."""
    try:
        import jax  # noqa: F401
        import jax.experimental  # noqa: F401
    except Exception:      # pragma: no cover - env without jax
        return False
    return True


@dataclass
class _Group:
    """Programs sharing one machine model, padded to common shapes."""

    programs: list[SimProgram]
    indices: list[int]                # positions in the caller's batch


@dataclass
class _Packed:
    """One machine-model group packed as padded struct-of-arrays
    (the numpy reference layout; the compiled backend uses the
    slot-major :func:`_pack_lean` layout instead).

    Validity is carried by masks (``active`` for uop slots, ``e_valid``
    for dependency edges); padding rows are all-False and provably
    identity under the recurrence, which is what lets the drivers pad
    shapes without changing results.
    """

    ports: tuple[str, ...]
    params: PipelineParams
    active: np.ndarray          # [B, U] bool — real (non-padding) slots
    is_first: np.ndarray        # [B, U] bool — first slot of its instr
    instr_of: np.ndarray        # [B, U] int64
    has_port: np.ndarray        # [B, U] bool
    elig: np.ndarray            # [B, U, P] bool
    cyc: np.ndarray             # [B, U] f64 — port occupation cycles
    lat: np.ndarray             # [B, U] f64 — instruction latency
    slot_start: np.ndarray      # [B, U] bool — first uop of its issue slot
    phase_u: np.ndarray         # [B, U] f64 — delivery offset of the slot
    fe_cpi: np.ndarray          # [B] f64 — delivery cycles per iteration
    e_valid: np.ndarray         # [B, E] bool
    e_src: np.ndarray           # [B, E] int64
    e_dst: np.ndarray           # [B, E] int64
    e_w: np.ndarray             # [B, E] f64
    e_wrap: np.ndarray          # [B, E] bool
    n_instr: int                # padded instruction-row count (>= 1)

    @property
    def batch(self) -> int:
        return self.active.shape[0]

    @property
    def slots(self) -> int:
        return self.active.shape[1]


def _composed_edges(prog: SimProgram) -> list[tuple[int, int, float, bool]]:
    """Dependency edges with zero-uop producers composed away.

    The slot sweep only learns execution times at uop slots, so an edge
    whose producer compiled to zero uops (unmatched form) would never
    see a valid execution mask and silently vanish.  The reference
    simulator treats such producers as executing the moment their own
    operands are ready; the dataflow equivalent is edge composition:
    ``s -w1-> z -w2-> d`` with zero-uop ``z`` becomes ``s -(w1+w2)-> d``.
    Wrap hops saturate at one iteration (the consumer looks back exactly
    one iteration, which can only over-delay — conservative), and
    self-loops on zero-uop nodes are dropped to keep the rewrite finite.
    """
    has_uops = [False] * prog.n_instructions
    for u in prog.uops:
        has_uops[u.instr_index] = True
    edges = [(s, d, w, bool(h)) for s, d, w, h in prog.edges]
    for _ in range(prog.n_instructions):
        if all(has_uops[s] for s, _, _, _ in edges):
            break
        in_by: dict[int, list[tuple[int, int, float, bool]]] = {}
        for e in edges:
            in_by.setdefault(e[1], []).append(e)
        out: dict[tuple[int, int, bool], float] = {}

        def keep(s: int, d: int, w: float, h: bool) -> None:
            k = (s, d, h)
            out[k] = max(out.get(k, float("-inf")), w)

        for s, d, w, h in edges:
            if has_uops[s]:
                keep(s, d, w, h)
                continue
            for s2, _, w2, h2 in in_by.get(s, ()):
                if s2 == s:
                    continue          # zero-uop self-loop: drop
                keep(s2, d, w + w2, h or h2)
        edges = [(s, d, w, h) for (s, d, h), w in out.items()]
    return [e for e in edges if has_uops[e[0]]]


def _bucket(n: int) -> int:
    """Shape bucket for the compile cache: next multiple of 4 (padding
    slots cost real scan steps, so the bucket stays tight; multiples of
    4 still let kernels of similar size share one executable)."""
    return max(4, -(-n // 4) * 4)


def _pack(programs: list[SimProgram], ports: tuple[str, ...],
          params: PipelineParams) -> _Packed:
    B = len(programs)
    P = len(ports)
    pindex = {p: i for i, p in enumerate(ports)}
    edge_lists = [_composed_edges(p) for p in programs]
    U = max((len(p.uops) for p in programs), default=0)
    I = max((p.n_instructions for p in programs), default=0)
    E = max((len(es) for es in edge_lists), default=0)

    active = np.zeros((B, U), bool)
    is_first = np.zeros((B, U), bool)
    instr_of = np.zeros((B, U), np.int64)
    has_port = np.zeros((B, U), bool)
    elig = np.zeros((B, U, P), bool)
    cyc = np.ones((B, U))
    lat = np.ones((B, U))
    slot_start = np.zeros((B, U), bool)
    phase_u = np.zeros((B, U))
    fe_cpi = np.zeros(B)
    e_valid = np.zeros((B, E), bool)
    e_src = np.zeros((B, E), np.int64)
    e_dst = np.zeros((B, E), np.int64)
    e_w = np.zeros((B, E))
    e_wrap = np.zeros((B, E), bool)
    for b, prog in enumerate(programs):
        fe = frontend_schedule(prog, params)
        fe_cpi[b] = fe.cpi
        seen: set[int] = set()
        for u, uop in enumerate(prog.uops):
            active[b, u] = True
            instr_of[b, u] = uop.instr_index
            if uop.instr_index not in seen:
                seen.add(uop.instr_index)
                is_first[b, u] = True
            if uop.ports and not fe.eliminated[u]:
                has_port[b, u] = True
                for pt in uop.ports:
                    elig[b, u, pindex[pt]] = True
            cyc[b, u] = max(1.0, uop.cycles)
            lat[b, u] = max(1.0, prog.latency[uop.instr_index])
            slot_start[b, u] = fe.slot_start[u]
            if fe.cpi:
                phase_u[b, u] = fe.phase[fe.slot_of[u]]
        for e, (src, dst, w, wrap) in enumerate(edge_lists[b]):
            e_valid[b, e] = True
            e_src[b, e], e_dst[b, e], e_w[b, e] = src, dst, w
            e_wrap[b, e] = wrap
    return _Packed(ports=ports, params=params, active=active,
                   is_first=is_first, instr_of=instr_of,
                   has_port=has_port, elig=elig, cyc=cyc, lat=lat,
                   slot_start=slot_start, phase_u=phase_u,
                   fe_cpi=fe_cpi, e_valid=e_valid, e_src=e_src,
                   e_dst=e_dst, e_w=e_w, e_wrap=e_wrap,
                   n_instr=max(I, 1))


# --------------------------------------------------------------------------
# Reference backend: numpy slot sweep
# --------------------------------------------------------------------------

def _run_numpy(pk: _Packed, n_iterations: int) -> np.ndarray:
    """Run the masked recurrence in numpy; returns ``iter_end [B, T]``
    (the retire timestamp of each iteration's last uop)."""
    params = pk.params
    B, U, I = pk.batch, pk.slots, pk.n_instr
    E = pk.e_valid.shape[1]
    rng = np.arange(B)

    port_cap = np.zeros((B, len(pk.ports)))
    exec_prev = np.zeros((B, I))
    valid_prev = np.zeros((B, I), bool)
    last_issue = np.zeros(B)
    last_retire = np.zeros(B)
    issue_ring = np.zeros((B, params.issue_width))
    rob_ring = np.zeros((B, params.rob_size))
    disp_ring = np.zeros((B, params.scheduler_size))
    rw_ring = np.zeros((B, params.retire_width))
    g_ctr = np.zeros(B, np.int64)           # uops issued (ROB/retire)
    gp_ctr = np.zeros(B, np.int64)          # port uops issued (scheduler)
    s_ctr = np.zeros(B, np.int64)           # issue slots (front-end width)
    iter_end = np.zeros((B, n_iterations))

    for it in range(n_iterations):
        exec_cur = np.zeros((B, I))
        valid_cur = np.zeros((B, I), bool)
        ready_cur = np.zeros((B, I))
        for u in range(U):
            a = pk.active[:, u]
            if not a.any():
                continue
            i_b = pk.instr_of[:, u]
            hp = pk.has_port[:, u]
            ss = pk.slot_start[:, u]

            # -- issue: in-order, front-end width (counted in issue
            #    slots — micro-fused pairs share one), fetch/decode
            #    delivery, finite ROB/scheduler; a ring entry constrains
            #    only once the counter has wrapped past it (mask),
            #    never via a sentinel timestamp
            t = np.maximum(last_issue, 0.0)
            t = np.maximum(t, np.where(
                ss & (s_ctr >= params.issue_width),
                issue_ring[rng, s_ctr % params.issue_width] + 1.0, 0.0))
            t = np.maximum(t, np.where(
                ss, it * pk.fe_cpi + pk.phase_u[:, u]
                + np.where(pk.fe_cpi > 0,
                           params.mispredict_penalty, 0.0), 0.0))
            t = np.maximum(t, np.where(
                g_ctr == 0, params.mispredict_penalty, 0.0))
            t = np.maximum(t, np.where(
                g_ctr >= params.rob_size,
                rob_ring[rng, g_ctr % params.rob_size], 0.0))
            t = np.maximum(t, np.where(
                hp & (gp_ctr >= params.scheduler_size),
                disp_ring[rng, gp_ctr % params.scheduler_size], 0.0))
            t = np.ceil(t)
            issue_t = np.where(a, t, last_issue)

            # -- operand readiness (first slot of each instruction)
            need = a & pk.is_first[:, u]
            if need.any() and E:
                m = pk.e_valid & (pk.e_dst == i_b[:, None]) & need[:, None]
                src_exec = np.where(
                    pk.e_wrap,
                    np.take_along_axis(exec_prev, pk.e_src, axis=1),
                    np.take_along_axis(exec_cur, pk.e_src, axis=1))
                src_ok = np.where(
                    pk.e_wrap,
                    np.take_along_axis(valid_prev, pk.e_src, axis=1),
                    np.take_along_axis(valid_cur, pk.e_src, axis=1))
                contrib = np.where(m & src_ok, src_exec + pk.e_w, 0.0)
                contrib = np.maximum(contrib, 0.0)
                ready = contrib.max(axis=1)
                ready_cur[need, i_b[need]] = ready[need]
            ready_t = ready_cur[rng, i_b]

            # -- dispatch: least-loaded eligible port; the port's booked
            #    capacity is its earliest back-to-back start time
            pf = np.where(pk.elig[:, u], port_cap, np.inf)
            choice = pf.argmin(axis=1)
            lb = np.maximum(issue_t + 1.0, np.ceil(ready_t))
            start = np.maximum(lb, pf[rng, choice])
            start = np.where(hp, start, issue_t)
            disp = np.where(a, start, 0.0)
            upd = a & hp
            port_cap[rng[upd], choice[upd]] += pk.cyc[:, u][upd]
            cur = exec_cur[rng, i_b]
            new_exec = np.where(valid_cur[rng, i_b],
                                np.maximum(cur, disp), disp)
            exec_cur[rng[a], i_b[a]] = new_exec[a]
            valid_cur[rng[a], i_b[a]] = True

            # -- retire: in-order, bounded bandwidth counted in
            #    fused-domain slots (a micro-fused continuation uop
            #    leaves with its slot for free)
            complete = disp + pk.lat[:, u]
            r = np.maximum(complete, last_retire)
            r = np.maximum(r, np.where(
                ss & (s_ctr >= params.retire_width),
                rw_ring[rng, s_ctr % params.retire_width] + 1.0, 0.0))
            retire_t = np.where(a, r, last_retire)

            # -- commit state for active elements (the issue ring only
            #    advances on slot starts: width is a slot resource)
            su = a & ss
            issue_ring[rng[su], (s_ctr % params.issue_width)[su]] = \
                issue_t[su]
            rob_ring[rng[a], (g_ctr % params.rob_size)[a]] = retire_t[a]
            # the retire ring holds *slot* retire times: a continuation
            # uop overwrites its own slot's entry (s_ctr has not
            # advanced past it yet only for slot starts)
            slot_idx = np.where(ss, s_ctr, s_ctr - 1)
            rw_ring[rng[a], (slot_idx % params.retire_width)[a]] = \
                retire_t[a]
            disp_ring[rng[upd], (gp_ctr % params.scheduler_size)[upd]] = \
                disp[upd]
            last_issue = issue_t
            last_retire = retire_t
            g_ctr = g_ctr + a
            gp_ctr = gp_ctr + upd
            s_ctr = s_ctr + su
        iter_end[:, it] = last_retire
        exec_prev, valid_prev = exec_cur, valid_cur
    return iter_end


# --------------------------------------------------------------------------
# Compiled backend: jax.jit over the same recurrence, sharded
# --------------------------------------------------------------------------

#: lanes per compiled shard: small enough that the per-step working set
#: stays cache-resident, large enough to amortize dispatch; every batch
#: is padded (with empty lanes) to a multiple of this, so one compiled
#: executable per (shape bucket, machine) serves all sweep sizes
JIT_SHARD = 64

#: threads used to run shards concurrently (XLA releases the GIL)
_POOL_WORKERS = max(1, min(4, __import__("os").cpu_count() or 1))
_POOL = None


def _pool():
    global _POOL
    if _POOL is None:
        from concurrent.futures import ThreadPoolExecutor
        _POOL = ThreadPoolExecutor(max_workers=_POOL_WORKERS)
    return _POOL


def _jit_compatible(programs: list[SimProgram],
                    params: PipelineParams) -> bool:
    """The lean compiled recurrence assumes (a) same-instruction uop
    slots are contiguous (``compile_program`` always emits them so) and
    (b) one iteration's uops fit inside the ROB/scheduler windows, so
    every ring read references a previous iteration.  Programs violating
    either run on the numpy reference path (individually — they do not
    downgrade the rest of their group)."""
    for prog in programs:
        seen: set[int] = set()
        prev = -1
        n = n_p = 0
        for u in prog.uops:
            if u.instr_index != prev and u.instr_index in seen:
                return False                      # non-contiguous slots
            seen.add(u.instr_index)
            prev = u.instr_index
            n += 1
            n_p += bool(u.ports)
        if n > params.rob_size or n_p > params.scheduler_size:
            return False
    return True


def _pack_lean(programs: list[SimProgram], ports: tuple[str, ...],
               params: PipelineParams, n_iterations: int) -> dict:
    """Pack one shard for the compiled recurrence.

    Slot-major ``[U, B]`` layout (scan consumes leading-axis slices);
    window-gate booleans and ring index bases are precomputed here
    because the uop counters depend only on the static active pattern.
    """
    B = len(programs)
    P = len(ports)
    T = n_iterations
    pindex = {p: i for i, p in enumerate(ports)}
    edge_lists = [_composed_edges(p) for p in programs]
    U = _bucket(max(max((len(p.uops) for p in programs), default=0), 1))
    E = _bucket(max(max((len(es) for es in edge_lists), default=0), 1))

    active = np.zeros((U, B), bool)
    first = np.zeros((U, B), bool)
    same_prev = np.zeros((U, B), bool)
    has_port = np.zeros((U, B), bool)
    elig = np.zeros((U, B, P), bool)
    cyc_upd = np.zeros((U, B))          # booked cycles (0 = no port)
    lat = np.ones((U, B))
    slot_start = np.zeros((U, B), bool)
    phase_u = np.zeros((U, B))
    fe_cpi = np.zeros(B)
    m_dst = np.zeros((U, B, E), bool)   # edges feeding this slot's instr
    m_src = np.zeros((U, B, E), bool)   # edges sourced at this slot's
    e_w = np.zeros((B, E))              # instr
    e_wrap = np.zeros((B, E), bool)
    n_uops = np.zeros(B, np.int64)
    n_puops = np.zeros(B, np.int64)
    n_slots = np.zeros(B, np.int64)
    pre_g = np.zeros((U, B), np.int64)
    pre_gp = np.zeros((U, B), np.int64)
    pre_s = np.zeros((U, B), np.int64)
    for b, prog in enumerate(programs):
        fe = frontend_schedule(prog, params)
        fe_cpi[b] = fe.cpi
        es = edge_lists[b]
        for e, (_, _, w, wrap) in enumerate(es):
            e_w[b, e] = w
            e_wrap[b, e] = wrap
        seen: set[int] = set()
        g = gp = s = 0
        prev_instr = -1
        for u, uop in enumerate(prog.uops):
            active[u, b] = True
            pre_g[u, b] = g
            pre_gp[u, b] = gp
            pre_s[u, b] = s
            slot_start[u, b] = fe.slot_start[u]
            if fe.cpi:
                phase_u[u, b] = fe.phase[fe.slot_of[u]]
            if uop.instr_index not in seen:
                seen.add(uop.instr_index)
                first[u, b] = True
            same_prev[u, b] = (uop.instr_index == prev_instr)
            prev_instr = uop.instr_index
            if uop.ports and not fe.eliminated[u]:
                has_port[u, b] = True
                cyc_upd[u, b] = max(1.0, uop.cycles)
                for pt in uop.ports:
                    elig[u, b, pindex[pt]] = True
                gp += 1
            lat[u, b] = max(1.0, prog.latency[uop.instr_index])
            for e, (src, dst, _, _) in enumerate(es):
                if dst == uop.instr_index:
                    m_dst[u, b, e] = True
                if src == uop.instr_index:
                    m_src[u, b, e] = True
            g += 1
            s += fe.slot_start[u]
        n_uops[b] = g
        n_puops[b] = gp
        n_slots[b] = s
    # window gates per (iteration, slot, lane): the issued-uop counters
    # are static, so "has the ring wrapped yet" is data, not control;
    # the issue-width ring is a *slot* resource, so its gate also
    # requires a slot start
    it_ = np.arange(T)[:, None, None]
    g_abs = it_ * n_uops[None, None, :] + pre_g[None]       # [T, U, B]
    gp_abs = it_ * n_puops[None, None, :] + pre_gp[None]
    s_abs = it_ * n_slots[None, None, :] + pre_s[None]
    gm = np.stack([(s_abs >= params.issue_width) & slot_start[None],
                   g_abs >= params.rob_size,
                   (gp_abs >= params.scheduler_size) & has_port[None]],
                  axis=-1)                                  # [T, U, B, 3]
    # retire bandwidth is a fused-domain (slot) resource too
    g_rw = (s_abs >= params.retire_width) & slot_start[None]  # [T, U, B]
    # static fetch/decode delivery floor per (iteration, slot, lane),
    # anchored after the mispredict recovery penalty (fetch restarts
    # once the mispredicted loop branch resolves); on unconstrained
    # lanes the penalty still delays the very first issue
    deliv = np.where(slot_start[None],
                     it_ * fe_cpi[None, None, :] + phase_u[None]
                     + np.where(fe_cpi > 0.0,
                                params.mispredict_penalty,
                                0.0)[None, None, :], 0.0)
    deliv[0, 0, :] = np.maximum(deliv[0, 0, :],
                                params.mispredict_penalty)
    return dict(active=active, first=first, same_prev=same_prev,
                has_port=has_port, elig=elig, cyc_upd=cyc_upd, lat=lat,
                slot_start=slot_start, deliv=deliv,
                m_dst=m_dst, m_src=m_src, e_w=e_w, e_wrap=e_wrap,
                gm=gm, g_rw=g_rw, n_uops=n_uops, n_puops=n_puops,
                pre_g=pre_g.T, pre_gp=pre_gp.T, U=U, E=E)


_LEAN_ARGS = ("active", "first", "same_prev", "has_port", "elig",
              "cyc_upd", "lat", "slot_start", "deliv", "m_dst", "m_src",
              "e_w", "e_wrap", "gm", "g_rw", "n_uops", "n_puops",
              "pre_g", "pre_gp")


@functools.lru_cache(maxsize=128)
def _compiled_run(U: int, E: int, P: int, T: int,
                  params: PipelineParams, flavor: str):
    """Build (and cache) the compiled shard recurrence for one shape
    bucket.  ``flavor`` selects the port-arbitration implementation
    (``"lax"`` or ``"pallas"``)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    Wi, R = params.issue_width, params.rob_size
    S, Wr = params.scheduler_size, params.retire_width
    NEG = -jnp.inf

    if flavor == "pallas":
        from .pallas_step import make_arbitration_step
        arbitrate = make_arbitration_step(P)
    else:
        def arbitrate(port_cap, elig, cyc_upd):
            pf = jnp.where(elig, port_cap, jnp.inf)
            pmin = jnp.min(pf, axis=1)
            choice = jnp.argmin(pf, axis=1)     # first index on ties
            oh = jnp.arange(P)[None, :] == choice[:, None]
            return port_cap + jnp.where(oh, cyc_upd[:, None], 0.0), pmin

    def run(active, first, same_prev, has_port, elig, cyc_upd, lat,
            slot_start, deliv, m_dst, m_src, e_w, e_wrap, gm, g_rw,
            n_uops, n_puops, pre_g, pre_gp):
        B = active.shape[1]
        zeros = jnp.zeros((B,))
        rngB = jnp.arange(B)[:, None]

        def slot_step(carry, x):
            (port_cap, cur_e, prev_e, last_issue, last_retire,
             run_exec, run_ready, reg_i, reg_rw) = carry
            (a, fi, sp, hp, el, cu, lt, ssx, dlx, md, gmx, grw,
             rob_v, sch_v, ms) = x

            # issue: in-order, gated on the front-end / ROB / scheduler
            # ring heads (gm masks rings that have not wrapped yet —
            # the issue-width gate additionally requires a slot start)
            # plus the static fetch/decode delivery floor
            heads = jnp.concatenate(
                [reg_i[:, :1] + 1.0, rob_v[:, None], sch_v[:, None]],
                axis=1)
            t = jnp.maximum(
                last_issue,
                jnp.max(heads * gmx.astype(heads.dtype), axis=1))
            t = jnp.maximum(t, dlx)
            t = jnp.ceil(t)
            issue_t = jnp.where(a, t, last_issue)

            # operand readiness: evaluated at an instruction's first
            # slot from the per-edge source-execute vector; -inf is the
            # identity for "no producer yet" (exact under max/clamp)
            src = jnp.where(e_wrap, prev_e, cur_e) + e_w
            ready = jnp.maximum(
                jnp.max(jnp.where(md, src, NEG), axis=1), 0.0)
            ready_t = jnp.where(fi, ready, run_ready)

            # dispatch: least-loaded eligible port
            lb = jnp.maximum(issue_t + 1.0, jnp.ceil(ready_t))
            port_cap, pmin = arbitrate(port_cap, el, cu)
            start = jnp.where(hp, jnp.maximum(lb, pmin), issue_t)
            disp = jnp.where(a, start, 0.0)

            # execute: running per-instruction max (same-instruction
            # slots are contiguous), pushed onto outgoing edges
            new_exec = jnp.maximum(disp, jnp.where(sp, run_exec, NEG))
            cur_e = jnp.where(ms, new_exec[:, None], cur_e)

            # retire: in-order, bounded bandwidth
            complete = disp + lt
            r = jnp.maximum(complete, last_retire)
            r = jnp.maximum(r, jnp.where(grw, reg_rw[:, 0] + 1.0, 0.0))
            retire_t = jnp.where(a, r, last_retire)

            # the issue/retire rings hold *slot* times: they only
            # advance when a slot starts (fused continuation uops are
            # free); a continuation instead overwrites its own slot's
            # retire entry (retire_t is monotone, so this is its max)
            su1 = (a & ssx)[:, None]
            reg_i = jnp.where(su1, jnp.concatenate(
                [reg_i[:, 1:], issue_t[:, None]], axis=1), reg_i)
            reg_rw = jnp.where(su1, jnp.concatenate(
                [reg_rw[:, 1:], retire_t[:, None]], axis=1),
                jnp.where(a[:, None], reg_rw.at[:, -1].set(retire_t),
                          reg_rw))
            return (port_cap, cur_e, prev_e, issue_t, retire_t,
                    new_exec, ready_t, reg_i, reg_rw), (retire_t, disp)

        def iter_body(carry, g_it):
            (port_cap, prev_e, last_issue, last_retire,
             reg_i, reg_rw, rob_ring, sch_ring, it) = carry
            gmx, grw, dlv = g_it
            # ROB/scheduler ring traffic hoisted out of the slot loop:
            # one iteration's uops fit inside both windows (checked by
            # _jit_compatible), so every read hits a previous iteration
            # — gather them all up front, scatter the writes at the end
            g0 = it * n_uops[:, None] + pre_g               # [B, U]
            gp0 = it * n_puops[:, None] + pre_gp
            rob_v = rob_ring[rngB, (g0 - R) % R]
            sch_v = sch_ring[rngB, jnp.maximum(gp0 - S, 0) % S]
            c = (port_cap, jnp.full_like(prev_e, NEG), prev_e,
                 last_issue, last_retire, zeros, zeros, reg_i, reg_rw)
            xs = (active, first, same_prev, has_port, elig, cyc_upd,
                  lat, slot_start, dlv, m_dst, gmx, grw, rob_v.T,
                  sch_v.T, m_src)
            c, (ret_ts, disp_ts) = lax.scan(slot_step, c, xs, unroll=2)
            (port_cap, cur_e, _, last_issue, last_retire,
             _, _, reg_i, reg_rw) = c
            # masked scatter: padding slots write out of bounds -> drop
            w_idx = jnp.where(active.T, g0 % R, R)
            rob_ring = rob_ring.at[rngB, w_idx].set(ret_ts.T,
                                                    mode="drop")
            wp_idx = jnp.where((active & has_port).T, gp0 % S, S)
            sch_ring = sch_ring.at[rngB, wp_idx].set(disp_ts.T,
                                                     mode="drop")
            return (port_cap, cur_e, last_issue, last_retire,
                    reg_i, reg_rw, rob_ring, sch_ring,
                    it + 1), last_retire

        E_ = m_dst.shape[2]
        init = (jnp.zeros((B, P)), jnp.full((B, E_), NEG), zeros, zeros,
                jnp.zeros((B, Wi)), jnp.zeros((B, Wr)),
                jnp.zeros((B, R)), jnp.zeros((B, S)),
                jnp.zeros((), jnp.int64))
        _, iter_end = lax.scan(iter_body, init, (gm, g_rw, deliv))
        return iter_end.T                                   # [B, T]

    return jax.jit(run)


def _empty_program(model) -> SimProgram:
    return SimProgram(model=model, n_instructions=0, uops=(),
                      latency=(), edges=())


def _run_jax(programs: list[SimProgram], ports: tuple[str, ...],
             params: PipelineParams, n_iterations: int,
             flavor: str) -> np.ndarray:
    """Shard + run the compiled recurrence; agrees with
    :func:`_run_numpy` to 1e-9 because it executes the identical
    arithmetic in float64 (``jax.experimental.enable_x64``)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    B = len(programs)
    model = programs[0].model
    n_shards = -(-B // JIT_SHARD)
    shards = []
    for s in range(n_shards):
        chunk = programs[s * JIT_SHARD:(s + 1) * JIT_SHARD]
        chunk = chunk + [_empty_program(model)] * (JIT_SHARD - len(chunk))
        shards.append(_pack_lean(chunk, ports, params, n_iterations))

    def run_shard(pk: dict) -> np.ndarray:
        with enable_x64():
            fn = _compiled_run(pk["U"], pk["E"], len(ports),
                               n_iterations, params, flavor)
            args = [jnp.asarray(pk[k]) for k in _LEAN_ARGS]
            return np.asarray(fn(*args))

    if len(shards) == 1:
        outs = [run_shard(shards[0])]
    else:
        outs = list(_pool().map(run_shard, shards))
    return np.concatenate(outs, axis=0)[:B]


# --------------------------------------------------------------------------
# Steady state + entry point
# --------------------------------------------------------------------------

def _steady_state(iter_end: np.ndarray, warmup: int, max_period: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Per-lane steady-state cycles/iteration from the retire
    trajectory.  The periodic-pattern scan is bounded: only the last
    ``3 * max_period`` deltas are ever examined (the pattern must repeat
    three times — the capacity accumulator can plateau mid-transient,
    and a 2x match would mistake that plateau for the steady state).
    Lanes with no repeating pattern get an explicit ``converged=False``
    and fall back to the mean slope of the simulated tail.
    """
    B = iter_end.shape[0]
    deltas = np.diff(iter_end[:, warmup:], axis=1)
    span = deltas.shape[1]
    cpi = deltas[:, span // 2:].mean(axis=1) if span else \
        iter_end[:, -1].copy()
    # the tail-mean slope vetoes aliased matches: a long-period pattern
    # (e.g. a scheduler backlog that stalls every Nth iteration) can
    # end on p identical deltas without them being the steady state
    slope = cpi.copy()
    converged = np.zeros(B, bool)
    for p in range(1, max_period + 1):
        if span >= 3 * p:
            pval = deltas[:, -p:].mean(axis=1)
            match = np.all(
                (deltas[:, -p:] == deltas[:, -2 * p:-p])
                & (deltas[:, -p:] == deltas[:, -3 * p:-2 * p]), axis=1)
            match &= np.abs(pval - slope) <= 0.25 + 0.02 * np.abs(slope)
            new = match & ~converged
            if new.any():   # converged at period p: periodic mean
                cpi = np.where(new, pval, cpi)
            converged |= match
    return cpi, converged


def _resolve_backend(backend: str, batch: int) -> str:
    if backend == "auto":
        if batch >= AUTO_JIT_MIN_BATCH and has_jax():
            return "jit"
        return "numpy"
    if backend in ("numpy", "jit", "pallas"):
        if backend != "numpy" and not has_jax():
            raise RuntimeError(
                f"backend={backend!r} requires jax, which failed to "
                "import; install jax or use backend='numpy'")
        return backend
    raise ValueError(f"unknown backend {backend!r} "
                     "(expected 'auto', 'numpy', 'jit' or 'pallas')")


def simulate_many(programs: list[SimProgram],
                  params: PipelineParams | None = None, *,
                  n_iterations: int = 96,
                  warmup_iterations: int = 4,
                  max_period: int = 8,
                  backend: str = "auto",
                  classify: Callable[..., str] | None
                  = None,
                  counters: dict | None = None) -> list[SimResult]:
    """Simulate every program; results match the input order.

    Args:
        programs: compiled loop bodies (see
            :func:`repro.core.sim.pipeline.compile_program`); mixed
            architectures are allowed.
        params: pipeline parameters forced for the whole batch;
            default: each program's own ``model.pipeline``.
        n_iterations: loop bodies simulated per kernel (the vectorized
            pass has no early exit; lanes that fail to converge within
            the horizon are re-run once at ``4 * n_iterations``).
        warmup_iterations: iterations excluded from the steady-state
            slope.
        max_period: longest periodic delta pattern accepted as
            convergence.
        backend: ``"numpy"`` (reference slot sweep), ``"jit"``
            (``jax.jit`` + ``vmap``, shape-bucketed), ``"pallas"``
            (jit with the Pallas arbitration step), or ``"auto"``
            (jit for groups of ≥ :data:`AUTO_JIT_MIN_BATCH` when jax is
            importable, else numpy).  See docs/performance.md.
        classify: optional replacement for the bottleneck classifier
            (the :class:`~repro.core.engine.AnalysisService` passes a
            memoized one).
        counters: optional dict whose ``"dispatches"`` entry is
            incremented once per driver invocation actually issued
            (split groups count each sub-invocation; a sharded jit
            dispatch counts once) — the engine surfaces this as
            ``stats.sim_group_dispatches``.
    """
    classify = classify or _classify
    groups: dict[tuple, _Group] = {}
    for pos, prog in enumerate(programs):
        p = params or prog.model.pipeline or DEFAULT_PARAMS
        key = (prog.model.ports, p)
        g = groups.setdefault(key, _Group([], []))
        g.programs.append(prog)
        g.indices.append(pos)

    out: list[SimResult | None] = [None] * len(programs)
    for (ports, p), g in groups.items():
        results = _simulate_group(
            g.programs, ports, p, n_iterations, warmup_iterations,
            max_period, _resolve_backend(backend, len(g.programs)),
            classify, counters)
        for pos, res in zip(g.indices, results):
            out[pos] = res
    return out  # type: ignore[return-value]


def _simulate_group(programs: list[SimProgram], ports: tuple[str, ...],
                    params: PipelineParams, n_iterations: int,
                    warmup: int, max_period: int, backend: str,
                    classify: Callable[..., str],
                    counters: dict | None = None, *,
                    _grown: bool = False) -> list[SimResult]:
    if max((len(p.uops) for p in programs), default=0) == 0:
        return [SimResult(0.0, 0, True, "empty", 0.0, {}, params)
                for _ in programs]
    if backend != "numpy":
        ok = [_jit_compatible([p], params) for p in programs]
        if not all(ok):
            # exotic programs (non-contiguous slots / iteration larger
            # than a window) take the reference path — individually,
            # so one of them does not downgrade the whole group
            exotic = [p for p, k in zip(programs, ok) if not k]
            rest = [p for p, k in zip(programs, ok) if k]
            sub = _simulate_group(exotic, ports, params, n_iterations,
                                  warmup, max_period, "numpy",
                                  classify, counters, _grown=_grown)
            out = iter(sub)
            if rest:
                sub2 = iter(_simulate_group(
                    rest, ports, params, n_iterations, warmup,
                    max_period, backend, classify, counters,
                    _grown=_grown))
                return [next(out) if not k else next(sub2)
                        for k in ok]
            return sub
    if counters is not None:
        counters["dispatches"] = counters.get("dispatches", 0) + 1
    if backend == "numpy":
        iter_end = _run_numpy(_pack(programs, ports, params),
                              n_iterations)
    else:
        iter_end = _run_jax(programs, ports, params, n_iterations,
                            "pallas" if backend == "pallas" else "lax")
    cpi, converged = _steady_state(iter_end, warmup, max_period)

    # one escalation pass: a lane whose transient outlasts the horizon
    # (e.g. a divider backlog that takes ~scheduler_size iterations to
    # fill) re-runs with 4x the iterations; converged lanes keep their
    # first-pass numbers bit-exactly
    retry: dict[int, SimResult] = {}
    if not _grown:
        retry_idx = [b for b, prog in enumerate(programs)
                     if prog.uops and not converged[b]]
        if retry_idx:
            sub = _simulate_group(
                [programs[b] for b in retry_idx], ports, params,
                4 * n_iterations, warmup, max_period, backend,
                classify, None, _grown=True)
            retry = dict(zip(retry_idx, sub))

    results = []
    for b, prog in enumerate(programs):
        if not prog.uops:
            results.append(SimResult(0.0, 0, True, "empty", 0.0, {},
                                     params))
            continue
        if b in retry:
            results.append(retry[b])
            continue
        sched = frontend_schedule(prog, params)
        fe = sched.n_slots / params.issue_width
        results.append(SimResult(
            cycles_per_iteration=float(cpi[b]),
            iterations=n_iterations, converged=bool(converged[b]),
            bottleneck=classify(float(cpi[b]), fe,
                                prog.port_bound_cycles, sched.cpi,
                                sched.mode),
            frontend_cycles=fe, port_busy={}, params=params,
            delivery_cycles=sched.cpi, fe_mode=sched.mode))
    return results
