"""Resource-constrained DAG scheduling — the simulator's TPU analogue.

The x86 simulator ticks cycles; compiled-HLO ops have float durations in
seconds, so this module schedules them event-style instead: ops are
processed in definition order (HLO lists definitions before uses), each
op starts once all of its operands have finished AND its ports are
free, and each port serializes the work booked on it.  The makespan is
therefore at least ``max(bound_overlap, critical_path)`` — the analytic
bound pair of :mod:`repro.core.hlo.analyzer` — and at most the serial
sum: it refines the analytic estimate exactly where dependency chains
and port contention interleave.

Used by ``AnalysisService.predict_hlo(mode="simulate")`` and, through
it, ``ServingEngine.dryrun_estimate``.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class DagNode:
    """One schedulable op: per-port occupation (seconds) + operand deps."""

    name: str
    occupation: dict[str, float]          # port -> seconds (run in parallel)
    deps: tuple[str, ...] = ()            # producer names


@dataclass
class DagSchedule:
    makespan: float
    finish: dict[str, float] = field(default_factory=dict)
    port_busy: dict[str, float] = field(default_factory=dict)
    #                              ^ booked (busy) seconds per port

    @property
    def bottleneck_port(self) -> str | None:
        if not self.port_busy:
            return None
        return max(self.port_busy, key=lambda p: self.port_busy[p])


def schedule_dag(nodes: list[DagNode]) -> DagSchedule:
    """List-schedule ``nodes`` (definition order) onto capacity-1 ports.

    An op's port occupations run concurrently with each other (a ``dot``
    uses MXU and HBM at once) but serialize against other ops booked on
    the same port: each booking starts no earlier than the port's last
    booking ends (classic in-order list scheduling), so the makespan is
    at least every per-port busy sum and at least the critical path.
    """
    port_cap: dict[str, float] = {}    # end of the last booking
    port_busy: dict[str, float] = {}   # booked seconds (excludes waits)
    finish: dict[str, float] = {}
    makespan = 0.0
    for node in nodes:
        ready = 0.0
        for dep in node.deps:
            ready = max(ready, finish.get(dep, 0.0))
        end = ready
        for port, secs in node.occupation.items():
            if secs <= 0.0:
                continue
            start = max(ready, port_cap.get(port, 0.0))
            port_cap[port] = start + secs
            port_busy[port] = port_busy.get(port, 0.0) + secs
            end = max(end, start + secs)
        finish[node.name] = end
        makespan = max(makespan, end)
    return DagSchedule(makespan=makespan, finish=finish,
                       port_busy=port_busy)
