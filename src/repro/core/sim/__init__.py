"""repro.core.sim — cycle-level out-of-order pipeline simulation.

The third prediction backend (after the analytic port bound and the LCD
bound): a parametric front-end + finite-window + port-arbitration
simulator for x86 loop kernels, a vectorized struct-of-arrays batch
driver, and the event-driven DAG scheduler used for compiled HLO.
See docs/simulation.md for the model and docs/architecture.md for how
the three backends compose.
"""
from __future__ import annotations

from .batch import (AUTO_JIT_MIN_BATCH, JIT_SHARD, has_jax,
                    simulate_many)
from .dag import DagNode, DagSchedule, schedule_dag
from .pipeline import (BOTTLENECKS, DEFAULT_PARAMS, FE_MODE_NAMES,
                       FrontendSchedule, SimProgram, SimResult, SimUop,
                       compile_program, frontend_schedule, simulate,
                       simulate_kernel)

__all__ = [
    "AUTO_JIT_MIN_BATCH", "BOTTLENECKS", "DEFAULT_PARAMS",
    "DagNode", "DagSchedule", "FE_MODE_NAMES", "FrontendSchedule",
    "JIT_SHARD", "SimProgram", "SimResult", "SimUop", "compile_program",
    "frontend_schedule", "has_jax", "schedule_dag", "simulate",
    "simulate_kernel", "simulate_many",
]
