"""Instruction-form database (paper Sec. II).

Each entry maps an *instruction form* (mnemonic + Intel-order operand-type
signature) to its micro-op decomposition, reciprocal throughput and latency —
the same triple OSACA stores as e.g.::

    vfmadd132pd-xmm_xmm_mem, 0.5, 4.0, "(0.5,0,0.5,0.5,0.5,0,0,0,0)"

We keep the eligible-port *sets* rather than the averaged occupation vector,
because the averaged vector is derivable (uniform scheduler) while the sets
additionally enable the min-max balanced scheduler (beyond-paper, IACA-like).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Iterable

from .isa import Instruction
from .ports import PortModel, Uop


@dataclass(frozen=True)
class InstrForm:
    mnemonic: str
    signature: tuple[str, ...]     # Intel order; "r" matches any gpr width
    uops: tuple[Uop, ...]
    throughput: float              # reciprocal throughput [cy/instr]
    latency: float
    notes: str = ""

    @property
    def key(self) -> tuple[str, tuple[str, ...]]:
        return (self.mnemonic, self.signature)

    def occupation_uniform(self, model: PortModel) -> dict[str, float]:
        occ = model.zero_occupation()
        for uop in self.uops:
            share = uop.cycles / len(uop.ports)
            for p in uop.ports:
                occ[p] += share
        return occ


def _collapse_gpr(token: str) -> str:
    return "r" if token in ("r8", "r16", "r32", "r64", "reg") else token


@dataclass
class MissingForm:
    instruction: Instruction

    def benchmark_spec(self) -> str:
        """ibench-style benchmark stub for an unknown form (paper Fig. 4:
        'if no match was found, corresponding benchmark files are generated
        automatically')."""
        sig = "_".join(self.instruction.signature) or "none"
        return (f"# auto-generated ibench benchmark for "
                f"{self.instruction.mnemonic}-{sig}\n"
                f"# latency: dependency chain; throughput: >=10 parallel "
                f"chains (paper Sec. II-A)\n"
                f"{self.instruction.text}\n")


class InstructionDB:
    """Lookup with progressive generalisation:

    1. exact (mnemonic, signature)
    2. gpr widths collapsed to "r"
    3. per-mnemonic default entry (signature ("*",))
    """

    def __init__(self, name: str, model: PortModel,
                 entries: Iterable[InstrForm] = ()):
        self.name = name
        self.model = model
        self._exact: dict[tuple[str, tuple[str, ...]], InstrForm] = {}
        self._default: dict[str, InstrForm] = {}
        for e in entries:
            self.add(e)

    def add(self, entry: InstrForm) -> None:
        self.model.validate_uops(entry.uops)
        if entry.signature == ("*",):
            self._default[entry.mnemonic] = entry
        else:
            self._exact[entry.key] = entry

    def __len__(self) -> int:
        return len(self._exact) + len(self._default)

    def lookup(self, instr: Instruction) -> InstrForm | None:
        sig = instr.signature
        hit = self._exact.get((instr.mnemonic, sig))
        if hit is not None:
            return hit
        collapsed = tuple(_collapse_gpr(t) for t in sig)
        hit = self._exact.get((instr.mnemonic, collapsed))
        if hit is not None:
            return hit
        # imm/reg interchangeable for most integer ALU forms
        relaxed = tuple("r" if t == "imm" else t for t in collapsed)
        hit = self._exact.get((instr.mnemonic, relaxed))
        if hit is not None:
            return hit
        return self._default.get(instr.mnemonic)

    def entries(self) -> list[InstrForm]:
        return list(self._exact.values()) + list(self._default.values())


# --------------------------------------------------------------------------
# Entry-construction DSL used by the per-architecture modules
# --------------------------------------------------------------------------

def E(mnemonic: str, signature: str, uops: Iterable[Uop],
      tp: float, lat: float, notes: str = "") -> InstrForm:
    sig = tuple(s for s in signature.split(",") if s) if signature else ()
    return InstrForm(mnemonic, sig, tuple(uops), tp, lat, notes)


def widen_double_pumped(entry: InstrForm, xmm_token: str = "xmm",
                        ymm_token: str = "ymm") -> InstrForm:
    """Derive the 256-bit form of a 128-bit entry on a double-pumped
    architecture (AMD Zen executes AVX as two 128-bit halves — paper
    Sec. III-A): every uop's occupation doubles, throughput doubles."""
    sig = tuple(ymm_token if t == xmm_token else t for t in entry.signature)
    return InstrForm(
        mnemonic=entry.mnemonic, signature=sig,
        uops=tuple(u.scaled(2.0) for u in entry.uops),
        throughput=entry.throughput * 2.0,
        latency=entry.latency + 1.0,
        notes=(entry.notes + " double-pumped 2x128b").strip())
