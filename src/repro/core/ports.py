"""Generic out-of-order port model (paper Fig. 1).

A machine is a set of named *ports*; each port accepts one micro-op per
cycle.  Instruction forms decompose into :class:`Uop` objects, each eligible
on a set of ports and occupying whichever port it is scheduled on for
``cycles`` cycles (divider pipes such as Skylake's ``0DV`` are ordinary ports
whose uops have ``cycles > 1``).

The same abstraction models TPU functional pipes (MXU / VPU / HBM / ICI) in
``repro.core.arch.tpu_v5e`` — occupation is then measured in seconds rather
than cycles; the engine is unit-agnostic.

These are the *runtime views*; the declarative, serializable spec that
owns identity + topology + pipeline + instruction table is
:class:`repro.core.machine.MachineModel` (``model.port_model`` yields
the :class:`PortModel`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, Mapping


@dataclass(frozen=True)
class Uop:
    """One micro-op: eligible port set + occupation per scheduled port."""

    ports: tuple[str, ...]
    cycles: float = 1.0
    # Zen AGU pairing (paper Sec. III-A): a load's AGU uop may be hidden
    # behind a store's AGU slot.  Marked uops are candidates for hiding.
    hideable_load: bool = False
    # Tag used by reports ("load", "store-agu", "store-data", "div", ...).
    kind: str = ""

    def scaled(self, factor: float) -> "Uop":
        return dataclasses.replace(self, cycles=self.cycles * factor)


def U(ports: str, cycles: float = 1.0, *, hideable_load: bool = False,
      kind: str = "") -> Uop:
    """Shorthand: ``U("2|3")`` = 1-cycle uop eligible on ports 2 and 3."""
    return Uop(tuple(ports.split("|")), cycles, hideable_load, kind)


@dataclass(frozen=True)
class PipelineParams:
    """Front-end / out-of-order window parameters of one architecture.

    Consumed by the cycle-level simulator (``repro.core.sim``): the
    analytic port model assumes an infinitely wide front end and an
    infinite scheduler window; these parameters are exactly what the
    simulator adds back.  Values come from the vendor optimization
    manuals the paper cites for its machine models (Intel [8], AMD [12]).

    The second block models the uiCA-style fetch/decode/delivery front
    end (docs/frontend.md).  Every field of that block defaults to
    *disabled* (0 / False), which makes ``PipelineParams()`` reproduce
    the pre-front-end simulator exactly: one uop per issue slot, no
    delivery constraint, no fusion, no elimination, no recovery delay.
    Width *consistency* (e.g. decoders wider than the issue stage) is
    deliberately not enforced here — ``tools/check_models.py`` flags it
    on shipped artifacts, so experiments can still construct
    intentionally inconsistent what-if machines.
    """

    issue_width: int = 4        # uops issued into the backend per cycle
    rob_size: int = 224         # reorder-buffer entries (uops in flight)
    scheduler_size: int = 97    # unified scheduler / reservation stations
    retire_width: int = 4       # uops retired (ROB entries freed) per cycle

    # ---- front end (uiCA-style; 0/False = stage not modelled) --------
    predecode_width: int = 0    # instructions length-marked per cycle
    decode_width: int = 0       # instructions decoded (MITE) per cycle
    complex_decode_width: int = 1   # decoders taking multi-uop instrs
    dsb_width: int = 0          # uop-cache delivery (uops per cycle)
    dsb_size: int = 0           # uop-cache capacity (uops)
    lsd_size: int = 0           # loop-stream-detector capacity (uops)
    macro_fusion: bool = False      # cmp/test + jcc decode as one
    micro_fusion: bool = False      # laminated uop pairs share a slot
    move_elimination: bool = False  # reg-reg moves rename away
    mispredict_penalty: float = 0.0     # loop-entry recovery cycles

    def __post_init__(self) -> None:
        for f in ("issue_width", "rob_size", "scheduler_size",
                  "retire_width"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1")
        for f in ("predecode_width", "decode_width",
                  "complex_decode_width", "dsb_width", "dsb_size",
                  "lsd_size", "mispredict_penalty"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")


@dataclass(frozen=True)
class PortModel:
    """A named machine: port list plus scheduling peculiarities."""

    name: str
    ports: tuple[str, ...]
    # Ports rendered as "<p> - DV" style divider pipes in reports.
    divider_ports: frozenset[str] = frozenset()
    # Zen rule: each store instruction lets one load instruction's AGU
    # uops execute in its shadow (they are shown parenthesised and excluded
    # from port totals).
    store_hides_load: bool = False
    # Measurement unit for occupation (cycles for CPUs, seconds for TPU).
    unit: str = "cy"
    frequency_hz: float | None = None
    # Store->load forwarding latency in `unit`, used by the critical-path /
    # loop-carried-dependency analysis (repro.core.latency).  Calibrated per
    # architecture like any other DB number (paper Sec. II methodology);
    # 0.0 means "fall back to the storing instruction's own latency".
    store_forward_latency: float = 0.0
    # Front-end / OoO-window parameters for the cycle-level simulator
    # (repro.core.sim); None means "analytic model only" (e.g. TPU).
    pipeline: PipelineParams | None = None

    def __post_init__(self) -> None:
        if len(set(self.ports)) != len(self.ports):
            raise ValueError(f"duplicate ports in model {self.name}")

    def validate_uops(self, uops: Iterable[Uop]) -> None:
        known = set(self.ports)
        for uop in uops:
            unknown = set(uop.ports) - known
            if unknown:
                raise ValueError(
                    f"uop references unknown ports {sorted(unknown)} "
                    f"(model {self.name} has {self.ports})")

    def zero_occupation(self) -> dict[str, float]:
        return {p: 0.0 for p in self.ports}


def merge_occupation(dst: dict[str, float], src: Mapping[str, float]) -> None:
    for port, occ in src.items():
        dst[port] = dst.get(port, 0.0) + occ
