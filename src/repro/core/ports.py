"""Generic out-of-order port model (paper Fig. 1).

A machine is a set of named *ports*; each port accepts one micro-op per
cycle.  Instruction forms decompose into :class:`Uop` objects, each eligible
on a set of ports and occupying whichever port it is scheduled on for
``cycles`` cycles (divider pipes such as Skylake's ``0DV`` are ordinary ports
whose uops have ``cycles > 1``).

The same abstraction models TPU functional pipes (MXU / VPU / HBM / ICI) in
``repro.core.arch.tpu_v5e`` — occupation is then measured in seconds rather
than cycles; the engine is unit-agnostic.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, Mapping


@dataclass(frozen=True)
class Uop:
    """One micro-op: eligible port set + occupation per scheduled port."""

    ports: tuple[str, ...]
    cycles: float = 1.0
    # Zen AGU pairing (paper Sec. III-A): a load's AGU uop may be hidden
    # behind a store's AGU slot.  Marked uops are candidates for hiding.
    hideable_load: bool = False
    # Tag used by reports ("load", "store-agu", "store-data", "div", ...).
    kind: str = ""

    def scaled(self, factor: float) -> "Uop":
        return dataclasses.replace(self, cycles=self.cycles * factor)


def U(ports: str, cycles: float = 1.0, *, hideable_load: bool = False,
      kind: str = "") -> Uop:
    """Shorthand: ``U("2|3")`` = 1-cycle uop eligible on ports 2 and 3."""
    return Uop(tuple(ports.split("|")), cycles, hideable_load, kind)


@dataclass(frozen=True)
class PortModel:
    """A named machine: port list plus scheduling peculiarities."""

    name: str
    ports: tuple[str, ...]
    # Ports rendered as "<p> - DV" style divider pipes in reports.
    divider_ports: frozenset[str] = frozenset()
    # Zen rule: each store instruction lets one load instruction's AGU
    # uops execute in its shadow (they are shown parenthesised and excluded
    # from port totals).
    store_hides_load: bool = False
    # Measurement unit for occupation (cycles for CPUs, seconds for TPU).
    unit: str = "cy"
    frequency_hz: float | None = None
    # Store->load forwarding latency in `unit`, used by the critical-path /
    # loop-carried-dependency analysis (repro.core.latency).  Calibrated per
    # architecture like any other DB number (paper Sec. II methodology);
    # 0.0 means "fall back to the storing instruction's own latency".
    store_forward_latency: float = 0.0

    def __post_init__(self) -> None:
        if len(set(self.ports)) != len(self.ports):
            raise ValueError(f"duplicate ports in model {self.name}")

    def validate_uops(self, uops: Iterable[Uop]) -> None:
        known = set(self.ports)
        for uop in uops:
            unknown = set(uop.ports) - known
            if unknown:
                raise ValueError(
                    f"uop references unknown ports {sorted(unknown)} "
                    f"(model {self.name} has {self.ports})")

    def zero_occupation(self) -> dict[str, float]:
        return {p: 0.0 for p in self.ports}


def merge_occupation(dst: dict[str, float], src: Mapping[str, float]) -> None:
    for port, occ in src.items():
        dst[port] = dst.get(port, 0.0) + occ
