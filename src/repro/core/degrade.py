"""Backend degradation ladder: circuit breakers + result validation.

The analytic bound (the source paper) and the cycle-level simulator are
redundant predictors of the same quantity, which is exactly the
structure graceful degradation needs: when an expensive backend fails,
a cheaper one still answers, and the analytic bound is the floor that
never goes away.  The rung sequence is

    pallas -> jit -> numpy -> analytic-only

(`tick` — the per-program reference interpreter used for small batches
— is its own single-rung ladder above the analytic floor).

Per-(machine digest x backend) :class:`CircuitBreaker` state machines
stop the engine from hammering a rung that keeps failing:

    closed ──failures >= threshold──> open ──cooldown──> half_open
      ^                                                      │
      └──────────── probe succeeds ──────────────────────────┤
                                                             │
                    probe fails ──> open (cooldown restarts) ─┘

All clocks are injectable so the chaos suite can step time without
sleeping.  The :class:`BreakerBoard` keeps a bounded transition log —
the telemetry that makes breaker opening/half-opening visible in
``service.export_stats()``.

:class:`HealthRouter` turns the breakers from *reactive* containment
into *proactive* routing: instead of attempting a rung and demoting on
failure, the dispatcher asks the router for a :class:`RoutePlan` first
— an open rung is skipped before any dispatch is paid, and a rung due
for a half-open probe gets at most one scheduled probe dispatch per
cooldown window while all other traffic routes below it
(docs/robustness.md#health-aware-routing).
"""

from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Sequence

__all__ = [
    "LADDER", "ladder_from", "BreakerConfig", "CircuitBreaker",
    "BreakerBoard", "validate_sims", "RouterConfig", "RoutePlan",
    "HealthRouter",
]

# sim rungs, most to least expensive; "analytic" is the implicit floor
LADDER: tuple[str, ...] = ("pallas", "jit", "numpy")


def ladder_from(backend: str) -> tuple[str, ...]:
    """The sim rungs at or below ``backend``.

    ``tick`` (the small-batch reference interpreter) has no cheaper sim
    rung — its only fallback is the analytic floor."""
    if backend == "tick":
        return ("tick",)
    try:
        i = LADDER.index(backend)
    except ValueError:
        raise ValueError(f"unknown sim backend {backend!r}; "
                         f"known: {', '.join(LADDER)} or 'tick'") from None
    return LADDER[i:]


@dataclass(frozen=True)
class BreakerConfig:
    """``failure_threshold`` consecutive failures open the breaker;
    after ``cooldown_s`` one half-open probe is allowed through."""

    failure_threshold: int = 3
    cooldown_s: float = 30.0

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if self.cooldown_s < 0:
            raise ValueError("cooldown_s must be >= 0")


class CircuitBreaker:
    """closed / open / half_open with cooldown; injectable clock."""

    def __init__(self, config: BreakerConfig,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[str, str, float], None] | None = None):
        self.config = config
        self._clock = clock
        self._on_transition = on_transition
        self._state = "closed"
        self._failures = 0
        self._opened_at = 0.0

    @property
    def state(self) -> str:
        return self._state

    @property
    def failures(self) -> int:
        return self._failures

    def _set(self, state: str) -> None:
        if state == self._state:
            return
        prev, self._state = self._state, state
        if self._on_transition is not None:
            self._on_transition(prev, state, self._clock())

    def peek(self, now: float | None = None) -> str:
        """Effective state at ``now`` *without* transitioning.

        Unlike :meth:`allow`, this never mutates the breaker, so a
        routing policy can look before it leaps: ``"closed"`` /
        ``"half_open"`` / ``"open"`` mirror :attr:`state`, and
        ``"due_probe"`` reports an open breaker whose cooldown has
        elapsed — the next :meth:`allow` call would admit one probe."""
        if self._state == "open":
            t = self._clock() if now is None else now
            if t - self._opened_at >= self.config.cooldown_s:
                return "due_probe"
        return self._state

    def allow(self) -> bool:
        """May a dispatch be attempted on this rung right now?

        An open breaker whose cooldown has elapsed transitions to
        half_open and lets exactly one probe through."""
        if self._state == "closed":
            return True
        if self._state == "open":
            if self._clock() - self._opened_at >= self.config.cooldown_s:
                self._set("half_open")
                return True
            return False
        # half_open: a probe is already in flight (or just allowed);
        # further calls wait for its verdict
        return False

    def record_success(self) -> None:
        self._failures = 0
        self._set("closed")

    def record_failure(self) -> None:
        self._failures += 1
        if self._state == "half_open" or self._failures >= self.config.failure_threshold:
            self._opened_at = self._clock()
            self._set("open")

    def snapshot(self) -> dict:
        return {"state": self._state, "failures": self._failures,
                "opened_at": self._opened_at}


class BreakerBoard:
    """Lazily-created breakers keyed (machine digest, backend), plus a
    bounded transition-event log.  Thread-safe."""

    def __init__(self, config: BreakerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 event_capacity: int = 256):
        self.config = config or BreakerConfig()
        self._clock = clock
        self._lock = threading.Lock()
        self._breakers: dict[tuple[str, str], CircuitBreaker] = {}
        self._events: deque[dict] = deque(maxlen=event_capacity)

    def breaker(self, machine_digest: str, backend: str) -> CircuitBreaker:
        key = (machine_digest, backend)
        with self._lock:
            br = self._breakers.get(key)
            if br is None:
                label = f"{machine_digest[:12]}/{backend}"

                def log(prev: str, new: str, t: float, _label=label) -> None:
                    self._events.append(
                        {"breaker": _label, "from": prev, "to": new, "t": t})

                br = CircuitBreaker(self.config, clock=self._clock,
                                    on_transition=log)
                self._breakers[key] = br
            return br

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "breakers": {f"{d[:12]}/{b}": br.snapshot()
                             for (d, b), br in sorted(self._breakers.items())},
                "events": list(self._events),
            }

    def reset(self) -> None:
        with self._lock:
            self._breakers.clear()
            self._events.clear()


# ----------------------------------------------------------------------
# health-aware dispatch routing
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class RouterConfig:
    """Tunables of one :class:`HealthRouter`.

    ``probe_interval_s`` is the minimum spacing between half-open probe
    dispatches per (machine digest, rung); ``None`` (default) uses the
    breaker's own cooldown, so at most one probe is scheduled per
    cooldown window."""

    probe_interval_s: float | None = None

    def __post_init__(self):
        if self.probe_interval_s is not None and self.probe_interval_s < 0:
            raise ValueError("probe_interval_s must be >= 0 or None")

    def to_dict(self) -> dict:
        return {"probe_interval_s": self.probe_interval_s}

    @classmethod
    def from_dict(cls, d) -> "RouterConfig":
        return cls(probe_interval_s=d.get("probe_interval_s"))


@dataclass(frozen=True)
class RoutePlan:
    """One routing decision: the rungs to walk (healthiest first),
    where the dispatch was routed *from* (``""`` when it starts at the
    requested rung), and whether the first rung is a scheduled
    half-open probe.  An empty ``rungs`` means every rung is unhealthy
    and the group should take the analytic floor without paying a
    single dispatch."""

    rungs: tuple[str, ...] = ()
    routed_from: str = ""
    probe: bool = False


class HealthRouter:
    """Breaker-aware routing policy: pick the healthiest rung *before*
    dispatch instead of demoting after a failure.

    Serializable (:meth:`to_json` round-trips the policy config; the
    probe bookkeeping is runtime state) with an injectable clock so the
    chaos suite can step time.  Thread-safe: the probe ledger is
    lock-protected.

    Routing semantics per rung, walked healthiest-first from the
    requested rung down (:func:`ladder_from`):

    * ``closed`` — dispatch here.
    * ``open`` (cooldown pending) — skip without paying a dispatch.
    * ``due_probe`` (open, cooldown elapsed) — at most one scheduled
      probe dispatch per ``probe_interval_s`` window is routed here
      (``RoutePlan.probe=True``); all other traffic routes below.
    * ``half_open`` — a probe is already in flight; route below.
    """

    def __init__(self, config: RouterConfig | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config or RouterConfig()
        self._clock = clock
        self._lock = threading.Lock()
        # (machine digest, rung) -> time of the last scheduled probe
        self._last_probe: dict[tuple[str, str], float] = {}
        self.stats = {"plans": 0, "routed": 0, "probes": 0,
                      "floor_routes": 0}

    # -- serialization (policy config only) ---------------------------
    def to_dict(self) -> dict:
        return {"config": self.config.to_dict()}

    @classmethod
    def from_dict(cls, d, clock: Callable[[], float] = time.monotonic,
                  ) -> "HealthRouter":
        return cls(RouterConfig.from_dict(d.get("config", {})),
                   clock=clock)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, text: str,
                  clock: Callable[[], float] = time.monotonic,
                  ) -> "HealthRouter":
        return cls.from_dict(json.loads(text), clock=clock)

    # -- routing ------------------------------------------------------
    def _route(self, board: BreakerBoard, digest: str,
               rungs: Sequence[str], consume: bool) -> RoutePlan:
        now = self._clock()
        rungs = tuple(rungs)
        for i, rung in enumerate(rungs):
            br = board.breaker(digest, rung)
            state = br.peek(now)
            if state == "closed":
                routed = rungs[0] if i else ""
                if consume:
                    with self._lock:
                        self.stats["plans"] += 1
                        self.stats["routed"] += bool(routed)
                return RoutePlan(rungs[i:], routed, False)
            if state == "due_probe":
                interval = (self.config.probe_interval_s
                            if self.config.probe_interval_s is not None
                            else br.config.cooldown_s)
                key = (digest, rung)
                with self._lock:
                    last = self._last_probe.get(key)
                    due = last is None or now - last >= interval
                    if due and consume:
                        self._last_probe[key] = now
                        self.stats["plans"] += 1
                        self.stats["probes"] += 1
                        self.stats["routed"] += bool(i)
                if due:
                    return RoutePlan(rungs[i:], rungs[0] if i else "",
                                     True)
            # open / half_open / probe-slot taken: route below
        if consume:
            with self._lock:
                self.stats["plans"] += 1
                self.stats["floor_routes"] += 1
        return RoutePlan((), rungs[0] if rungs else "", False)

    def plan(self, board: BreakerBoard, digest: str,
             rungs: Sequence[str]) -> RoutePlan:
        """Commit to a routing decision for one dispatch (a returned
        probe consumes the probe slot for its window)."""
        return self._route(board, digest, rungs, consume=True)

    def preview(self, board: BreakerBoard, digest: str,
                rungs: Sequence[str]) -> RoutePlan:
        """The decision :meth:`plan` *would* make, without consuming a
        probe slot or touching the stats — the service's pre-dispatch
        consult (the engine's :meth:`plan` at dispatch time stays the
        single probe scheduler)."""
        return self._route(board, digest, rungs, consume=False)

    def snapshot(self) -> dict:
        with self._lock:
            return {"config": self.config.to_dict(),
                    "stats": dict(self.stats),
                    "pending_probes": {f"{d[:12]}/{r}": t for (d, r), t
                                       in sorted(self._last_probe.items())}}

    def reset(self) -> None:
        with self._lock:
            self._last_probe.clear()
            for k in self.stats:
                self.stats[k] = 0


# ----------------------------------------------------------------------
# post-dispatch result validation
# ----------------------------------------------------------------------
def validate_sims(sims: Sequence, progs: Sequence,
                  divergence_factor: float = 50.0) -> list[str]:
    """Problems with a backend's output, empty when clean.

    Rejects non-finite or negative cycle counts outright, and flags
    implausible divergence from each program's analytic port bound —
    the sim models *more* constraints than port pressure (front end,
    dependencies), so it can exceed the bound, but not by 50x; and it
    cannot undercut a positive bound by 50x either.  Corrupt output is
    thereby treated exactly like a dispatch fault."""
    problems: list[str] = []
    for sim, prog in zip(sims, progs):
        cpi = sim.cycles_per_iteration
        if not math.isfinite(cpi):
            problems.append(f"{prog.kernel_id}: non-finite cycles ({cpi})")
            continue
        if cpi < 0:
            problems.append(f"{prog.kernel_id}: negative cycles ({cpi})")
            continue
        bound = prog.port_bound_cycles
        if bound > 0:
            if cpi > bound * divergence_factor:
                problems.append(
                    f"{prog.kernel_id}: {cpi:.3f} cy/it diverges above "
                    f"{divergence_factor:.0f}x the {bound:.3f} port bound")
            elif cpi * divergence_factor < bound:
                problems.append(
                    f"{prog.kernel_id}: {cpi:.3f} cy/it diverges below "
                    f"1/{divergence_factor:.0f}x the {bound:.3f} port bound")
    return problems
