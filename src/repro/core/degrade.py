"""Backend degradation ladder: circuit breakers + result validation.

The analytic bound (the source paper) and the cycle-level simulator are
redundant predictors of the same quantity, which is exactly the
structure graceful degradation needs: when an expensive backend fails,
a cheaper one still answers, and the analytic bound is the floor that
never goes away.  The rung sequence is

    pallas -> jit -> numpy -> analytic-only

(`tick` — the per-program reference interpreter used for small batches
— is its own single-rung ladder above the analytic floor).

Per-(machine digest x backend) :class:`CircuitBreaker` state machines
stop the engine from hammering a rung that keeps failing:

    closed ──failures >= threshold──> open ──cooldown──> half_open
      ^                                                      │
      └──────────── probe succeeds ──────────────────────────┤
                                                             │
                    probe fails ──> open (cooldown restarts) ─┘

All clocks are injectable so the chaos suite can step time without
sleeping.  The :class:`BreakerBoard` keeps a bounded transition log —
the telemetry that makes breaker opening/half-opening visible in
``service.export_stats()``.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Sequence

__all__ = [
    "LADDER", "ladder_from", "BreakerConfig", "CircuitBreaker",
    "BreakerBoard", "validate_sims",
]

# sim rungs, most to least expensive; "analytic" is the implicit floor
LADDER: tuple[str, ...] = ("pallas", "jit", "numpy")


def ladder_from(backend: str) -> tuple[str, ...]:
    """The sim rungs at or below ``backend``.

    ``tick`` (the small-batch reference interpreter) has no cheaper sim
    rung — its only fallback is the analytic floor."""
    if backend == "tick":
        return ("tick",)
    try:
        i = LADDER.index(backend)
    except ValueError:
        raise ValueError(f"unknown sim backend {backend!r}; "
                         f"known: {', '.join(LADDER)} or 'tick'") from None
    return LADDER[i:]


@dataclass(frozen=True)
class BreakerConfig:
    """``failure_threshold`` consecutive failures open the breaker;
    after ``cooldown_s`` one half-open probe is allowed through."""

    failure_threshold: int = 3
    cooldown_s: float = 30.0

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if self.cooldown_s < 0:
            raise ValueError("cooldown_s must be >= 0")


class CircuitBreaker:
    """closed / open / half_open with cooldown; injectable clock."""

    def __init__(self, config: BreakerConfig,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[str, str, float], None] | None = None):
        self.config = config
        self._clock = clock
        self._on_transition = on_transition
        self._state = "closed"
        self._failures = 0
        self._opened_at = 0.0

    @property
    def state(self) -> str:
        return self._state

    @property
    def failures(self) -> int:
        return self._failures

    def _set(self, state: str) -> None:
        if state == self._state:
            return
        prev, self._state = self._state, state
        if self._on_transition is not None:
            self._on_transition(prev, state, self._clock())

    def allow(self) -> bool:
        """May a dispatch be attempted on this rung right now?

        An open breaker whose cooldown has elapsed transitions to
        half_open and lets exactly one probe through."""
        if self._state == "closed":
            return True
        if self._state == "open":
            if self._clock() - self._opened_at >= self.config.cooldown_s:
                self._set("half_open")
                return True
            return False
        # half_open: a probe is already in flight (or just allowed);
        # further calls wait for its verdict
        return False

    def record_success(self) -> None:
        self._failures = 0
        self._set("closed")

    def record_failure(self) -> None:
        self._failures += 1
        if self._state == "half_open" or self._failures >= self.config.failure_threshold:
            self._opened_at = self._clock()
            self._set("open")

    def snapshot(self) -> dict:
        return {"state": self._state, "failures": self._failures,
                "opened_at": self._opened_at}


class BreakerBoard:
    """Lazily-created breakers keyed (machine digest, backend), plus a
    bounded transition-event log.  Thread-safe."""

    def __init__(self, config: BreakerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 event_capacity: int = 256):
        self.config = config or BreakerConfig()
        self._clock = clock
        self._lock = threading.Lock()
        self._breakers: dict[tuple[str, str], CircuitBreaker] = {}
        self._events: deque[dict] = deque(maxlen=event_capacity)

    def breaker(self, machine_digest: str, backend: str) -> CircuitBreaker:
        key = (machine_digest, backend)
        with self._lock:
            br = self._breakers.get(key)
            if br is None:
                label = f"{machine_digest[:12]}/{backend}"

                def log(prev: str, new: str, t: float, _label=label) -> None:
                    self._events.append(
                        {"breaker": _label, "from": prev, "to": new, "t": t})

                br = CircuitBreaker(self.config, clock=self._clock,
                                    on_transition=log)
                self._breakers[key] = br
            return br

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "breakers": {f"{d[:12]}/{b}": br.snapshot()
                             for (d, b), br in sorted(self._breakers.items())},
                "events": list(self._events),
            }

    def reset(self) -> None:
        with self._lock:
            self._breakers.clear()
            self._events.clear()


# ----------------------------------------------------------------------
# post-dispatch result validation
# ----------------------------------------------------------------------
def validate_sims(sims: Sequence, progs: Sequence,
                  divergence_factor: float = 50.0) -> list[str]:
    """Problems with a backend's output, empty when clean.

    Rejects non-finite or negative cycle counts outright, and flags
    implausible divergence from each program's analytic port bound —
    the sim models *more* constraints than port pressure (front end,
    dependencies), so it can exceed the bound, but not by 50x; and it
    cannot undercut a positive bound by 50x either.  Corrupt output is
    thereby treated exactly like a dispatch fault."""
    problems: list[str] = []
    for sim, prog in zip(sims, progs):
        cpi = sim.cycles_per_iteration
        if not math.isfinite(cpi):
            problems.append(f"{prog.kernel_id}: non-finite cycles ({cpi})")
            continue
        if cpi < 0:
            problems.append(f"{prog.kernel_id}: negative cycles ({cpi})")
            continue
        bound = prog.port_bound_cycles
        if bound > 0:
            if cpi > bound * divergence_factor:
                problems.append(
                    f"{prog.kernel_id}: {cpi:.3f} cy/it diverges above "
                    f"{divergence_factor:.0f}x the {bound:.3f} port bound")
            elif cpi * divergence_factor < bound:
                problems.append(
                    f"{prog.kernel_id}: {cpi:.3f} cy/it diverges below "
                    f"1/{divergence_factor:.0f}x the {bound:.3f} port bound")
    return problems
