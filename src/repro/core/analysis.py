"""Unified throughput (+) critical-path analysis (paper Sec. III, and the
OSACA follow-up arXiv:1910.00214): map every kernel instruction to its DB
entry, schedule uops onto ports, sum per-port occupation, and combine the
port-occupation bound with the loop-carried-dependency (LCD) bound —

    predicted = max(port_bound, loop_carried_dependency)

The paper's own worst mispredictions (pi at -O1, Table V: measurement ~2x
the port-bound estimate) are exactly the cases where the LCD term binds.
Both bounds and the binding constraint are reported by ``render()``.

Implements the Zen store/load AGU pairing: each store instruction hides one
load instruction's AGU uops (displayed parenthesised, excluded from totals) —
paper Sec. III-A, Table IV.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from .database import InstructionDB, MissingForm
from .isa import Instruction
from .latency import LatencyResult, analyze_latency
from .machine import as_database
from .ports import PortModel, merge_occupation
from .scheduler import SCHEDULERS, ScheduledUop


@dataclass
class InstructionReport:
    instruction: Instruction
    occupation: dict[str, float]          # visible occupation per port
    hidden_occupation: dict[str, float]   # parenthesised (hidden) occupation
    throughput: float | None
    latency: float | None
    matched: bool

    def total(self) -> float:
        return sum(self.occupation.values())


@dataclass
class AnalysisResult:
    """Combined throughput + critical-path prediction for one kernel.

    The headline number, ``predicted_cycles``, is the *combined* bound
    ``max(port_bound_cycles, lcd_cycles)`` per assembly iteration; the two
    constituent bounds are always reported alongside so callers (and
    ``render()``) can see which constraint binds.
    """

    model: PortModel
    rows: list[InstructionReport]
    port_totals: dict[str, float]         # visible occupation per port
    bottleneck_port: str                  # argmax of port_totals
    predicted_cycles: float               # combined bound, per asm iteration
    missing: list[MissingForm]
    scheduler: str
    unroll_factor: int = 1
    # --- constituent bounds (per assembly iteration) -------------------
    port_bound_cycles: float = 0.0        # pure throughput (paper) bound
    lcd_cycles: float = 0.0               # loop-carried dependency bound
    latency_result: LatencyResult | None = None
    binding: str = "throughput"           # "throughput" | "latency"
    #                                       | "simulation" | "memory"
    # --- cycle-level simulation (mode="simulate" only) -----------------
    bound_sim: float = 0.0                # steady-state cy/asm-it; 0 = not
    #                                       simulated
    sim_result: object | None = None      # repro.core.sim.SimResult
    # --- ECM memory-hierarchy composition (working_set= requests) ------
    bound_ecm: float = 0.0                # max(in-core, T_nOL + transfers);
    #                                       0 = not composed
    ecm_result: object | None = None      # repro.core.mem.EcmResult
    # --- degradation provenance (docs/robustness.md) --------------------
    degraded: bool = False                # a cheaper backend answered after
    #                                       the requested one failed
    backend_used: str = ""                # fallback rung ("" = as requested)
    fault_trace_id: int = 0               # FaultInjector event id (0 = none)
    routed_from: str = ""                 # rung the HealthRouter skipped
    #                                       pre-dispatch ("" = not routed)
    probe: bool = False                   # answered by a scheduled
    #                                       half-open probe dispatch

    @property
    def cycles_per_source_iteration(self) -> float:
        """Combined bound scaled back to one *source* loop iteration."""
        return self.predicted_cycles / self.unroll_factor

    @property
    def sim_per_source_iteration(self) -> float:
        """The simulated bound per source iteration (0 if not simulated)."""
        return self.bound_sim / self.unroll_factor

    @property
    def ecm_per_source_iteration(self) -> float:
        """The ECM-composed bound per source iteration (0 if no ECM)."""
        return self.bound_ecm / self.unroll_factor

    @property
    def port_bound_per_source_iteration(self) -> float:
        """The paper's pure port-occupation bound per source iteration."""
        return self.port_bound_cycles / self.unroll_factor

    @property
    def lcd_per_source_iteration(self) -> float:
        """The loop-carried-dependency bound per source iteration."""
        return self.lcd_cycles / self.unroll_factor

    # ------------------------------------------------------------------
    def render(self, precision: int = 2) -> str:
        headers = []
        for p in self.model.ports:
            headers.append(f"{p} - DV" if p in self.model.divider_ports
                           else p)
        width = max(6, max(len(h) for h in headers) + 1)

        def fmt(v: float, hidden: float = 0.0) -> str:
            if v <= 1e-12 and hidden <= 1e-12:
                return " " * width
            if hidden > 1e-12:
                return f"({hidden:.{precision}f})".rjust(width)
            return f"{v:.{precision}f}".rjust(width)

        lines = ["| " + " | ".join(h.rjust(width) for h in headers)
                 + " | Assembly Instructions"]
        lines.append("|" + "-" * (len(lines[0]) - 1))
        for row in self.rows:
            cells = [fmt(row.occupation.get(p, 0.0),
                         row.hidden_occupation.get(p, 0.0))
                     for p in self.model.ports]
            marker = "" if row.matched else "   # NOT IN DB"
            lines.append("| " + " | ".join(cells) + " | "
                         + row.instruction.text + marker)
        totals = [f"{self.port_totals[p]:.{precision}f}".rjust(width)
                  for p in self.model.ports]
        lines.append("|" + "-" * (len(lines[0]) - 1))
        lines.append("| " + " | ".join(totals) + " |")
        unit = self.model.unit
        lines.append(
            f"Port (throughput) bound: {self.port_bound_cycles:.{precision}f}"
            f" {unit}/asm-it   (bottleneck port {self.bottleneck_port})")
        if self.latency_result is not None:
            lines.append(
                f"Loop-carried dependency: {self.lcd_cycles:.{precision}f} "
                f"{unit}/asm-it"
                + ("" if not self.latency_result.chain else
                   "   (critical chain: "
                   + " -> ".join(i.mnemonic
                                 for i in self.latency_result.chain) + ")"))
        if self.sim_result is not None:
            lines.append(
                f"Simulated (cycle-level): {self.bound_sim:.{precision}f} "
                f"{unit}/asm-it"
                + (f"   ({self.sim_result.bottleneck}-limited)"
                   if getattr(self.sim_result, "bottleneck", "") else ""))
        if self.ecm_result is not None:
            lines.append(
                f"ECM composition: {self.bound_ecm:.{precision}f} {unit}"
                f"/asm-it   {self.ecm_result.notation()}"
                f"   (working set {self.ecm_result.working_set:.0f} B, "
                f"{self.ecm_result.resident}-resident)")
        rule = "ECM" if self.ecm_result is not None \
            else "simulation" if self.sim_result is not None \
            else "max(port, LCD)"
        lines.append(
            f"Predicted: {self.predicted_cycles:.{precision}f} {unit}/asm-it"
            f" = {rule}"
            + (f"   ({self.cycles_per_source_iteration:.{precision}f} "
               f"{unit}/src-it @ unroll "
               f"{self.unroll_factor})" if self.unroll_factor != 1 else "")
            + f"   [{self.binding}-bound, scheduler={self.scheduler}]")
        if self.missing:
            lines.append("Missing forms (benchmarks auto-generated):")
            for m in self.missing:
                lines.append("  - " + m.instruction.form)
        return "\n".join(lines)


def hidden_instruction_indices(model: PortModel,
                               entries: list) -> set[int]:
    """Zen store/load AGU pairing (paper Sec. III-A, Table IV): each
    store instruction lets one load's hideable AGU uops execute in its
    shadow; OSACA hides the first loads in program order.  Shared by the
    analytic pipeline and the simulator so both model the same machine.

    Args:
        model: the port model (only ``store_hides_load`` matters).
        entries: DB entry (or None) per kernel instruction.
    Returns:
        indices of instructions whose hideable-load uops are hidden.
    """
    hidden: set[int] = set()
    if not model.store_hides_load:
        return hidden
    n_stores = sum(
        1 for e in entries
        if e is not None and any(u.kind == "store-agu" for u in e.uops))
    budget = n_stores
    for idx, e in enumerate(entries):
        if budget == 0:
            break
        if e is not None and any(u.hideable_load for u in e.uops):
            hidden.add(idx)
            budget -= 1
    return hidden


def analyze(kernel: list[Instruction], db: InstructionDB,
            scheduler: str = "uniform",
            unroll_factor: int = 1, *,
            latency_bound: bool = True,
            store_forward_latency: float | None = None,
            schedule_fn: Callable | None = None,
            lookup: Callable | None = None,
            edges: "list[tuple[int, int, float, bool]] | None" = None,
            ) -> AnalysisResult:
    """Predict kernel runtime as ``max(port_bound, loop-carried dep)``.

    Args:
        kernel: instructions of one assembly loop iteration (see
            :func:`repro.core.kernel.extract_kernel`).
        db: the machine to analyze on — an instruction-form database, a
            :class:`~repro.core.machine.MachineModel`, or an arch
            id/alias resolved through the default registry.
        scheduler: ``"uniform"`` (paper assumption 2) or ``"balanced"``
            (IACA-like min-max LP).
        unroll_factor: assembly-iterations per source iteration; only
            affects the ``*_per_source_iteration`` properties.
        latency_bound: when True (default) also run the critical-path /
            LCD analysis and fold it into ``predicted_cycles``; when
            False, reproduce the paper's pure throughput model.
        store_forward_latency: override for the architecture's
            store->load forwarding latency (defaults to the PortModel's).
        schedule_fn: override for ``SCHEDULERS[scheduler]`` — the batched
            :class:`repro.core.engine.AnalysisService` injects a
            memoizing wrapper around the balanced-scheduler LP here.
        lookup: override for ``db.lookup`` (memoized by the service).
        edges: precomputed :func:`repro.core.latency.dependency_edges`
            result for the LCD pass (memoized by the service); ignored
            when ``store_forward_latency`` overrides the model value.
    """
    db = as_database(db)
    model = db.model
    if schedule_fn is None:
        schedule_fn = SCHEDULERS[scheduler]
    if lookup is None:
        lookup = db.lookup

    # 1. match instruction forms
    matched: list[tuple[Instruction, object]] = []
    missing: list[MissingForm] = []
    for ins in kernel:
        entry = lookup(ins)
        if entry is None and not _is_ignorable(ins):
            missing.append(MissingForm(ins))
        matched.append((ins, entry))

    # 2. Zen AGU pairing: each store hides one load instruction's
    #    hideable AGU uops (the first loads in program order, as OSACA does)
    hidden_instrs = hidden_instruction_indices(model,
                                               [e for _, e in matched])

    # 3. flatten uops and schedule
    visible_uops: list[tuple[int, object]] = []
    hidden_uops: list[tuple[int, object]] = []
    for idx, (ins, e) in enumerate(matched):
        if e is None:
            continue
        for uop in e.uops:
            if idx in hidden_instrs and uop.hideable_load:
                hidden_uops.append((idx, uop))
            else:
                visible_uops.append((idx, uop))
    scheduled = schedule_fn(model, visible_uops)
    scheduled_hidden = SCHEDULERS["uniform"](model, hidden_uops)

    # 4. accumulate per-instruction and per-port occupation
    rows: list[InstructionReport] = []
    per_instr: dict[int, dict[str, float]] = {}
    per_instr_hidden: dict[int, dict[str, float]] = {}
    for s in scheduled:
        merge_occupation(per_instr.setdefault(s.instr_index, {}),
                         s.assignment)
    for s in scheduled_hidden:
        merge_occupation(per_instr_hidden.setdefault(s.instr_index, {}),
                         s.assignment)
    port_totals = model.zero_occupation()
    for idx, (ins, e) in enumerate(matched):
        occ = per_instr.get(idx, {})
        merge_occupation(port_totals, occ)
        rows.append(InstructionReport(
            instruction=ins, occupation=occ,
            hidden_occupation=per_instr_hidden.get(idx, {}),
            throughput=getattr(e, "throughput", None),
            latency=getattr(e, "latency", None),
            matched=e is not None))

    bottleneck = max(port_totals, key=lambda p: port_totals[p])
    port_bound = port_totals[bottleneck]

    # 5. critical-path / loop-carried-dependency bound (arXiv:1910.00214):
    #    the headline prediction is max(throughput bound, LCD).
    lat_res: LatencyResult | None = None
    lcd = 0.0
    if latency_bound:
        if store_forward_latency is not None:
            edges = None          # override invalidates injected edges
        lat_res = analyze_latency(
            kernel, db, store_forward_latency=store_forward_latency,
            lookup=lookup, edges=edges)
        lcd = lat_res.loop_carried_cycles
    combined = max(port_bound, lcd)
    binding = "latency" if lcd > port_bound + 1e-9 else "throughput"

    return AnalysisResult(
        model=model, rows=rows, port_totals=port_totals,
        bottleneck_port=bottleneck,
        predicted_cycles=combined,
        missing=missing, scheduler=scheduler, unroll_factor=unroll_factor,
        port_bound_cycles=port_bound, lcd_cycles=lcd,
        latency_result=lat_res, binding=binding)


def _is_ignorable(ins: Instruction) -> bool:
    return ins.mnemonic in ("nop", "vzeroupper", "endbr64", "ret", "leave")
