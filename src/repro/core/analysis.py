"""Throughput analysis (paper Sec. III): map every kernel instruction to its
DB entry, schedule uops onto ports, sum per-port occupation, report the
bottleneck port and the predicted cycles per (assembly) loop iteration.

Implements the Zen store/load AGU pairing: each store instruction hides one
load instruction's AGU uops (displayed parenthesised, excluded from totals) —
paper Sec. III-A, Table IV.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .database import InstructionDB, MissingForm
from .isa import Instruction
from .ports import PortModel, merge_occupation
from .scheduler import SCHEDULERS, ScheduledUop


@dataclass
class InstructionReport:
    instruction: Instruction
    occupation: dict[str, float]          # visible occupation per port
    hidden_occupation: dict[str, float]   # parenthesised (hidden) occupation
    throughput: float | None
    latency: float | None
    matched: bool

    def total(self) -> float:
        return sum(self.occupation.values())


@dataclass
class AnalysisResult:
    model: PortModel
    rows: list[InstructionReport]
    port_totals: dict[str, float]
    bottleneck_port: str
    predicted_cycles: float               # per assembly iteration
    missing: list[MissingForm]
    scheduler: str
    unroll_factor: int = 1

    @property
    def cycles_per_source_iteration(self) -> float:
        return self.predicted_cycles / self.unroll_factor

    # ------------------------------------------------------------------
    def render(self, precision: int = 2) -> str:
        headers = []
        for p in self.model.ports:
            headers.append(f"{p} - DV" if p in self.model.divider_ports
                           else p)
        width = max(6, max(len(h) for h in headers) + 1)

        def fmt(v: float, hidden: float = 0.0) -> str:
            if v <= 1e-12 and hidden <= 1e-12:
                return " " * width
            if hidden > 1e-12:
                return f"({hidden:.{precision}f})".rjust(width)
            return f"{v:.{precision}f}".rjust(width)

        lines = ["| " + " | ".join(h.rjust(width) for h in headers)
                 + " | Assembly Instructions"]
        lines.append("|" + "-" * (len(lines[0]) - 1))
        for row in self.rows:
            cells = [fmt(row.occupation.get(p, 0.0),
                         row.hidden_occupation.get(p, 0.0))
                     for p in self.model.ports]
            marker = "" if row.matched else "   # NOT IN DB"
            lines.append("| " + " | ".join(cells) + " | "
                         + row.instruction.text + marker)
        totals = [f"{self.port_totals[p]:.{precision}f}".rjust(width)
                  for p in self.model.ports]
        lines.append("|" + "-" * (len(lines[0]) - 1))
        lines.append("| " + " | ".join(totals) + " |")
        lines.append(
            f"Bottleneck port: {self.bottleneck_port}   predicted "
            f"{self.predicted_cycles:.{precision}f} {self.model.unit}/asm-it"
            + (f"   ({self.cycles_per_source_iteration:.{precision}f} "
               f"{self.model.unit}/src-it @ unroll "
               f"{self.unroll_factor})" if self.unroll_factor != 1 else "")
            + f"   [scheduler={self.scheduler}]")
        if self.missing:
            lines.append("Missing forms (benchmarks auto-generated):")
            for m in self.missing:
                lines.append("  - " + m.instruction.form)
        return "\n".join(lines)


def analyze(kernel: list[Instruction], db: InstructionDB,
            scheduler: str = "uniform",
            unroll_factor: int = 1) -> AnalysisResult:
    model = db.model
    schedule_fn = SCHEDULERS[scheduler]

    # 1. match instruction forms
    matched: list[tuple[Instruction, object]] = []
    missing: list[MissingForm] = []
    for ins in kernel:
        entry = db.lookup(ins)
        if entry is None and not _is_ignorable(ins):
            missing.append(MissingForm(ins))
        matched.append((ins, entry))

    # 2. Zen AGU pairing: each store hides one load instruction's
    #    hideable AGU uops (the first loads in program order, as OSACA does)
    hidden_instrs: set[int] = set()
    if model.store_hides_load:
        n_stores = sum(
            1 for ins, e in matched
            if e is not None and any(u.kind == "store-agu" for u in e.uops))
        if n_stores:
            budget = n_stores
            for idx, (ins, e) in enumerate(matched):
                if budget == 0:
                    break
                if e is not None and any(u.hideable_load for u in e.uops):
                    hidden_instrs.add(idx)
                    budget -= 1

    # 3. flatten uops and schedule
    visible_uops: list[tuple[int, object]] = []
    hidden_uops: list[tuple[int, object]] = []
    for idx, (ins, e) in enumerate(matched):
        if e is None:
            continue
        for uop in e.uops:
            if idx in hidden_instrs and uop.hideable_load:
                hidden_uops.append((idx, uop))
            else:
                visible_uops.append((idx, uop))
    scheduled = schedule_fn(model, visible_uops)
    scheduled_hidden = SCHEDULERS["uniform"](model, hidden_uops)

    # 4. accumulate per-instruction and per-port occupation
    rows: list[InstructionReport] = []
    per_instr: dict[int, dict[str, float]] = {}
    per_instr_hidden: dict[int, dict[str, float]] = {}
    for s in scheduled:
        merge_occupation(per_instr.setdefault(s.instr_index, {}),
                         s.assignment)
    for s in scheduled_hidden:
        merge_occupation(per_instr_hidden.setdefault(s.instr_index, {}),
                         s.assignment)
    port_totals = model.zero_occupation()
    for idx, (ins, e) in enumerate(matched):
        occ = per_instr.get(idx, {})
        merge_occupation(port_totals, occ)
        rows.append(InstructionReport(
            instruction=ins, occupation=occ,
            hidden_occupation=per_instr_hidden.get(idx, {}),
            throughput=getattr(e, "throughput", None),
            latency=getattr(e, "latency", None),
            matched=e is not None))

    bottleneck = max(port_totals, key=lambda p: port_totals[p])
    return AnalysisResult(
        model=model, rows=rows, port_totals=port_totals,
        bottleneck_port=bottleneck,
        predicted_cycles=port_totals[bottleneck],
        missing=missing, scheduler=scheduler, unroll_factor=unroll_factor)


def _is_ignorable(ins: Instruction) -> bool:
    return ins.mnemonic in ("nop", "vzeroupper", "endbr64", "ret", "leave")
