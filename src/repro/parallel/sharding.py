"""Logical-axis -> mesh sharding rules (DP x TP x EP + FSDP/ZeRO-3).

Every parameter dimension carries a logical name (see repro.models.schema).
Rules map logical names to mesh axes:

  vocab / q_heads / kv_heads / ff / experts -> "model"  (tensor/expert par.)
  embed                                     -> FSDP axes (ZeRO-3 over data
                                               [+ pod]); all-gathered at use
  layers                                    -> replicated (scan dim)

A divisibility guard demotes any mapping whose dimension does not divide by
the axis size (e.g. Qwen1.5's 40 heads on a 16-way model axis, or Grok's 8
experts) to replication — those cells then surface as collective-/memory-
heavy rows in the roofline table and are hillclimb targets (EXPERIMENTS.md
§Perf)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.schema import PSpec, is_pspec


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    data_axes: tuple[str, ...]          # batch / FSDP axes
    model_axis: str = "model"
    fsdp: bool = True                   # ZeRO-3 param sharding over data
    # Decode-stationary mode (§Perf iteration C): weights stay fully
    # sharded at use time — the "embed" (contraction) dim of every matrix
    # is computed sharded over the data axes and the tiny per-token
    # partial sums are reduced, instead of all-gathering every weight for
    # every generated token.  Used when the decode batch cannot occupy
    # the data axes (long-context, batch 1).
    stationary_weights: bool = False

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])


def make_rules(mesh: Mesh, fsdp: bool = True,
               stationary_weights: bool = False) -> ShardingRules:
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a != "model")
    return ShardingRules(mesh=mesh, data_axes=data_axes, fsdp=fsdp,
                         stationary_weights=stationary_weights)


# mapping logical name -> candidate mesh assignment builder
def _logical_assignment(rules: ShardingRules):
    m = rules.model_axis
    fsdp_axes = rules.data_axes if rules.fsdp else ()
    return {
        "vocab": m,
        "q_heads": m,
        "kv_heads": m,
        "ff": m,
        "experts": m,
        "heads": m,            # ssm per-head params / dt projection
        "embed": fsdp_axes,    # ZeRO-3
        "layers": None,
        None: None,
    }


def _axis_size(mesh: Mesh, assignment) -> int:
    if assignment is None:
        return 1
    if isinstance(assignment, tuple):
        return int(np.prod([mesh.shape[a] for a in assignment])) \
            if assignment else 1
    return int(mesh.shape[assignment])


def spec_for(pspec: PSpec, rules: ShardingRules) -> P:
    """PartitionSpec for one parameter, with divisibility demotion and
    first-wins axis allocation (a mesh axis may appear only once — e.g.
    stacked MoE weights (layers, experts, embed, ff) map experts->model
    and must then leave ff unsharded)."""
    table = _logical_assignment(rules)
    out: list = []
    used: set = set()
    for dim, logical in zip(pspec.shape, pspec.logical):
        assignment = table.get(logical, None)
        size = _axis_size(rules.mesh, assignment)
        axes = assignment if isinstance(assignment, tuple) \
            else (assignment,) if assignment else ()
        if assignment in (None, ()) or size <= 1 or dim % size != 0 \
                or any(a in used for a in axes):
            out.append(None)
        else:
            out.append(assignment)
            used.update(axes)
    # trim trailing Nones (canonical form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(schema, rules: ShardingRules):
    """Pytree of NamedSharding mirroring the params tree."""
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, spec_for(s, rules)),
        schema, is_leaf=is_pspec)


# ---------------------------------------------------------------------- #
# Activations / inputs
# ---------------------------------------------------------------------- #

def batch_spec(rules: ShardingRules) -> P:
    return P(rules.data_axes)


def batch_shardings(batch_tree, rules: ShardingRules):
    """Shard dim 0 (global batch) over the data axes; demote if indivisible
    (long_500k has batch 1 -> fully replicated inputs, the cache carries
    the parallelism instead)."""
    def one(x):
        dim0 = x.shape[0] if getattr(x, "shape", ()) else 0
        if dim0 and dim0 % max(rules.data_size, 1) == 0:
            return NamedSharding(rules.mesh, P(rules.data_axes))
        return NamedSharding(rules.mesh, P())
    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, rules: ShardingRules, batch: int,
                    stacked: bool = True):
    """KV/SSM cache sharding.  Batch >= data axes: shard batch.  batch==1
    (long-context): shard the sequence/window dim of attention caches over
    the data axes (flash-decoding style) and SSM heads over model."""
    m = rules.model_axis
    msize = rules.model_size
    dsize = rules.data_size
    shard_batch = batch % dsize == 0

    def one(x):
        shape = x.shape
        off = 1 if stacked and len(shape) >= 1 else 0  # leading n_groups
        spec: list = [None] * len(shape)
        dims = shape[off:]
        if len(dims) == 4 and not hasattr(x, "_ssm"):  # (B,W,H,D) or (B,H,P,N)
            pass
        # identify attention kv (B,W,Hkv,Dh) vs ssm h (B,H,P,N) vs conv
        if shard_batch:
            if len(dims) >= 1 and dims[0] % dsize == 0:
                spec[off] = rules.data_axes
        elif len(dims) == 4 and dims[1] % dsize == 0 and dims[1] >= dsize:
            # batch==1 attention cache: shard window dim over data
            spec[off + 1] = rules.data_axes
        # model axis on the head-ish dim when divisible
        if len(dims) == 4:
            # attention cache (B,W,Hkv,Dh): dims[2]=Hkv; ssm h (B,H,P,N):
            # dims[1]=H.  Try Hkv first, else H.
            if spec[off + 2] is None and dims[2] % msize == 0 \
                    and dims[2] >= msize:
                spec[off + 2] = m
            elif spec[off + 1] is None and dims[1] % msize == 0 \
                    and dims[1] >= msize:
                spec[off + 1] = m
        elif len(dims) == 3 and dims[2] % msize == 0 and dims[2] >= msize:
            spec[off + 2] = m  # conv state (B,K-1,di)
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(rules.mesh, P(*spec))

    return jax.tree.map(one, cache_tree)


def replicated(rules: ShardingRules):
    return NamedSharding(rules.mesh, P())


# ---------------------------------------------------------------------- #
# Activation-sharding context: model code annotates intermediate tensors
# with logical names; outside a context (smoke tests on one device) the
# annotation is a no-op.  XLA's sharding propagation degrades badly
# through lax.scan layer stacks without these constraints (first dry-run
# measured 84 GiB/dev temp on qwen2.5-3b; with constraints ~5 GiB).
# ---------------------------------------------------------------------- #

_ACTIVE_RULES: list[ShardingRules | None] = [None]


class activation_sharding:
    def __init__(self, rules: ShardingRules | None):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()


def active_rules() -> ShardingRules | None:
    return _ACTIVE_RULES[-1]


def compute_spec_for(pspec: PSpec, rules: ShardingRules,
                     drop_layers: bool = True) -> P:
    """PartitionSpec for a parameter *at use time* inside a block: FSDP
    ("embed") axes are gathered (None); tensor/expert-parallel axes stay.
    With drop_layers the leading scan ("layers") dim is removed — the spec
    then matches the per-layer slice seen inside the scan body."""
    table = _logical_assignment(rules)
    out: list = []
    used: set = set()
    for dim, logical in zip(pspec.shape, pspec.logical):
        if logical == "layers" and drop_layers:
            continue
        if logical == "embed":
            if rules.stationary_weights and \
                    dim % max(rules.data_size, 1) == 0 and \
                    not any(a in used for a in rules.data_axes):
                out.append(rules.data_axes)
                used.update(rules.data_axes)
            else:
                out.append(None)
            continue
        assignment = table.get(logical, None)
        size = _axis_size(rules.mesh, assignment)
        axes = assignment if isinstance(assignment, tuple) \
            else (assignment,) if assignment else ()
        if assignment in (None, ()) or size <= 1 or dim % size != 0 \
                or any(a in used for a in axes):
            out.append(None)
        else:
            out.append(assignment)
            used.update(axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def compute_specs(schema, rules: ShardingRules, drop_layers: bool = True):
    """Pytree of use-time PartitionSpecs mirroring the params tree."""
    import jax as _jax
    from repro.models.schema import is_pspec as _is_pspec
    return _jax.tree.map(
        lambda s: compute_spec_for(s, rules, drop_layers), schema,
        is_leaf=_is_pspec)


def gather_params(params, specs):
    """FSDP just-in-time weight gather: constrain each param leaf to its
    use-time spec (inside a scan body this inserts one all-gather per
    layer, the ZeRO-3 pattern).  No-op outside an activation context."""
    rules = _ACTIVE_RULES[-1]
    if rules is None or specs is None:
        return params
    import jax as _jax

    def one(x, spec):
        return _jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, spec))
    return _jax.tree.map(one, params, specs,
                         is_leaf=lambda x: isinstance(x, P))


def moe_sharding_mode(n_experts: int) -> str:
    """"ep" when experts divide the model axis (shard experts), else "tp"
    (shard each expert's d_ff) — e.g. Grok-1's 8 experts on a 16-way
    model axis."""
    rules = _ACTIVE_RULES[-1]
    if rules is None:
        return "ep"
    return "ep" if n_experts % rules.model_size == 0 else "tp"


def row_parallel_matmul(x, w, enabled: bool = True):
    """x: (..., k) with k sharded over the model axis (e.g. attention
    heads or d_inner), w: (k, d) row-sharded.  Explicit Megatron
    row-parallel: local partial matmul, bf16 cast, psum over "model" —
    auto-SPMD emits the same all-reduce but in f32 (2x ICI bytes)."""
    import jax as _jax
    rules = _ACTIVE_RULES[-1]
    k = w.shape[0]
    if not enabled or rules is None or k % rules.model_size != 0 \
            or rules.stationary_weights:
        return x @ w
    shard_map, check = shard_map_compat()
    B = x.shape[0]
    batch_ok = B % rules.data_size == 0 and B >= rules.data_size
    lead = (rules.data_axes,) if batch_ok else (None,)
    x_spec = P(*(lead + (None,) * (x.ndim - 2) + ("model",)))
    out_spec = P(*(lead + (None,) * (x.ndim - 2)))

    def local_fn(wl, xl):
        return _jax.lax.psum((xl @ wl).astype(xl.dtype), "model")

    return shard_map(local_fn, mesh=rules.mesh,
                     in_specs=(P("model"), x_spec), out_specs=out_spec,
                     **check)(w, x)


def shard_map_compat():
    """``jax.shard_map`` across jax versions.

    Returns ``(shard_map, check_kwargs)``: the function from its current
    home (top-level since ~0.6, ``jax.experimental.shard_map`` before)
    and the kwargs that disable the replication check under its current
    name (``check_vma``, formerly ``check_rep``).
    """
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    import inspect
    sig = inspect.signature(shard_map).parameters
    check = {"check_vma": False} if "check_vma" in sig else \
        {"check_rep": False}
    return shard_map, check


def constrain(x, *logical):
    """logical per dim: "batch" -> data axes, "model" -> model axis,
    None -> unsharded.  Dims that do not divide are demoted."""
    rules = _ACTIVE_RULES[-1]
    if rules is None:
        return x
    spec = []
    for dim, name in zip(x.shape, logical):
        if name == "batch" and dim % rules.data_size == 0:
            spec.append(rules.data_axes)
        elif name == "model" and dim % rules.model_size == 0:
            spec.append(rules.model_axis)
        elif name == "seq" and dim % rules.data_size == 0:
            spec.append(rules.data_axes)
        else:
            spec.append(None)
    while spec and spec[-1] is None:
        spec.pop()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*spec)))
