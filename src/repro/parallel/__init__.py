from .sharding import (ShardingRules, make_rules, param_shardings,
                       batch_shardings, cache_shardings, spec_for)
