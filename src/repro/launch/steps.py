"""Step builders: jit-able train / prefill / decode / encode steps with
their input/output shardings and ShapeDtypeStruct stand-ins (no device
allocation — the dry-run path)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (abstract_cache, abstract_params, decode_step,
                          encode, model_schema, prefill, train_loss)
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.transformer import AUDIO_FRAME_DIM
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import (ShardingRules, batch_shardings,
                                     cache_shardings, compute_specs,
                                     param_shardings)


# --------------------------------------------------------------------- #
# Input specs (ShapeDtypeStructs) per (config x shape cell)
# --------------------------------------------------------------------- #

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"token": sds((B, 1), jnp.int32),
                "pos": sds((), jnp.int32)}
    batch: dict = {}
    if cfg.modality == "audio":
        batch["frames"] = sds((B, S, AUDIO_FRAME_DIM), jnp.bfloat16)
    else:
        batch["tokens"] = sds((B, S), jnp.int32)
    if cfg.modality == "vision":
        batch["patches"] = sds((B, cfg.n_patches, cfg.d_model),
                               jnp.bfloat16)
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    return batch


@dataclass
class Step:
    name: str
    fn: Callable                      # jit-ready python callable
    args: tuple                       # abstract example arguments
    in_shardings: tuple
    out_shardings: Any
    rules: ShardingRules | None = None

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings)

    def lower(self):
        from repro.parallel.sharding import activation_sharding
        with activation_sharding(self.rules):
            return self.jitted().lower(*self.args)


# --------------------------------------------------------------------- #
# Builders
# --------------------------------------------------------------------- #

def _state_shardings(cfg: ModelConfig, rules: ShardingRules):
    schema = model_schema(cfg)
    pshard = param_shardings(schema, rules)
    return {
        "params": pshard,
        "opt": {"m": pshard, "v": pshard,
                "step": NamedSharding(rules.mesh, P())},
    }


def abstract_state(cfg: ModelConfig, opt: AdamWConfig):
    params = abstract_params(model_schema(cfg))
    opt_state = jax.eval_shape(partial(adamw_init, cfg=opt), params)
    return {"params": params, "opt": opt_state}


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                         rules: ShardingRules,
                         act_budget_bytes: float = 3e9) -> int:
    """Gradient-accumulation factor so per-device scan-saved activations
    (one (B/dp, S, d) residual per layer) stay under the budget."""
    per_dev = (shape.global_batch / max(rules.data_size, 1)) \
        * shape.seq_len * cfg.d_model * 2 * (cfg.n_layers + 2)
    if cfg.seq_shard_residual:
        per_dev /= max(rules.model_size, 1)
    # every microbatch must still divide the data axis, or activations
    # lose their batch sharding entirely (measured 180 GiB/dev on
    # nemotron-4 before this cap)
    n_max = max(1, shape.global_batch // max(rules.data_size, 1))
    n = 1
    while per_dev / n > act_budget_bytes and n < n_max:
        n *= 2
    return min(n, n_max)


def build_train_step(cfg: ModelConfig, shape: ShapeConfig,
                     rules: ShardingRules,
                     opt: AdamWConfig | None = None,
                     microbatches: int | None = None) -> Step:
    opt = opt or AdamWConfig(
        moment_dtype=jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16"
        else jnp.float32)
    if microbatches is None and cfg.train_microbatches:
        microbatches = cfg.train_microbatches
    n_micro = microbatches if microbatches is not None \
        else default_microbatches(cfg, shape, rules)
    specs = compute_specs(model_schema(cfg), rules)

    def split_micro(x):
        B = x.shape[0]
        return x.reshape(n_micro, B // n_micro, *x.shape[1:])

    def train_step(state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(train_loss)(
                state["params"], batch, cfg, specs)
        else:
            micro = jax.tree.map(split_micro, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32),
                state["params"])

            def accum(carry, mb):
                tot_loss, tot_grad = carry
                loss, grads = jax.value_and_grad(train_loss)(
                    state["params"], mb, cfg, specs)
                tot_grad = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32),
                    tot_grad, grads)
                return (tot_loss + loss, tot_grad), None

            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zero), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        params, opt_state, gnorm = adamw_update(
            state["params"], grads, state["opt"], opt)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return {"params": params, "opt": opt_state}, metrics

    st_shard = _state_shardings(cfg, rules)
    batch = input_specs(cfg, shape)
    b_shard = batch_shardings(batch, rules)
    repl = NamedSharding(rules.mesh, P())
    return Step(
        name="train_step", fn=train_step,
        args=(abstract_state(cfg, opt), batch),
        in_shardings=(st_shard, b_shard),
        out_shardings=(st_shard, {"loss": repl, "grad_norm": repl}),
        rules=rules)


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                       rules: ShardingRules) -> Step:
    batch = input_specs(cfg, shape)
    b_shard = batch_shardings(batch, rules)
    schema = model_schema(cfg)
    p_shard = param_shardings(schema, rules)
    params = abstract_params(schema)

    specs = compute_specs(schema, rules)
    if cfg.encoder_only:
        def encode_step(params, batch):
            return encode(params, batch, cfg, specs)
        logits_shard = NamedSharding(
            rules.mesh, P(rules.data_axes, None, None))
        return Step("encode_step", encode_step, (params, batch),
                    (p_shard, b_shard), logits_shard, rules=rules)

    def prefill_step(params, batch):
        return prefill(params, batch, cfg, param_specs=specs)

    # cache sharding derived from the abstract output structure.
    # NOTE: must trace inside the activation context — JAX caches the
    # jaxpr per function object, and a context-less eval_shape here would
    # be reused by .lower(), silently dropping every sharding constraint
    # and the shard_map MoE path (observed: jamba prefill fell back to
    # the naive dispatch with 16 GB f32 all-reduces per layer).
    from repro.parallel.sharding import activation_sharding
    with activation_sharding(rules):
        out_abstract = jax.eval_shape(prefill_step, params, batch)
    logits_a, cache_a = out_abstract
    c_shard = cache_shardings(cache_a, rules, shape.global_batch)
    # prefix caches are unstacked
    if cache_a["prefix"]:
        c_shard["prefix"] = cache_shardings(
            cache_a["prefix"], rules, shape.global_batch, stacked=False)
    logits_shard = NamedSharding(rules.mesh, P(rules.data_axes))
    return Step("prefill_step", prefill_step, (params, batch),
                (p_shard, b_shard), (logits_shard, c_shard), rules=rules)


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig,
                      rules: ShardingRules) -> Step:
    if rules.stationary_weights is False and \
            shape.global_batch < rules.data_size:
        # single-sequence decode cannot occupy the data axes with batch;
        # keep weights fully sharded (stationary) and reduce the tiny
        # per-token partial sums instead of gathering weights per token
        from repro.parallel.sharding import make_rules as _mk
        rules = _mk(rules.mesh, fsdp=rules.fsdp, stationary_weights=True)
    schema = model_schema(cfg)
    p_shard = param_shardings(schema, rules)
    params = abstract_params(schema)
    B, S = shape.global_batch, shape.seq_len
    cache = abstract_cache(cfg, B, S)
    c_shard = cache_shardings(cache, rules, B)
    if cache["prefix"]:
        c_shard["prefix"] = cache_shardings(cache["prefix"], rules, B,
                                            stacked=False)
    inputs = input_specs(cfg, shape)
    tok_shard = batch_shardings(inputs, rules)

    specs = compute_specs(schema, rules)

    def serve_step(params, token, pos, cache):
        return decode_step(params, token, pos, cache, cfg,
                           param_specs=specs)

    logits_shard = NamedSharding(
        rules.mesh,
        P(rules.data_axes) if B % rules.data_size == 0 else P())
    return Step(
        "serve_step", serve_step,
        (params, inputs["token"], inputs["pos"], cache),
        (p_shard, tok_shard["token"], tok_shard["pos"], c_shard),
        (logits_shard, c_shard), rules=rules)


def build_step(cfg: ModelConfig, shape: ShapeConfig,
               rules: ShardingRules) -> Step:
    if shape.kind == "train":
        return build_train_step(cfg, shape, rules)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, rules)
    return build_decode_step(cfg, shape, rules)
