"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the "pod"
axis carries data parallelism + ZeRO sharding across pods (DCN-ish in real
deployments; ICI-attached in the port model with its own link budget).

Defined as functions so importing this module never touches jax device
state (the dry-run pins XLA_FLAGS before first jax init)."""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False) -> Mesh:
    """Reduced mesh for CI (8 forced host devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) > n:
        # e.g. 512 forced host devices, single-pod 256-chip mesh
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
    raise RuntimeError(
        f"need {n} devices for mesh {dict(zip(axes, shape))}, have "
        f"{len(devices)}; the dry-run must set "
        f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
        f"any jax import")
