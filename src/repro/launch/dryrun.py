"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, record memory/cost analysis and the
port-model roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be invoked as its own process (the XLA_FLAGS lines below run before
any jax import):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core.engine import default_service
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models.config import SHAPES
from repro.parallel.sharding import make_rules

# ---- skip table (see DESIGN.md §4) -----------------------------------
FULL_ATTENTION = {"kimi-k2-1t-a32b", "grok-1-314b", "qwen1.5-32b",
                  "nemotron-4-340b", "qwen2.5-3b", "llava-next-34b"}
ENCODER_ONLY = {"hubert-xlarge"}


def cell_skip_reason(arch: str, shape: str) -> str | None:
    if arch in ENCODER_ONLY and shape in ("decode_32k", "long_500k"):
        return "encoder-only: no autoregressive decode step"
    if arch in FULL_ATTENTION and shape == "long_500k":
        return "pure full attention: 524k dense-KV decode not deployable"
    return None


def _coerce(value: str):
    if value.lower() in ("true", "false"):
        return value.lower() == "true"
    try:
        return int(value)
    except ValueError:
        pass
    try:
        return float(value)
    except ValueError:
        return value


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_text: bool = False,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_updates(**overrides)
    shape = SHAPES[shape_name]
    record: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "overrides": overrides or {},
    }
    skip = cell_skip_reason(arch, shape_name)
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        return record

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh)
    n_chips = mesh.devices.size
    with mesh:
        step = build_step(cfg, shape, rules)
        lowered = step.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        text = compiled.as_text()

    analysis = default_service().predict_hlo(text)
    record.update({
        "status": "ok",
        "step": step.name,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "utilization")
                          if k in cost},
        "portmodel": {
            "flops_per_device": analysis.flops,
            "mxu_flops_per_device": analysis.mxu_flops,
            "hbm_bytes_per_device": analysis.hbm_bytes,
            "ici_bytes_per_device": analysis.ici_bytes,
            "compute_s": analysis.terms.compute_s,
            "memory_s": analysis.terms.memory_s,
            "collective_s": analysis.terms.collective_s,
            "bound_overlap_s": analysis.terms.bound_overlap,
            "bound_serial_s": analysis.terms.bound_serial,
            "critical_path_s": analysis.terms.critical_path_s,
            "bound_combined_s": analysis.terms.bound_combined,
            "binding": analysis.terms.binding,
            "dominant": analysis.terms.dominant,
            "collectives": {k: list(v) for k, v in
                            analysis.collective_breakdown.items()},
        },
    })
    if keep_text:
        record["hlo_text"] = text
    return record


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    if not out:
        out["repr"] = str(mem)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape x mesh) cell")
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--print-hlo", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", dest="overrides",
                    help="ModelConfig override, e.g. --set remat=dots "
                         "--set tp_shard_map=true (perf iterations)")
    args = ap.parse_args(argv)
    overrides = {}
    for kv in args.overrides:
        k, _, v = kv.partition("=")
        overrides[k] = _coerce(v)

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required unless --all")
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    results = []
    failures = 0
    for arch, shape, mp in cells:
        label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
        print(f"=== {label}", flush=True)
        try:
            rec = run_cell(arch, shape, mp, keep_text=args.print_hlo,
                           overrides=overrides)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        results.append(rec)
        if rec["status"] == "ok":
            pm = rec["portmodel"]
            print(f"  ok: step={rec['step']} compile={rec['compile_s']}s "
                  f"temp={rec['memory'].get('temp_size_in_bytes', 0) / 2**30:.2f}GiB/dev "
                  f"dominant={pm['dominant']} "
                  f"bound={pm['bound_combined_s'] * 1e3:.2f}ms "
                  f"({pm['binding']}-bound)", flush=True)
            print(f"  memory_analysis: {rec['memory']}")
            print(f"  cost_analysis:   {rec['cost_analysis']}")
        elif rec["status"] == "skipped":
            print(f"  skipped: {rec['reason']}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"done: {sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
