from .config import ModelConfig, ShapeConfig, SHAPES
from .schema import (abstract_params, init_params, logical_axes,
                     param_count, PSpec)
from .transformer import (count_params, decode_step, encode, forward,
                          init_cache, abstract_cache, model_schema,
                          prefill, train_loss)
