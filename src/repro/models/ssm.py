"""Mamba-2 SSD (state-space duality) mixer — arXiv:2405.21060.

Chunked algorithm: within a chunk of length Q the recurrence is expanded
into an attention-like quadratic form (runs on the MXU); across chunks a
sequential scan passes the (H, P, N) state.  The per-chunk inner kernel is
the Pallas hot spot (repro.kernels.ssd_scan); this module is the XLA
reference path used by training, the dry-run and the oracle tests.

Also used (with small d_state) for the Mamba layers of the Jamba hybrid —
Jamba itself uses Mamba-1; the SSD formulation is the TPU-native adaptation
(DESIGN.md Sec. 3: MXU-friendly chunked matmuls instead of the GPU
selective-scan kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import constrain

from .config import ModelConfig
from .schema import PSpec


def ssm_schema(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    H, N, G, K = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv
    return {
        "wz": PSpec((d, di), ("embed", "ff")),
        "wx": PSpec((d, di), ("embed", "ff")),
        "wbc": PSpec((d, 2 * G * N), ("embed", None)),
        "wdt": PSpec((d, H), ("embed", "heads")),
        "conv_x": PSpec((K, di), (None, "ff")),
        "conv_bc": PSpec((K, 2 * G * N), (None, None)),
        "a_log": PSpec((H,), ("heads",), dtype=jnp.float32, init="zeros"),
        "dt_bias": PSpec((H,), ("heads",), dtype=jnp.float32, init="zeros"),
        "d_skip": PSpec((H,), ("heads",), dtype=jnp.float32, init="ones"),
        "norm": PSpec((di,), ("ff",), init="ones"),
        "out_proj": PSpec((di, d), ("ff", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        out = out + xp[:, k:k + x.shape[1], :].astype(jnp.float32) \
            * w[k].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


def _project(params, u, cfg: ModelConfig):
    """u: (B,S,d) -> z,x,Bm,Cm,dt (post conv/activations) + raw conv
    inputs (needed for the decode conv-state cache)."""
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = constrain(u @ params["wz"], "batch", None, "model")   # (B,S,di)
    x_raw = constrain(u @ params["wx"], "batch", None, "model")
    bc_raw = u @ params["wbc"]                             # (B,S,2GN)
    dt = u.astype(jnp.float32) @ params["wdt"].astype(jnp.float32)
    x = _causal_conv(x_raw, params["conv_x"])
    bc = _causal_conv(bc_raw, params["conv_bc"])
    Bm, Cm = jnp.split(bc, 2, axis=-1)                     # (B,S,GN) each
    dt = jax.nn.softplus(dt + params["dt_bias"])           # (B,S,H)
    return z, x, Bm, Cm, dt, x_raw, bc_raw


def ssd_forward(params: dict, u: jax.Array, cfg: ModelConfig,
                return_state: bool = False):
    """Full-sequence forward.  u: (B,S,d) -> (B,S,d)."""
    B, S_orig, _ = u.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, \
        cfg.ssm_groups
    Q = min(cfg.ssm_chunk, S_orig)
    S = -(-S_orig // Q) * Q
    if S != S_orig:
        u = jnp.pad(u, ((0, 0), (0, S - S_orig), (0, 0)))
    nc = S // Q

    z, x, Bm, Cm, dt, x_raw, bc_raw = _project(params, u, cfg)
    if S != S_orig:
        # zero dt on padded steps: da=0 and dt*B*x=0 keep the recurrent
        # state exact through the padding
        valid = (jnp.arange(S) < S_orig)[None, :, None]
        dt = dt * valid
    xh = x.reshape(B, nc, Q, H, P)
    Bh = Bm.reshape(B, nc, Q, G, N)
    Ch = Cm.reshape(B, nc, Q, G, N)
    dtc = dt.reshape(B, nc, Q, H)
    A = -jnp.exp(params["a_log"])                          # (H,) negative
    dA = dtc * A                                           # (B,nc,Q,H)

    # move chunk dim first for the scan
    xh, Bh, Ch, dtc, dA = (t.transpose(1, 0, 2, 3, 4) if t.ndim == 5
                           else t.transpose(1, 0, 2, 3)
                           for t in (xh, Bh, Ch, dtc, dA))

    def chunk_step(h, inp):
        xq, bq, cq, dtq, daq = inp                # (B,Q,H,P),(B,Q,G,N),...
        cum = jnp.cumsum(daq, axis=1)             # (B,Q,H)
        # intra-chunk: y[i] = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
        seg = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H)
        li = jnp.arange(Q)
        mask = li[:, None] >= li[None, :]
        # mask BEFORE exp: above-diagonal seg is positive and overflows,
        # and grad-through-where would still propagate the inf as NaN
        seg = jnp.where(mask[None, :, :, None], seg, -1e30)
        L = jnp.exp(seg)
        cb = jnp.einsum("bqgn,bkgn->bqkg", cq.astype(jnp.float32),
                        bq.astype(jnp.float32))           # (B,Q,Q,G)
        heads_per_group = H // G
        cbh = jnp.repeat(cb, heads_per_group, axis=-1)    # (B,Q,Q,H)
        w = cbh * L * dtq[:, None, :, :]                  # (B,Q,Q,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w,
                             xq.astype(jnp.float32))
        # inter-chunk: contribution of the carried state, C scaled by the
        # decay accumulated since the chunk start
        cqh = jnp.repeat(cq.astype(jnp.float32)[:, :, :, None, :],
                         heads_per_group, axis=3).reshape(B, Q, H, N)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp",
                             cqh * jnp.exp(cum)[..., None], h)
        y = y_intra + y_inter
        # state update: h' = exp(sum dA) h + sum_j exp(cum_last-cum_j) dt_j B_j x_j
        total = cum[:, -1:, :]                            # (B,1,H)
        decay_out = jnp.exp(total - cum)                  # (B,Q,H)
        bqh = jnp.repeat(bq.astype(jnp.float32)[:, :, :, None, :],
                         heads_per_group, axis=3).reshape(B, Q, H, N)
        dS = jnp.einsum("bqhn,bqhp->bhpn",
                        bqh * (decay_out * dtq)[..., None],
                        xq.astype(jnp.float32))
        h_new = h * jnp.exp(total[:, 0, :])[:, :, None, None] + dS
        return h_new, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, ys = lax.scan(chunk_step, h0, (xh, Bh, Ch, dtc, dA))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + params["d_skip"][None, None, :, None] \
        * x.reshape(B, S, H, P).astype(jnp.float32)
    y = y.reshape(B, S, H * P)

    # gated RMSNorm + out projection (Mamba-2 block epilogue)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    rms = jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True)
                        + cfg.norm_eps)
    y = (y * rms * params["norm"].astype(jnp.float32)).astype(u.dtype)
    from repro.parallel.sharding import row_parallel_matmul
    out = row_parallel_matmul(y, params["out_proj"],
                              enabled=cfg.tp_shard_map)
    if S != S_orig:
        out = out[:, :S_orig]
    if return_state:
        K = cfg.ssm_conv
        state = {
            "h": h_final,
            "conv_x": x_raw[:, S_orig - (K - 1):S_orig
                            ].astype(jnp.bfloat16),
            "conv_bc": bc_raw[:, S_orig - (K - 1):S_orig
                              ].astype(jnp.bfloat16),
        }
        return out, state
    return out


# ---------------------------------------------------------------------- #
# Single-token decode
# ---------------------------------------------------------------------- #

def ssm_cache_init(cfg: ModelConfig, batch: int):
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, \
        cfg.ssm_groups
    K = cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, cfg.d_inner), jnp.bfloat16),
        "conv_bc": jnp.zeros((batch, K - 1, 2 * G * N), jnp.bfloat16),
    }


def ssd_decode_step(params: dict, u: jax.Array, cache: dict,
                    cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """u: (B,1,d) -> (B,1,d), updated cache."""
    B = u.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, \
        cfg.ssm_groups
    heads_per_group = H // G
    z = u @ params["wz"]                                   # (B,1,di)
    x = u @ params["wx"]
    bc = u @ params["wbc"]
    dt = u.astype(jnp.float32) @ params["wdt"].astype(jnp.float32)

    # causal conv with cached window
    cw_x = jnp.concatenate([cache["conv_x"].astype(x.dtype), x], axis=1)
    cw_bc = jnp.concatenate([cache["conv_bc"].astype(bc.dtype), bc], axis=1)
    x = jax.nn.silu((cw_x.astype(jnp.float32)
                     * params["conv_x"].astype(jnp.float32)).sum(1,
                     keepdims=True)).astype(x.dtype)
    bc = jax.nn.silu((cw_bc.astype(jnp.float32)
                      * params["conv_bc"].astype(jnp.float32)).sum(1,
                      keepdims=True)).astype(bc.dtype)
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])[:, 0]     # (B,H)
    A = -jnp.exp(params["a_log"])
    da = jnp.exp(dt * A)                                   # (B,H)

    xh = x.reshape(B, H, P).astype(jnp.float32)
    bh = jnp.repeat(Bm.reshape(B, G, N).astype(jnp.float32)[:, :, None, :],
                    heads_per_group, axis=2).reshape(B, H, N)
    ch = jnp.repeat(Cm.reshape(B, G, N).astype(jnp.float32)[:, :, None, :],
                    heads_per_group, axis=2).reshape(B, H, N)
    h = cache["h"] * da[:, :, None, None] + \
        jnp.einsum("bhn,bhp->bhpn", bh * dt[..., None], xh)
    y = jnp.einsum("bhn,bhpn->bhp", ch, h)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, H * P)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    rms = jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True)
                        + cfg.norm_eps)
    y = (y * rms * params["norm"].astype(jnp.float32)).astype(u.dtype)
    out = y @ params["out_proj"]
    new_cache = {
        "h": h,
        "conv_x": cw_x[:, 1:].astype(jnp.bfloat16),
        "conv_bc": cw_bc[:, 1:].astype(jnp.bfloat16),
    }
    return out, new_cache
