"""Mixture-of-Experts FFN: token-choice top-k routing with sort-based
dispatch into fixed-capacity expert buffers (static shapes throughout, so
the same code path serves real execution, AD, and the dry-run).

Sharding modes (picked in repro.parallel.sharding):
  * EP — experts sharded over the `model` axis (n_experts % model == 0);
  * TP — expert d_ff sharded over `model` (few-expert models, e.g. grok-1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import constrain, moe_sharding_mode

from .config import ModelConfig
from .schema import PSpec


def moe_schema(cfg: ModelConfig) -> dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    sch = {
        "router": PSpec((d, E), ("embed", None), dtype=jnp.float32,
                        scale=0.02),
        "w_gate": PSpec((E, d, f), ("experts", "embed", "ff")),
        "w_up": PSpec((E, d, f), ("experts", "embed", "ff")),
        "w_out": PSpec((E, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        sch["shared"] = {
            "w_gate": PSpec((d, fs), ("embed", "ff")),
            "w_up": PSpec((d, fs), ("embed", "ff")),
            "w_out": PSpec((fs, d), ("ff", "embed")),
        }
    return sch


def _activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def _dispatch_compute_combine(params: dict, x: jax.Array,
                              cfg: ModelConfig, e_base: int,
                              e_local: int, capacity: int
                              ) -> tuple[jax.Array, jax.Array]:
    """Local (per-shard) token-choice dispatch for experts
    [e_base, e_base + e_local).  x: (T, d) local tokens.  Returns the
    partial output (zero rows for tokens routed elsewhere) and the local
    load-balance statistics term."""
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ params["router"]          # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(gates, K)                          # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance stats (combined into the global aux loss by caller)
    me = jnp.mean(gates, axis=0)                                # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    # ---- sort local (token, expert) pairs by expert --------------------
    flat_e = top_i.reshape(-1)                                  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    within = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    local_e = se - e_base
    mine = (local_e >= 0) & (local_e < e_local)
    keep = (within < capacity) & mine
    slot = jnp.where(keep, local_e * capacity + within,
                     e_local * capacity)

    # ---- dispatch into (e_local, capacity, d) ---------------------------
    xs = jnp.take(x, st, axis=0)                                # (T*K, d)
    buf = jnp.zeros((e_local * capacity, d), x.dtype)
    buf = buf.at[slot].set(xs, mode="drop")
    buf = buf.reshape(e_local, capacity, d)

    # ---- grouped expert FFN (einsum over the local expert dim) ---------
    g = _activation(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]),
                    cfg.activation)
    if cfg.glu:
        g = g * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g, params["w_out"])

    # ---- combine back to token order ------------------------------------
    y_flat = y.reshape(e_local * capacity, d)
    contrib = jnp.take(y_flat, jnp.where(keep, slot, 0), axis=0)
    contrib = contrib * (sw * keep)[:, None].astype(y_flat.dtype)
    out = jnp.zeros((T, d), jnp.float32).at[st].add(
        contrib.astype(jnp.float32))
    return out, aux


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig
            ) -> tuple[jax.Array, jax.Array]:
    """x: (T, d) flattened tokens -> (out (T, d), aux_loss ()).

    Distributed path (inside an activation-sharding context): shard_map
    over the mesh — tokens stay local to their data shard; each model
    shard dispatches only to its own experts (EP) or computes a d_ff
    slice of every expert (TP), and one psum over "model" combines
    expert outputs.  Communication per layer = one (T_local, d)
    all-reduce, like a Megatron FFN — no global sort/gather (the naive
    SPMD lowering of token dispatch all-gathered activations; see
    EXPERIMENTS.md §Perf iteration log)."""
    from repro.parallel.sharding import active_rules
    rules = active_rules()
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k

    if rules is not None and rules.stationary_weights:
        # decode-stationary: expert weights stay sharded on their
        # contraction ("embed") dim; auto-SPMD turns the handful of
        # decode tokens into partial matmuls + tiny psums, with zero
        # weight movement — the shard_map path would re-gather weights.
        rules = None

    if rules is None:
        capacity = max(8, -(-int(T * K / E * cfg.capacity_factor)
                            ) // 8 * 8)
        out, aux = _dispatch_compute_combine(params, x, cfg, 0, E,
                                             capacity)
        out = out.astype(x.dtype)
    else:
        from repro.parallel.sharding import shard_map_compat
        from jax.sharding import PartitionSpec as P
        shard_map, _check = shard_map_compat()
        mode = moe_sharding_mode(E)
        msize = rules.model_size
        dsize = rules.data_size
        e_local = E // msize if mode == "ep" else E
        T_loc = T // dsize if T % dsize == 0 else T
        capacity = max(8, -(-int(T_loc * K / E * cfg.capacity_factor)
                            ) // 8 * 8)
        t_spec = P(rules.data_axes) if T % dsize == 0 else P()
        if mode == "ep":
            w_spec = {"router": P(), "w_gate": P("model",),
                      "w_up": P("model",), "w_out": P("model",)}
        else:
            w_spec = {"router": P(), "w_gate": P(None, None, "model"),
                      "w_up": P(None, None, "model"),
                      "w_out": P(None, "model", None)}
        if cfg.n_shared_experts:
            w_spec["shared"] = {"w_gate": P(None, "model"),
                                "w_up": P(None, "model"),
                                "w_out": P("model", None)}
        routed = {k: params[k] for k in w_spec if k in params}

        def local_fn(w, xl):
            if mode == "ep":
                e_base = lax.axis_index("model") * e_local
            else:
                e_base = 0
            Tl = xl.shape[0]
            chunk = min(cfg.moe_token_chunk, Tl)
            if Tl % chunk == 0 and Tl // chunk > 1:
                cap = max(8, -(-int(chunk * K / E * cfg.capacity_factor)
                               ) // 8 * 8)

                def one_chunk(xc):
                    o, a = _dispatch_compute_combine(w, xc, cfg, e_base,
                                                     e_local, cap)
                    return o, a
                outs, auxs = lax.map(
                    one_chunk, xl.reshape(Tl // chunk, chunk, d))
                out = outs.reshape(Tl, d)
                aux = auxs.mean()
            else:
                out, aux = _dispatch_compute_combine(w, xl, cfg,
                                                     e_base, e_local,
                                                     capacity)
            if cfg.n_shared_experts:
                spw = w["shared"]
                h = _activation(xl @ spw["w_gate"], cfg.activation)
                if cfg.glu:
                    h = h * (xl @ spw["w_up"])
                out = out + (h @ spw["w_out"]).astype(jnp.float32)
            # reduce in bf16: per-shard partials are already f32-
            # accumulated; the cross-shard sum in bf16 halves ICI bytes
            # (§Perf iteration B1)
            out = lax.psum(out.astype(xl.dtype), "model")
            aux = lax.pmean(aux, rules.data_axes) if T % dsize == 0 \
                else aux
            aux = lax.pmean(aux, "model")
            return out.astype(xl.dtype), aux

        mapped = shard_map(
            local_fn, mesh=rules.mesh,
            in_specs=(w_spec, P(*t_spec)),
            out_specs=(P(*t_spec), P()),
            **_check)
        out, aux = mapped(routed, x)
        return out, aux

    if cfg.n_shared_experts:
        sp = params["shared"]
        h = _activation(x @ sp["w_gate"], cfg.activation)
        if cfg.glu:
            h = h * (x @ sp["w_up"])
        out = out + (h @ sp["w_out"]).astype(out.dtype)
    return out.astype(x.dtype), aux


def dense_ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d)."""
    from repro.parallel.sharding import active_rules
    rules = active_rules()
    if cfg.tp_shard_map and rules is not None \
            and not rules.stationary_weights \
            and params["w_out"].shape[0] % rules.model_size == 0:
        return _dense_ffn_tp(params, x, cfg, rules)
    h = _activation(x @ params["w_gate"], cfg.activation)
    if cfg.glu:
        h = h * (x @ params["w_up"])
    h = constrain(h, "batch", None, "model")
    return (h @ params["w_out"]).astype(x.dtype)


def _dense_ffn_tp(params: dict, x: jax.Array, cfg: ModelConfig,
                  rules) -> jax.Array:
    """Explicit Megatron-SP TP: the sequence-sharded residual is
    all-gathered (bf16) on entry, the column/row-parallel FFN computes
    locally, and the row-parallel partial sums leave through a bf16
    reduce-scatter back to sequence sharding — replacing auto-SPMD's
    f32 all-reduce + reshard pair (half the bytes twice over)."""
    from repro.parallel.sharding import shard_map_compat
    from jax.sharding import PartitionSpec as P
    shard_map, _check = shard_map_compat()
    B, S, d = x.shape
    batch_ok = B % rules.data_size == 0
    seq_sp = cfg.seq_shard_residual and S % rules.model_size == 0
    x_spec = P(rules.data_axes if batch_ok else None,
               "model" if seq_sp else None)
    w_spec = {"w_gate": P(None, "model"), "w_out": P("model", None)}
    if cfg.glu:
        w_spec["w_up"] = P(None, "model")

    def local_fn(w, xl):
        if seq_sp:
            xl = lax.all_gather(xl, "model", axis=1, tiled=True)
        h = _activation(xl @ w["w_gate"], cfg.activation)
        if cfg.glu:
            h = h * (xl @ w["w_up"])
        partial = (h @ w["w_out"]).astype(xl.dtype)   # bf16 partials
        if seq_sp:
            return lax.psum_scatter(partial, "model",
                                    scatter_dimension=1, tiled=True)
        return lax.psum(partial, "model")

    routed = {k: params[k] for k in w_spec}
    return shard_map(local_fn, mesh=rules.mesh,
                     in_specs=(w_spec, x_spec), out_specs=x_spec,
                     **_check)(routed, x)


def dense_ffn_schema(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    sch = {
        "w_gate": PSpec((d, f), ("embed", "ff")),
        "w_out": PSpec((f, d), ("ff", "embed")),
    }
    if cfg.glu:
        sch["w_up"] = PSpec((d, f), ("embed", "ff"))
    return sch
