"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0              # default d_model // n_heads

    # attention
    attention: str = "full"      # full | swa
    window: int = 4096           # sliding-window size (attention == "swa")
    qkv_bias: bool = False
    causal: bool = True          # False for encoder-only (hubert)
    attn_logit_softcap: float = 0.0

    # FFN
    activation: str = "silu"     # silu | gelu | relu2
    glu: bool = True             # gated (SwiGLU-style) FFN

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # local tokens dispatched per MoE inner chunk (bounds the per-device
    # dispatch buffers to chunk*top_k*d regardless of batch size)
    moe_token_chunk: int = 4096
    # gradient-accumulation override: 0 = auto from the activation budget
    train_microbatches: int = 0
    # first k layers use a dense FFN instead of MoE (Kimi K2 layer 0)
    n_dense_layers: int = 0

    # SSM / hybrid
    layer_pattern: str = "attn"  # attn | ssm | jamba (1 attn per group of 8)
    hybrid_group: int = 8        # layers per hybrid group
    hybrid_attn_index: int = 3   # position of the attn layer inside a group
    moe_every: int = 1           # MoE FFN every n-th layer (jamba: 2)
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # modality frontends (stubs per instructions: precomputed embeddings)
    modality: str = "text"       # text | audio | vision
    n_patches: int = 0           # vision: patch embeddings per sample
    encoder_only: bool = False

    # numerics
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"   # m/v dtype; bf16 for trillion-scale

    # runtime knobs (overridable per experiment; see EXPERIMENTS.md §Perf)
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    loss_chunk: int = 512
    remat: str = "full"          # full | dots | none
    use_pallas: bool = False     # Pallas kernels (TPU); XLA path for dry-run
    prefill_causal_skip: bool = False  # dynamic-bound kv loop (perf iter)
    # Megatron-SP style: residual stream sequence-sharded over the model
    # axis between blocks -> remat-saved activations shrink by the model
    # size and gradient accumulation becomes unnecessary for most archs
    # (weight all-gathers then happen once per step, not per microbatch).
    seq_shard_residual: bool = True
    # Explicit Megatron-style tensor parallelism via shard_map for the
    # dense FFN, attention/SSD out-projections: the row-parallel partial
    # sums are cast to bf16 before the psum, halving the per-layer
    # activation all-reduce bytes that XLA's auto-SPMD reduces in f32
    # (§Perf iterations A1/A2 — now the default).
    tp_shard_map: bool = True

    def __post_init__(self):
        if self.n_heads and not self.d_head:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> list[str]:
        """Per-layer mixer kind: 'attn' or 'ssm'."""
        if self.layer_pattern == "attn":
            return ["attn"] * self.n_layers
        if self.layer_pattern == "ssm":
            return ["ssm"] * self.n_layers
        if self.layer_pattern == "jamba":
            kinds = []
            for i in range(self.n_layers):
                kinds.append("attn" if i % self.hybrid_group ==
                             self.hybrid_attn_index else "ssm")
            return kinds
        raise ValueError(self.layer_pattern)

    def ffn_kinds(self) -> list[str]:
        """Per-layer FFN kind: 'dense' | 'moe' | 'none'."""
        out = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                out.append("none")       # pure Mamba2: block = mixer only
            elif self.is_moe and i >= self.n_dense_layers \
                    and i % self.moe_every == (self.moe_every - 1):
                out.append("moe")
            elif self.d_ff > 0 or self.is_moe:
                out.append("dense")
            else:
                out.append("none")
        return out

    def param_count(self) -> int:
        """Total parameters (embedding + blocks + head), exact."""
        from .transformer import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from .transformer import count_params
        return count_params(self, active_only=True)

    def with_updates(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_training(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
