"""Model assembly: embeddings / modality stubs, attention + SSM + MoE
blocks, layer-stack scan (HLO stays compact for 512-way SPMD compiles on a
single host core), losses, and the three step kinds (train forward,
prefill, decode).

Layer stacking: the per-layer (mixer, ffn) plan is folded into its smallest
period p (dense: p=1; Jamba: p=8 = 7 Mamba + 1 attention with alternating
dense/MoE FFN); parameters are stacked over n_layers/p groups and the stack
runs under ``lax.scan`` with configurable remat.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import constrain, gather_params

from .attention import chunked_attention, decode_attention, rope
from .config import ModelConfig
from .moe import dense_ffn, dense_ffn_schema, moe_ffn, moe_schema
from .schema import PSpec, is_pspec, param_count
from .ssm import (ssd_decode_step, ssd_forward, ssm_cache_init, ssm_schema)

AUDIO_FRAME_DIM = 512  # conv-stem output dim of the stubbed HuBERT frontend


# ---------------------------------------------------------------------- #
# Schemas
# ---------------------------------------------------------------------- #

def attn_schema(cfg: ModelConfig) -> dict:
    d, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    sch = {
        "wq": PSpec((d, Hq * Dh), ("embed", "q_heads")),
        "wk": PSpec((d, Hkv * Dh), ("embed", "kv_heads")),
        "wv": PSpec((d, Hkv * Dh), ("embed", "kv_heads")),
        "wo": PSpec((Hq * Dh, d), ("q_heads", "embed")),
    }
    if cfg.qkv_bias:
        sch["bq"] = PSpec((Hq * Dh,), ("q_heads",), init="zeros")
        sch["bk"] = PSpec((Hkv * Dh,), ("kv_heads",), init="zeros")
        sch["bv"] = PSpec((Hkv * Dh,), ("kv_heads",), init="zeros")
    return sch


def block_schema(cfg: ModelConfig, kind: str, ffn_kind: str) -> dict:
    d = cfg.d_model
    sch: dict = {"norm1": PSpec((d,), ("embed",), init="ones")}
    if kind == "attn":
        sch["attn"] = attn_schema(cfg)
    else:
        sch["ssm"] = ssm_schema(cfg)
    if ffn_kind != "none":
        sch["norm2"] = PSpec((d,), ("embed",), init="ones")
        if ffn_kind == "moe":
            sch["ffn"] = moe_schema(cfg)
        else:
            sch["ffn"] = dense_ffn_schema(cfg)
    return sch


def layer_plan(cfg: ModelConfig):
    """(prefix_pairs, period_pairs, n_groups): prefix layers run unstacked,
    the periodic remainder is scanned in groups of len(period_pairs)."""
    pairs = list(zip(cfg.layer_kinds(), cfg.ffn_kinds()))
    prefix = pairs[:cfg.n_dense_layers]
    rest = pairs[cfg.n_dense_layers:]
    period = len(rest)
    for p in (1, 2, 4, 8, 16, 32):
        if p <= len(rest) and len(rest) % p == 0 and \
                all(rest[i] == rest[i % p] for i in range(len(rest))):
            period = p
            break
    return prefix, rest[:period], len(rest) // period


def _stack(schema, n: int):
    return jax.tree.map(
        lambda s: dataclasses.replace(
            s, shape=(n,) + s.shape, logical=("layers",) + s.logical),
        schema, is_leaf=is_pspec)


def model_schema(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    sch: dict = {}
    if cfg.modality == "audio":
        sch["frame_proj"] = PSpec((AUDIO_FRAME_DIM, d), (None, "embed"))
    else:
        sch["embed"] = PSpec((V, d), ("vocab", "embed"), scale=0.02)
    if cfg.modality == "vision":
        # anyres patch embeddings arrive at d_model; learned adapter
        sch["patch_adapter"] = PSpec((d, d), ("embed", None))
    prefix, period, n_groups = layer_plan(cfg)
    sch["prefix"] = [block_schema(cfg, k, f) for k, f in prefix]
    sch["stack"] = _stack([block_schema(cfg, k, f) for k, f in period],
                          n_groups)
    sch["final_norm"] = PSpec((d,), ("embed",), init="ones")
    sch["lm_head"] = PSpec((d, V), ("embed", "vocab"), scale=0.02)
    return sch


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    sch = model_schema(cfg)
    total = param_count(sch)
    if active_only and cfg.is_moe:
        # subtract inactive expert weights
        _, period, n_groups = layer_plan(cfg)
        moe_layers = sum(1 for _, f in period if f == "moe") * n_groups
        E, K = cfg.n_experts, cfg.top_k
        per_expert = cfg.d_model * cfg.d_ff_expert * (3 if cfg.glu else 2)
        total -= moe_layers * (E - K) * per_expert
    return total


# ---------------------------------------------------------------------- #
# Norms / embeddings
# ---------------------------------------------------------------------- #

def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def embed_inputs(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.modality == "audio":
        return batch["frames"].astype(jnp.bfloat16) @ params["frame_proj"]
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.modality == "vision" and "patches" in batch:
        adapted = batch["patches"].astype(x.dtype) @ params["patch_adapter"]
        x = lax.dynamic_update_slice(x, adapted, (0, 0, 0))
    return x


# ---------------------------------------------------------------------- #
# Blocks
# ---------------------------------------------------------------------- #

def _qkv(p: dict, h: jax.Array, cfg: ModelConfig):
    B, S, _ = h.shape
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = constrain(q.reshape(B, S, cfg.n_heads, cfg.d_head),
                  "batch", None, "model", None)
    k = constrain(k.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
                  "batch", None, "model", None)
    v = constrain(v.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
                  "batch", None, "model", None)
    return q, k, v


def _row_parallel_proj(o_flat: jax.Array, wo: jax.Array,
                       cfg: ModelConfig) -> jax.Array:
    """Attention out-projection; with tp_shard_map the heads-sharded
    activations hit a row-parallel matmul whose bf16 partials are psummed
    explicitly (halves the f32 all-reduce auto-SPMD emits)."""
    from repro.parallel.sharding import row_parallel_matmul
    return row_parallel_matmul(o_flat, wo, enabled=cfg.tp_shard_map)


def attention_block(p: dict, h: jax.Array, positions: jax.Array,
                    cfg: ModelConfig, *, want_cache: bool = False,
                    cache_window: int = 0):
    q, k, v = _qkv(p, h, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    window = cfg.window if cfg.attention == "swa" else 0
    if cfg.use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q, k, v, causal=cfg.causal, window=window,
                            softcap=cfg.attn_logit_softcap)
    else:
        o = chunked_attention(
            q, k, v, causal=cfg.causal, window=window,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            softcap=cfg.attn_logit_softcap,
            causal_skip=cfg.prefill_causal_skip)
    B, S = h.shape[:2]
    out = _row_parallel_proj(o.reshape(B, S, -1), p["wo"], cfg)
    cache = None
    if want_cache:
        if cache_window and cache_window < S:
            k, v = k[:, -cache_window:], v[:, -cache_window:]
        cache = {"k": k, "v": v}
    return out, cache


def attention_decode(p: dict, h: jax.Array, pos: jax.Array, cache: dict,
                     cfg: ModelConfig):
    """h: (B,1,d); cache k/v: (B,W,Hkv,Dh)."""
    q, k, v = _qkv(p, h, cfg)
    q = rope(q, pos[None, None], cfg.rope_theta)
    k = rope(k, pos[None, None], cfg.rope_theta)
    W = cache["k"].shape[1]
    ring = cfg.attention == "swa"
    idx = (pos % W) if ring else pos
    k_cache = lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
    v_cache = lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
    o = decode_attention(q, k_cache, v_cache, pos, ring=ring,
                         softcap=cfg.attn_logit_softcap)
    out = o.reshape(h.shape[0], 1, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


def _ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig, ffn_kind: str):
    if ffn_kind == "none":
        return x, 0.0
    h = rmsnorm(x, p["norm2"], cfg.norm_eps)
    if ffn_kind == "moe":
        B, S, d = h.shape
        out, aux = moe_ffn(p["ffn"], h.reshape(B * S, d), cfg)
        return x + out.reshape(B, S, d), aux
    return x + dense_ffn(p["ffn"], h, cfg), 0.0


def block_forward(p: dict, x: jax.Array, positions: jax.Array,
                  cfg: ModelConfig, kind: str, ffn_kind: str, *,
                  want_cache: bool = False, cache_window: int = 0):
    if cfg.seq_shard_residual:
        x = constrain(x, "batch", "model", None)
    else:
        x = constrain(x, "batch", None, None)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        out, cache = attention_block(p["attn"], h, positions, cfg,
                                     want_cache=want_cache,
                                     cache_window=cache_window)
    else:
        if want_cache:
            out, cache = ssd_forward(p["ssm"], h, cfg, return_state=True)
        else:
            out, cache = ssd_forward(p["ssm"], h, cfg), None
    x = x + out
    x, aux = _ffn_apply(p, x, cfg, ffn_kind)
    return x, cache, aux


def block_decode(p: dict, x: jax.Array, pos: jax.Array, cache: dict,
                 cfg: ModelConfig, kind: str, ffn_kind: str):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        out, new_cache = attention_decode(p["attn"], h, pos, cache, cfg)
    else:
        out, new_cache = ssd_decode_step(p["ssm"], h, cache, cfg)
    x = x + out
    x, _ = _ffn_apply(p, x, cfg, ffn_kind)
    return x, new_cache


# ---------------------------------------------------------------------- #
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------- #

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def forward(params: dict, batch: dict, cfg: ModelConfig, *,
            want_cache: bool = False, cache_window: int = 0,
            param_specs: dict | None = None):
    """Returns (hidden (B,S,d), caches, aux).  ``param_specs`` (a pytree
    of use-time PartitionSpecs) enables just-in-time FSDP weight
    gathering — one all-gather per layer inside the scan (ZeRO-3)."""
    sp = param_specs or {}
    top = {k: params[k] for k in params
           if k not in ("prefix", "stack") and k in sp}
    if top:
        gathered = gather_params(top, {k: sp[k] for k in top})
        params = {**params, **gathered}
    x = embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    prefix, period, n_groups = layer_plan(cfg)

    caches: dict = {"prefix": [], "stack": None}
    aux = jnp.zeros((), jnp.float32)
    for i, (p, (kind, fk)) in enumerate(zip(params["prefix"], prefix)):
        if sp:
            p = gather_params(p, sp["prefix"][i])
        x, c, a = block_forward(p, x, positions, cfg, kind, fk,
                                want_cache=want_cache,
                                cache_window=cache_window)
        caches["prefix"].append(c)
        aux = aux + a

    def group_body(carry, gp):
        x, aux = carry
        if sp:
            gp = gather_params(gp, sp["stack"])
        group_caches = []
        for j, (kind, fk) in enumerate(period):
            x, c, a = block_forward(gp[j], x, positions, cfg, kind, fk,
                                    want_cache=want_cache,
                                    cache_window=cache_window)
            group_caches.append(c)
            aux = aux + a
        return (x, aux), group_caches

    body = _remat(group_body, cfg)
    (x, aux), stack_caches = lax.scan(body, (x, aux), params["stack"])
    caches["stack"] = stack_caches
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, caches, aux


def chunked_ce_loss(x: jax.Array, head: jax.Array, labels: jax.Array,
                    chunk: int) -> jax.Array:
    """Cross-entropy without materialising (B,S,V): scan over seq chunks."""
    B, S, d = x.shape
    c = min(chunk, S)
    assert S % c == 0
    n = S // c
    xc = x.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, c).transpose(1, 0, 2)

    @jax.checkpoint  # recompute chunk logits in backward: (B,c,V) never saved
    def step_inner(xb, lb):
        logits = (xb @ head).astype(jnp.float32)          # (B,c,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def step(tot, inp):
        xb, lb = inp
        return tot + step_inner(xb, lb), None

    total, _ = lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)


def train_loss(params: dict, batch: dict, cfg: ModelConfig,
               param_specs: dict | None = None) -> jax.Array:
    x, _, aux = forward(params, batch, cfg, param_specs=param_specs)
    head = params["lm_head"]
    if param_specs:
        head = gather_params(head, param_specs["lm_head"])
    loss = chunked_ce_loss(x, head, batch["labels"], cfg.loss_chunk)
    return loss + 0.01 * aux


def prefill(params: dict, batch: dict, cfg: ModelConfig, *,
            cache_len: int = 0, param_specs: dict | None = None):
    """Run the prompt; return (last-token logits, caches)."""
    window = cfg.window if cfg.attention == "swa" else 0
    x, caches, _ = forward(params, batch, cfg, want_cache=True,
                           cache_window=window or cache_len,
                           param_specs=param_specs)
    logits = (x[:, -1:] @ params["lm_head"]).astype(jnp.float32)
    return logits, caches


def encode(params: dict, batch: dict, cfg: ModelConfig,
           param_specs: dict | None = None):
    """Encoder-only forward (hubert): per-position class logits."""
    x, _, _ = forward(params, batch, cfg, param_specs=param_specs)
    return (x @ params["lm_head"]).astype(jnp.float32)


# ---------------------------------------------------------------------- #
# Decode
# ---------------------------------------------------------------------- #

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree matching the layer plan.  Attention layers get
    (B, W, Hkv, Dh) k/v buffers (W = sliding window for SWA); SSM layers
    get their recurrent state."""
    prefix, period, n_groups = layer_plan(cfg)
    W = min(cfg.window, max_len) if cfg.attention == "swa" else max_len

    def one(kind):
        if kind == "attn":
            shape = (batch, W, cfg.n_kv_heads, cfg.d_head)
            return {"k": jnp.zeros(shape, jnp.bfloat16),
                    "v": jnp.zeros(shape, jnp.bfloat16)}
        return ssm_cache_init(cfg, batch)

    def stack_cache(kind):
        return jax.tree.map(
            lambda a: jnp.zeros((n_groups,) + a.shape, a.dtype), one(kind))

    return {
        "prefix": [one(k) for k, _ in prefix],
        "stack": [stack_cache(k) for k, _ in period],
    }


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_step(params: dict, token: jax.Array, pos: jax.Array,
                cache: dict, cfg: ModelConfig, patches=None,
                param_specs: dict | None = None):
    """token: (B,1) int32; pos: () int32.  Returns (logits, new cache)."""
    if cfg.modality == "audio":
        raise ValueError("encoder-only model has no decode step")
    sp = param_specs or {}
    x = jnp.take(params["embed"], token, axis=0)
    prefix, period, n_groups = layer_plan(cfg)

    new_prefix = []
    for i, (p, (kind, fk), c) in enumerate(zip(params["prefix"], prefix,
                                               cache["prefix"])):
        if sp:
            p = gather_params(p, sp["prefix"][i])
        x, nc = block_decode(p, x, pos, c, cfg, kind, fk)
        new_prefix.append(nc)

    def group_body(x, inp):
        gp, gcache = inp
        if sp:
            gp = gather_params(gp, sp["stack"])
        new_caches = []
        for j, (kind, fk) in enumerate(period):
            x, nc = block_decode(gp[j], x, pos, gcache[j], cfg, kind, fk)
            new_caches.append(nc)
        return x, new_caches

    x, new_stack = lax.scan(group_body, x,
                            (params["stack"], cache["stack"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, {"prefix": new_prefix, "stack": new_stack}
