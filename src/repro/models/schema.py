"""Parameter schema: single source of truth for shapes, dtypes, logical
sharding axes and initializers.

A schema is a pytree (nested dicts) of :class:`PSpec` leaves.  From it we
derive (a) real initialised parameters for smoke tests, (b)
ShapeDtypeStructs for the dry-run (no allocation), and (c) PartitionSpecs
via the logical-axis rules in ``repro.parallel.sharding``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]    # logical axis per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"               # normal | zeros | ones
    scale: float | None = None         # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape,
                                                      self.logical)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def init_params(schema, key: jax.Array):
    """Materialise real parameters (used with reduced configs on CPU)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, spec.dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            fan_in = spec.shape[-1] if len(spec.shape) else 1
            scale = spec.scale if spec.scale is not None \
                else 1.0 / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, spec.shape, jnp.float32)
                        * scale).astype(spec.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(schema):
    """ShapeDtypeStructs — the dry-run path; allocates nothing."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), schema,
        is_leaf=is_pspec)


def logical_axes(schema):
    """Pytree of logical-axis tuples, mirroring the params tree."""
    return jax.tree.map(lambda s: s.logical, schema, is_leaf=is_pspec)


def param_count(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_pspec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def map_schema(schema, fn):
    return jax.tree.map(fn, schema, is_leaf=is_pspec)
