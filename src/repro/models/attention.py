"""Attention: RoPE, GQA, chunked online-softmax (flash-style in XLA),
sliding-window, decode-with-cache.

The chunked path is the dry-run/roofline path: it never materialises the
(S x S) score matrix (inner/outer scans keep the live set to one
(chunk_q x chunk_kv) tile), which is what makes prefill_32k compile within
per-device memory.  The Pallas kernel in ``repro.kernels.flash_attention``
implements the same math for TPU; ``ref.py`` cross-checks both.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------- #
# RoPE
# ---------------------------------------------------------------------- #

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def _softcap(s: jax.Array, cap: float) -> jax.Array:
    if cap > 0.0:
        return cap * jnp.tanh(s / cap)
    return s


# ---------------------------------------------------------------------- #
# Chunked (online-softmax) attention — training & prefill
# ---------------------------------------------------------------------- #

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      chunk_q: int = 1024, chunk_kv: int = 1024,
                      softcap: float = 0.0,
                      causal_skip: bool = False) -> jax.Array:
    """q: (B,S,Hq,D)  k,v: (B,S,Hkv,D), Hq = G*Hkv.  Returns (B,S,Hq,D).

    ``causal_skip``: use a dynamic-bound ``fori_loop`` over kv chunks so
    strictly-upper-triangular chunk pairs are never computed (inference
    only — dynamic bounds are not reverse-mode differentiable).
    """
    B, S_orig, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    cq = min(chunk_q, S_orig)
    ckv = min(chunk_kv, S_orig)
    # pad to a chunk multiple; padded key positions are masked out below
    import math
    lcm = cq * ckv // math.gcd(cq, ckv)
    S = -(-S_orig // lcm) * lcm
    if S != S_orig:
        padding = ((0, 0), (0, S - S_orig), (0, 0), (0, 0))
        q = jnp.pad(q, padding)
        k = jnp.pad(k, padding)
        v = jnp.pad(v, padding)
    nq, nkv = S // cq, S // ckv
    scale = 1.0 / (D ** 0.5)
    valid_len = S_orig

    # (B,S,Hkv,G,D) -> chunked (nq,B,cq,Hkv,G,D)
    qc = q.reshape(B, nq, cq, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nkv, ckv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nkv, ckv, Hkv, D).transpose(1, 0, 2, 3, 4)

    def q_chunk_body(qi, q_blk):
        # online-softmax accumulators, fp32
        m0 = jnp.full((B, cq, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, cq, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, cq, Hkv, G, D), jnp.float32)
        qpos = qi * cq + jnp.arange(cq)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk, v_blk = kc[ki], vc[ki]
            kpos = ki * ckv + jnp.arange(ckv)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            s = _softcap(s, softcap)
            mask = (kpos < valid_len)[None, :] * jnp.ones((cq, 1), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        if causal and causal_skip:
            # dynamic upper bound: only chunk pairs with kpos <= max qpos
            hi = (qi * cq + cq + ckv - 1) // ckv
            def fori_body(ki, carry):
                c, _ = kv_step(carry, ki)
                return c
            m, l, acc = lax.fori_loop(0, hi, fori_body, (m0, l0, a0))
        else:
            lo = 0
            if window and not causal:
                lo = 0
            (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    out = lax.map(lambda args: q_chunk_body(*args),
                  (jnp.arange(nq), qc))
    # (nq,B,cq,Hkv,G,D) -> (B,S,Hq,D)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hq, D)
    return out[:, :S_orig] if S != S_orig else out


# ---------------------------------------------------------------------- #
# Decode attention (single new token against a KV cache)
# ---------------------------------------------------------------------- #

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, ring: bool = False,
                     softcap: float = 0.0) -> jax.Array:
    """q: (B,1,Hq,D); caches: (B,W,Hkv,D); pos: () current position.

    ``ring=True``: the cache is a sliding-window ring buffer — every slot
    with index < min(pos+1, W) is valid (softmax is permutation-
    invariant, so ring order is irrelevant).
    """
    B, W, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    idx = jnp.arange(W)
    valid = idx < jnp.minimum(pos + 1, W) if ring else idx <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p,
                     v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------- #
# Reference (materialises S x S — tests only)
# ---------------------------------------------------------------------- #

def reference_attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32))
    s = s / (D ** 0.5)
    s = _softcap(s, softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)
