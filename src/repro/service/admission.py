"""Per-tenant admission control: bounded queue depth + token buckets.

A long-lived prediction service must reject load it cannot carry
*explicitly* (an :class:`AdmissionError` the caller can back off on)
instead of letting queueing latency grow without bound.  Two mechanisms
compose, both checked at submit time before a request touches the
queue:

* **bounded depth** — a global in-flight ceiling plus a per-tenant
  ceiling (no tenant can occupy the whole queue);
* **token-bucket rate limit** — each tenant refills at
  ``rate_per_s`` tokens/s up to ``burst``; a submit spends one token.
  The bucket is the classic continuous-refill formulation, so a tenant
  may burst up to ``burst`` requests instantly and then sustain
  ``rate_per_s``.

Both are pure bookkeeping (no clocks of their own: the caller passes
``now``), which keeps them trivially testable and lets the service
drive them from the asyncio loop's monotonic clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class AdmissionError(Exception):
    """Request rejected at submit time (queue full or rate limited).

    Attributes:
        tenant: the tenant whose request was rejected.
        reason: ``"queue_depth"``, ``"tenant_depth"`` or ``"rate"``.
    """

    def __init__(self, tenant: str, reason: str, detail: str = ""):
        self.tenant = tenant
        self.reason = reason
        super().__init__(
            f"admission rejected for tenant {tenant!r}: {reason}"
            + (f" ({detail})" if detail else ""))


@dataclass(frozen=True)
class TenantPolicy:
    """Admission knobs for one tenant (or the default for all)."""

    max_in_flight: int = 64         # per-tenant queue-depth ceiling
    rate_per_s: float = float("inf")   # sustained token refill rate
    burst: float = 64.0             # bucket capacity (max burst size)
    # retry *budget*: how fast this tenant may consume dispatch
    # retries (a separate bucket from admission, spent by the
    # dispatcher, not at submit).  inf (default) = unlimited retries,
    # bit-identical to the pre-budget service
    retry_rate_per_s: float = float("inf")
    retry_burst: float = 16.0       # retry bucket capacity


@dataclass
class TokenBucket:
    """Continuous-refill token bucket; ``try_spend`` is O(1)."""

    rate_per_s: float
    burst: float
    tokens: float = field(default=-1.0)   # -1 = start full
    stamp: float = 0.0

    def try_spend(self, now: float, cost: float = 1.0) -> bool:
        if self.tokens < 0:
            self.tokens = self.burst
            self.stamp = now
        self.tokens = min(self.burst,
                          self.tokens + (now - self.stamp) *
                          self.rate_per_s)
        self.stamp = now
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False


class AdmissionController:
    """Tracks in-flight counts and per-tenant buckets.

    ``admit(tenant, now)`` either reserves a slot (the caller must later
    ``release(tenant)`` exactly once) or raises :class:`AdmissionError`.
    Not thread-safe by itself — the service calls it from one event
    loop; the synchronous bench path serializes through the loop too.
    """

    def __init__(self, max_queue_depth: int = 256,
                 default_policy: TenantPolicy | None = None,
                 per_tenant: dict[str, TenantPolicy] | None = None):
        self.max_queue_depth = max_queue_depth
        self.default_policy = default_policy or TenantPolicy()
        self.per_tenant = dict(per_tenant or {})
        self.in_flight: dict[str, int] = {}
        self._buckets: dict[str, TokenBucket] = {}
        self._retry_buckets: dict[str, TokenBucket] = {}

    def policy(self, tenant: str) -> TenantPolicy:
        return self.per_tenant.get(tenant, self.default_policy)

    @property
    def total_in_flight(self) -> int:
        return sum(self.in_flight.values())

    def admit(self, tenant: str, now: float) -> None:
        pol = self.policy(tenant)
        mine = self.in_flight.get(tenant, 0)
        if self.total_in_flight >= self.max_queue_depth:
            raise AdmissionError(tenant, "queue_depth",
                                 f"global depth {self.max_queue_depth}")
        if mine >= pol.max_in_flight:
            raise AdmissionError(tenant, "tenant_depth",
                                 f"tenant depth {pol.max_in_flight}")
        bucket = self._buckets.get(tenant)
        if bucket is None:
            bucket = TokenBucket(pol.rate_per_s, pol.burst)
            self._buckets[tenant] = bucket
        if not bucket.try_spend(now):
            raise AdmissionError(tenant, "rate",
                                 f"{pol.rate_per_s}/s burst {pol.burst}")
        self.in_flight[tenant] = mine + 1

    def try_retry(self, tenant: str, now: float) -> bool:
        """Spend one token from the tenant's *retry* budget.

        Unlike :meth:`admit` this never raises — the dispatcher fails
        the affected requests fast with an explicit reason instead
        (docs/robustness.md#retry-budgets).  The default policy
        (``retry_rate_per_s=inf``) always grants, which keeps the
        budget-off service bit-identical to PR 9."""
        pol = self.policy(tenant)
        if pol.retry_rate_per_s == float("inf"):
            return True     # unlimited: skip the bucket (inf * 0 = nan)
        bucket = self._retry_buckets.get(tenant)
        if bucket is None:
            bucket = TokenBucket(pol.retry_rate_per_s, pol.retry_burst)
            self._retry_buckets[tenant] = bucket
        return bucket.try_spend(now)

    def release(self, tenant: str) -> None:
        n = self.in_flight.get(tenant, 0)
        if n <= 1:
            self.in_flight.pop(tenant, None)
        else:
            self.in_flight[tenant] = n - 1
