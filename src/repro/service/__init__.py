"""repro.service — persistent multi-tenant prediction service.

A long-lived async front over the in-process prediction engine
(:class:`repro.core.engine.AnalysisService`): request queue with
per-tenant admission control, cohort/batch formation by
(machine digest x mode x backend), a TTL/size-bounded cross-request
result cache, JSON observability, and an analytic SLO self-model that
predicts the service's own p50/p99 latency with busy-period analysis.
See docs/serving-service.md.
"""
from __future__ import annotations

from .admission import AdmissionController, AdmissionError, TenantPolicy
from .cache import TTLCache
from .cohort import cohort_key, form_cohorts, is_partition
from .request import (DeadlineExceeded, DispatchError, HloRequest,
                      ServiceClosed, ServiceRequest, ServiceResponse)
from .service import PredictionService, ServiceConfig, replay
from .slo import (FlowSpec, SloModel, SloPrediction,
                  busy_period_response, mixture_quantile)
from .telemetry import LatencyHistogram, Telemetry

__all__ = [
    "AdmissionController", "AdmissionError", "DeadlineExceeded",
    "DispatchError", "FlowSpec", "HloRequest", "LatencyHistogram",
    "PredictionService", "ServiceClosed", "ServiceConfig",
    "ServiceRequest", "ServiceResponse", "SloModel", "SloPrediction",
    "TTLCache", "Telemetry", "TenantPolicy", "busy_period_response",
    "cohort_key", "form_cohorts", "is_partition", "mixture_quantile",
    "replay",
]
