"""Analytic SLO self-model: predict the predictor's own latency.

The repo's prediction engine composes analytic bounds (port bound, LCD
chain, ECM transfer terms) into a single headline number.  This module
applies the same discipline to the *service wrapped around it*: from
three inputs — per-class arrival rate, the batching window, and the
measured per-dispatch cost — it predicts the p50/p99 response time a
tenant will observe, using classic busy-period / response-time
analysis for interfering flows (the holistic-analysis formulation;
see PAPERS.md / ROADMAP for the lineage).

Model
-----
Each cohort class ``j`` (one ``(kind, machine, mode, backend)`` key)
is a *flow*: a dispatch of cost ``C_j`` released every ``T_j`` seconds
(``T_j`` = elapsed / dispatches, i.e. the batch-former's actual
release period, never below the batching window ``W``).  All flows
share one dispatch executor, so a dispatch of class ``i`` can be
delayed by the busy period of every other flow:

* **busy period** (Eq. 6 style):
  ``w = C_i + sum_j ceil((w + J_j) / T_j) * C_j``, iterated to a fixed
  point;
* **worst response** (Eq. 7/8 style): over the ``q``-th release inside
  the busy period, ``R_i = max_q (v_q + C_i - q * T_i)`` with
  ``v_q = q * C_i + interference(v_q)``.

A request of class ``j`` then sees ``window wait + response``: the
window wait is uniform on ``[0, W]`` (Poisson-ish arrivals within one
batching window) and the response lies in ``[C_j, R_j]``, so its
latency is modeled uniform on ``[C_j, R_j + W]``.  Overall service
percentiles are the quantiles of the share-weighted *mixture* of those
per-class distributions (solved by bisection on the piecewise-linear
CDF).  Validation: ``benchmarks/service_bench.py`` replays mixed
traffic and records measured vs. predicted percentiles into
``BENCH_service.json``; CI gates the p99 prediction to within 50% of
measurement.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Iterable, Mapping, Sequence


@dataclass(frozen=True)
class FlowSpec:
    """One cohort class as a periodic interfering flow."""

    name: str
    cost_s: float       # C: mean dispatch cost
    period_s: float     # T: mean inter-dispatch interval
    share: float = 0.0  # fraction of requests belonging to this class
    jitter_s: float = 0.0
    # tail cost: the dispatch cost a *tail* request rides (defaults to
    # the mean).  The service's warm dispatches are answered from the
    # engine's memo caches at near-zero cost, which drags the mean
    # down; the requests that define p99 ride cold dispatches, so the
    # response-time recursion charges this cost for the flow's own
    # dispatch while interference and utilization stay mean-based
    # (mean x rate = the actual work the flow injects).
    tail_cost_s: float | None = None

    @property
    def tail_cost(self) -> float:
        return self.tail_cost_s if self.tail_cost_s is not None \
            else self.cost_s

    @property
    def utilization(self) -> float:
        return self.cost_s / self.period_s if self.period_s > 0 else \
            float("inf")


def busy_period_response(flow: FlowSpec,
                         interfering: Sequence[FlowSpec],
                         max_iter: int = 10_000) -> float:
    """Worst-case response time of one flow under interference.

    Returns ``inf`` when the flow set is unstable (total utilization
    >= 1) or the iteration fails to converge within ``max_iter``.
    """
    total_util = flow.utilization + sum(f.utilization
                                        for f in interfering)
    if total_util >= 1.0:
        return float("inf")

    def interference(horizon: float) -> float:
        return sum(math.ceil((horizon + f.jitter_s) / f.period_s)
                   * f.cost_s for f in interfering)

    # busy period w (fixed point, monotone increasing => converges
    # under util < 1); the flow's own dispatch is charged at its tail
    # cost (see FlowSpec.tail_cost_s)
    own = flow.tail_cost
    w = own
    for _ in range(max_iter):
        w_new = own + interference(w)
        if abs(w_new - w) <= 1e-12:
            break
        w = w_new
    else:
        return float("inf")

    n_releases = max(1, math.ceil((w + flow.jitter_s) / flow.period_s))
    r_max = 0.0
    for q in range(n_releases):
        v = q * own
        for _ in range(max_iter):
            v_new = q * own + interference(v)
            if abs(v_new - v) <= 1e-12:
                break
            v = v_new
        else:
            return float("inf")
        r_max = max(r_max, v + own - q * flow.period_s)
    return r_max


def mixture_quantile(classes: Sequence[tuple[float, float, float]],
                     q: float, iters: int = 80) -> float:
    """Quantile of a mixture of uniforms ``[(share, lo, hi), ...]``.

    The mixture CDF is piecewise linear and monotone; bisection over
    ``[min lo, max hi]`` converges geometrically.
    """
    live = [(s, lo, max(hi, lo)) for s, lo, hi in classes if s > 0]
    if not live:
        return 0.0
    total = sum(s for s, _, _ in live)
    a = min(lo for _, lo, _ in live)
    b = max(hi for _, _, hi in live)
    if b <= a:
        return a

    def cdf(x: float) -> float:
        acc = 0.0
        for s, lo, hi in live:
            if x >= hi:
                acc += s
            elif x > lo:
                acc += s * (x - lo) / (hi - lo)
        return acc / total

    lo_x, hi_x = a, b
    for _ in range(iters):
        mid = 0.5 * (lo_x + hi_x)
        if cdf(mid) < q:
            lo_x = mid
        else:
            hi_x = mid
    return 0.5 * (lo_x + hi_x)


@dataclass
class SloPrediction:
    """Predicted service percentiles plus the per-class breakdown."""

    p50_s: float
    p99_s: float
    utilization: float
    per_class: dict[str, dict[str, float]]

    def as_dict(self) -> dict[str, Any]:
        return {"p50_s": self.p50_s, "p99_s": self.p99_s,
                "utilization": self.utilization,
                "per_class": self.per_class}


class SloModel:
    """Busy-period latency model of one :class:`PredictionService`."""

    def __init__(self, window_s: float, flows: Iterable[FlowSpec]):
        self.window_s = window_s
        self.flows = list(flows)

    @classmethod
    def from_telemetry(cls, export: Mapping[str, Any],
                       window_s: float) -> "SloModel":
        """Build the flow set from a ``Telemetry.export()`` dict.

        ``C_j`` is the measured mean dispatch cost of class ``j``
        (mean x release rate = the work the flow actually injects, so
        utilization and interference stay consistent) and its tail
        cost is the p90 (warm engine-cached dispatches cost near zero
        and would dilute the cold-dispatch cost that governs p99);
        ``T_j`` is the observed release period (elapsed time over
        dispatch count), floored at the batching window — the service
        cannot release one class faster than it forms cohorts.
        """
        elapsed = float(export.get("elapsed_s") or 0.0)
        classes = export.get("cohort_classes", {})
        total_requests = sum(int(c.get("requests", 0))
                             for c in classes.values()) or 1
        flows = []
        for name, c in classes.items():
            dispatches = int(c.get("dispatches", 0))
            if dispatches <= 0:
                continue
            cost = float(c["cost"]["mean_s"])
            tail = float(c["cost"].get("p90_s") or cost)
            period = max(window_s, elapsed / dispatches) \
                if elapsed > 0 else max(window_s, cost)
            # jitter of one batching window: cohorts formed by the same
            # drain cycle release *simultaneously*, so an interfering
            # flow must count at least one release at t=0 — exactly
            # what a release jitter >= its phase slack encodes in the
            # holistic formulation
            flows.append(FlowSpec(
                name=name, cost_s=cost, period_s=max(period, 1e-9),
                share=int(c.get("requests", 0)) / total_requests,
                jitter_s=window_s, tail_cost_s=max(tail, cost)))
        return cls(window_s=window_s, flows=flows)

    def predict(self) -> SloPrediction:
        per_class: dict[str, dict[str, float]] = {}
        mixture: list[tuple[float, float, float]] = []
        for flow in self.flows:
            others = [f for f in self.flows if f is not flow]
            resp = busy_period_response(flow, others)
            lo = flow.cost_s
            hi = (resp if math.isfinite(resp) else flow.cost_s) \
                + self.window_s
            per_class[flow.name] = {
                "cost_s": flow.cost_s, "period_s": flow.period_s,
                "share": flow.share, "response_s": resp,
                "p50_s": lo + 0.5 * (hi - lo),
                "p99_s": lo + 0.99 * (hi - lo),
            }
            mixture.append((flow.share, lo, hi))
        util = sum(f.utilization for f in self.flows)
        return SloPrediction(
            p50_s=mixture_quantile(mixture, 0.50),
            p99_s=mixture_quantile(mixture, 0.99),
            utilization=util, per_class=per_class)
