"""Cohort formation: coalesce compatible in-flight requests.

The batch engine (``AnalysisService.predict_batch`` /
``simulate_many``) amortizes compilation and dispatch overhead only
when every request in a batch shares the same machine model, mode and
batch-simulation backend — the planner groups by machine internally,
but mixing modes or backends would force it back onto per-point paths.
The cohort former therefore *partitions* the in-flight set by

    ``(kind, machine digest, mode, backend [, HLO pricing knobs])``

and dispatches each cohort as one batched engine call.  Partitioning
(every request in exactly one cohort, no cohort mixing keys) is the
correctness property ``tests/test_service_cohorts.py`` locks with
hypothesis; bit-identical results vs per-request ``predict`` follow
from the engine's own batch/single parity.

The functions here are pure (no clocks, no I/O): the service hands
them its drained queue, the tests hand them synthetic request lists.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Iterable, Sequence

from .request import ServiceRequest

if TYPE_CHECKING:                       # pragma: no cover
    from repro.core.engine import AnalysisService


def cohort_key(engine: "AnalysisService", req: ServiceRequest) -> tuple:
    """The compatibility class of one request.

    x86 requests batch when they agree on (machine digest, mode,
    backend); HLO requests additionally carry their pricing knobs
    (``ici_links``/``flop_dtype``/``working_set``) because
    ``predict_hlo_batch`` applies them batch-wide.  The machine model
    resolves through the engine's memoized ``resolve_machine``, so key
    computation is cheap after the first request per arch.
    """
    if req.analysis is not None:
        a = req.analysis
        digest = engine.resolve_machine(a.arch).digest
        return ("x86", digest, a.mode, req.backend)
    h = req.hlo
    digest = engine.resolve_machine(h.machine).digest
    return ("hlo", digest, h.mode, req.backend,
            h.ici_links, h.flop_dtype, h.working_set)


def form_cohorts(engine: "AnalysisService",
                 requests: Sequence[ServiceRequest],
                 max_cohort: int | None = None,
                 ) -> list[tuple[tuple, list[int]]]:
    """Partition ``requests`` into dispatch cohorts.

    Returns ``[(key, indices), ...]`` in first-seen order; ``indices``
    index into ``requests`` and preserve arrival order within a cohort
    (the engine planner dedupes identical cells itself, so duplicates
    stay in the cohort).  ``max_cohort`` splits oversized cohorts so a
    tenant flooding one key cannot make a single dispatch arbitrarily
    large (and arbitrarily late for everyone in it).
    """
    buckets: dict[tuple, list[int]] = {}
    order: list[tuple] = []
    for i, req in enumerate(requests):
        key = cohort_key(engine, req)
        if key not in buckets:
            buckets[key] = []
            order.append(key)
        buckets[key].append(i)
    out: list[tuple[tuple, list[int]]] = []
    for key in order:
        idxs = buckets[key]
        if max_cohort is None or len(idxs) <= max_cohort:
            out.append((key, idxs))
        else:
            for lo in range(0, len(idxs), max_cohort):
                out.append((key, idxs[lo:lo + max_cohort]))
    return out


def is_partition(cohorts: Iterable[tuple[tuple, list[int]]],
                 n_requests: int) -> bool:
    """True when the cohorts cover each request index exactly once."""
    seen: list[int] = []
    for _, idxs in cohorts:
        seen.extend(idxs)
    return sorted(seen) == list(range(n_requests))
