"""Service observability: histograms, counters, traces — exported as JSON.

Everything the SLO self-model (``repro.service.slo``) and the load
harness (``benchmarks/service_bench.py``) consume comes from here:

* :class:`LatencyHistogram` — log-spaced fixed buckets (counting, not
  sampling: thousands of requests cost a few hundred ints) with exact
  ``count``/``sum`` and interpolated percentiles;
* :class:`Telemetry` — per-stage latency histograms (queue wait,
  dispatch, end-to-end), queue-depth and batch-size distributions,
  per-tenant counters, per-cohort-class dispatch accounting (the SLO
  model's flow inputs), and a bounded ring of structured trace events.

``export()`` returns one plain-JSON dict; nothing here imports the
engine, so the module stays importable in minimal environments.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any


class LatencyHistogram:
    """Fixed log-spaced buckets from ``lo_s`` to ``hi_s``.

    Percentiles interpolate within the matched bucket (log-linear), so
    p99 error is bounded by the bucket ratio (default ~7% per decade
    with 36 buckets over 9 decades) — tight enough for an SLO gate at
    +/-50%.
    """

    def __init__(self, lo_s: float = 1e-6, hi_s: float = 1e3,
                 buckets_per_decade: int = 4):
        self.lo_s = lo_s
        self.hi_s = hi_s
        decades = math.log10(hi_s / lo_s)
        self.n = max(1, int(round(decades * buckets_per_decade)))
        self.ratio = (hi_s / lo_s) ** (1.0 / self.n)
        self.counts = [0] * (self.n + 2)    # +underflow +overflow
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def _index(self, v: float) -> int:
        if v < self.lo_s:
            return 0
        if v >= self.hi_s:
            return self.n + 1
        return 1 + int(math.log(v / self.lo_s) / math.log(self.ratio))

    def observe(self, v: float) -> None:
        self.counts[self._index(v)] += 1
        self.count += 1
        self.sum += v
        self.max = max(self.max, v)

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Interpolated quantile ``q`` in [0, 1]; 0.0 when empty."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= target:
                frac = (target - acc) / c
                if i == 0:
                    return self.lo_s * frac
                if i == self.n + 1:
                    return self.max
                lo = self.lo_s * self.ratio ** (i - 1)
                hi = min(lo * self.ratio, self.max if self.max else
                         lo * self.ratio)
                return lo + (hi - lo) * frac
            acc += c
        return self.max

    def as_dict(self) -> dict[str, float]:
        return {"count": self.count, "mean_s": self.mean(),
                "p50_s": self.percentile(0.50),
                "p90_s": self.percentile(0.90),
                "p99_s": self.percentile(0.99),
                "max_s": self.max}


@dataclass
class TenantCounters:
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0            # AdmissionError at submit
    completed: int = 0
    failed: int = 0              # dispatch errors after retries
    deadline_exceeded: int = 0
    cancelled: int = 0
    cache_hits: int = 0          # served from the cross-request cache
    retry_budget_exhausted: int = 0   # failed fast: no retry budget left

    def as_dict(self) -> dict[str, int]:
        return dict(vars(self))


@dataclass
class CohortClassStats:
    """Per cohort-class dispatch accounting — the SLO model's flows."""

    dispatches: int = 0
    requests: int = 0
    retries: int = 0
    routed: int = 0              # cohorts started below the requested
    #                              rung by the pre-dispatch consult
    hedges: int = 0              # hedged (duplicate) dispatches issued
    hedge_wins: int = 0          # hedges that answered before primary
    cost: LatencyHistogram = field(default_factory=LatencyHistogram)

    def as_dict(self) -> dict[str, Any]:
        return {"dispatches": self.dispatches,
                "requests": self.requests, "retries": self.retries,
                "routed": self.routed, "hedges": self.hedges,
                "hedge_wins": self.hedge_wins,
                "cost": self.cost.as_dict()}


class Telemetry:
    """All measured state of one :class:`PredictionService`."""

    def __init__(self, trace_capacity: int = 512):
        self.queue_wait = LatencyHistogram()
        self.dispatch = LatencyHistogram()
        self.total = LatencyHistogram()
        self.retry_sleep = LatencyHistogram()   # governed backoff sleeps
        self.batch_size = LatencyHistogram(lo_s=1.0, hi_s=4096.0,
                                           buckets_per_decade=8)
        self.queue_depth = LatencyHistogram(lo_s=1.0, hi_s=65536.0,
                                            buckets_per_decade=8)
        self.tenants: dict[str, TenantCounters] = {}
        self.cohort_classes: dict[str, CohortClassStats] = {}
        self.engine_dispatches = 0       # compiled/tick dispatches issued
        self.traces: deque[dict] = deque(maxlen=trace_capacity)
        self.started_at: float | None = None
        self.stopped_at: float | None = None

    def tenant(self, name: str) -> TenantCounters:
        tc = self.tenants.get(name)
        if tc is None:
            tc = self.tenants[name] = TenantCounters()
        return tc

    def cohort_class(self, key: tuple | str) -> CohortClassStats:
        name = key if isinstance(key, str) else class_name(key)
        cc = self.cohort_classes.get(name)
        if cc is None:
            cc = self.cohort_classes[name] = CohortClassStats()
        return cc

    def trace(self, event: str, **fields: Any) -> None:
        self.traces.append({"event": event, **fields})

    def elapsed_s(self, now: float | None = None) -> float:
        if self.started_at is None:
            return 0.0
        end = self.stopped_at if self.stopped_at is not None else now
        return max(0.0, (end or self.started_at) - self.started_at)

    def export(self, now: float | None = None) -> dict[str, Any]:
        """One JSON-serializable dict with every counter/histogram."""
        return {
            "elapsed_s": self.elapsed_s(now),
            "stages": {"queue_wait": self.queue_wait.as_dict(),
                       "dispatch": self.dispatch.as_dict(),
                       "retry_sleep": self.retry_sleep.as_dict(),
                       "total": self.total.as_dict()},
            "batch_size": self.batch_size.as_dict(),
            "queue_depth": self.queue_depth.as_dict(),
            "engine_dispatches": self.engine_dispatches,
            "tenants": {t: c.as_dict()
                        for t, c in sorted(self.tenants.items())},
            "cohort_classes": {n: c.as_dict() for n, c in
                               sorted(self.cohort_classes.items())},
            "traces": list(self.traces),
        }


def class_name(key: tuple) -> str:
    """Human-readable cohort-class label: ``kind/digest8/mode/backend``."""
    kind, digest, mode, backend = key[0], key[1], key[2], key[3]
    return f"{kind}/{str(digest)[:8]}/{mode}/{backend or 'auto'}"
