"""The persistent multi-tenant prediction service.

:class:`PredictionService` is a long-lived asyncio front over the
in-process :class:`~repro.core.engine.AnalysisService` planner:

    submit -> admission control -> request queue -> cohort former
           -> batched engine dispatch -> response (+ telemetry)

* **Admission** (``repro.service.admission``): bounded global and
  per-tenant queue depth plus token-bucket rate limits; rejected
  submits raise :class:`AdmissionError` immediately instead of queueing
  unboundedly.
* **Batching** (``repro.service.cohort``): the dispatcher drains the
  queue after a tunable ``batch_window_s``, partitions the in-flight
  set by ``(kind, machine digest, mode, backend)`` and issues *one*
  ``predict_batch`` / ``predict_hlo_batch`` per cohort — the grouped
  planner then turns a cohort into a handful of compiled dispatches.
* **Cross-request cache** (``repro.service.cache``): responses are
  kept in a TTL+size-bounded cache keyed by the same content digests
  the engine memoizes on, shared across tenants; hits return at submit
  time without touching the queue.
* **Robustness**: per-request deadlines (submit-relative,
  propagated to the dispatcher which skips expired work), per-dispatch
  timeout with *governed* retries — capped full-jitter backoff from a
  seeded RNG, per-tenant retry budgets (exhausted budget fails fast
  with an explicit reason), sleeps clamped to the tightest remaining
  request deadline — optional hedged dispatch for straggler cohorts
  (docs/robustness.md#retry-budgets), a pre-dispatch
  :class:`~repro.core.degrade.HealthRouter` consult when the engine
  carries one (docs/robustness.md#health-aware-routing), and a
  documented cancellation path (cancel the task awaiting
  :meth:`submit`; the dispatcher notices and drops the request from
  its cohort).
* **Observability** (``repro.service.telemetry``): per-stage latency
  histograms, queue-depth/batch-size distributions, per-tenant and
  per-cohort-class counters, trace events — ``export_stats()`` returns
  one JSON dict, which also feeds the analytic SLO self-model
  (``repro.service.slo``).

See docs/serving-service.md for the worked example and
``benchmarks/service_bench.py`` for the load-generation harness.
"""
from __future__ import annotations

import asyncio
import hashlib
import random
from dataclasses import dataclass, field as dc_field
from typing import Any, Sequence

from repro.core.degrade import LADDER, ladder_from
from repro.core.engine import AnalysisService

from .admission import AdmissionController, AdmissionError, TenantPolicy
from .cache import TTLCache
from .cohort import form_cohorts
from .request import (DeadlineExceeded, DispatchError, HloRequest,
                      ServiceClosed, ServiceRequest, ServiceResponse)
from .slo import SloModel, SloPrediction
from .telemetry import Telemetry, class_name


@dataclass
class ServiceConfig:
    """Tunables of one :class:`PredictionService` (see
    docs/serving-service.md#admission-control-knobs)."""

    batch_window_s: float = 0.002       # cohort formation window
    max_queue_depth: int = 256          # global in-flight ceiling
    default_policy: TenantPolicy = dc_field(default_factory=TenantPolicy)
    tenant_policies: dict[str, TenantPolicy] = dc_field(
        default_factory=dict)
    default_timeout_s: float = 60.0     # per-request deadline
    dispatch_timeout_s: float = 60.0    # one engine dispatch attempt
    max_retries: int = 1                # extra dispatch attempts
    retry_backoff_s: float = 0.05       # backoff base, doubled per retry
    retry_backoff_cap_s: float = 1.0    # backoff ceiling (the doubling
    #                                     can never sleep longer)
    retry_seed: int = 0                 # full-jitter RNG seed: replays
    #                                     are deterministic
    hedge: bool = False                 # hedged dispatch: after the
    #                                     hedge delay, race the next
    #                                     ladder rung against a
    #                                     straggling primary dispatch
    hedge_delay_s: float | None = None  # None = p99 of the measured
    #                                     dispatch histogram
    max_cohort: int = 1024              # split larger cohorts
    cache_entries: int = 4096           # cross-request cache size bound
    cache_ttl_s: float = float("inf")   # cross-request cache TTL
    backend: str | None = None          # default sim batch driver


class PredictionService:
    """Async, batching, caching, admission-controlled prediction front.

    One instance wraps one :class:`AnalysisService` (its planner and
    memo caches are shared by every tenant).  Lifecycle::

        service = PredictionService()
        await service.start()
        resp = await service.submit(ServiceRequest(analysis=req,
                                                   tenant="alice"))
        await service.stop()

    or synchronously via :func:`replay`.  ``submit`` raises
    :class:`AdmissionError` / :class:`ServiceClosed` at submit time;
    every other failure (deadline, dispatch error) comes back *inside*
    the :class:`ServiceResponse` so telemetry and partial batches stay
    consistent.
    """

    _STOP = object()

    def __init__(self, engine: AnalysisService | None = None,
                 config: ServiceConfig | None = None):
        self.engine = engine or AnalysisService()
        self.config = config or ServiceConfig()
        self.admission = AdmissionController(
            max_queue_depth=self.config.max_queue_depth,
            default_policy=self.config.default_policy,
            per_tenant=self.config.tenant_policies)
        # the engine's fault injector (when armed) also covers the
        # cross-request cache, so one FaultPlan exercises the whole stack
        self.cache = TTLCache(max_entries=self.config.cache_entries,
                              ttl_s=self.config.cache_ttl_s,
                              faults=self.engine.faults)
        self.telemetry = Telemetry()
        # full-jitter backoff RNG: seeded so a replayed fault schedule
        # produces the same retry timing (docs/robustness.md)
        self._retry_rng = random.Random(self.config.retry_seed)
        self._queue: asyncio.Queue | None = None
        self._dispatcher: asyncio.Task | None = None
        self._closed = True
        # registry epoch at the last cache fill: a machine-model
        # re-registration invalidates every cross-request entry (they
        # key on digests of models that may no longer be resolvable)
        self._registry_epoch = self.engine.registry.epoch

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> None:
        """Start the dispatcher; idempotent while running."""
        if self._dispatcher is not None and not self._dispatcher.done():
            return
        self._queue = asyncio.Queue()
        self._closed = False
        loop = asyncio.get_running_loop()
        if self.telemetry.started_at is None:
            self.telemetry.started_at = loop.time()
        self._dispatcher = asyncio.create_task(self._run())

    async def stop(self, drain: bool = True) -> None:
        """Stop accepting work; ``drain=True`` (default) serves every
        already-queued request first, ``False`` fails them with
        :class:`ServiceClosed`."""
        if self._closed and self._dispatcher is None:
            return
        self._closed = True
        if self._queue is not None:
            self._queue.put_nowait(self._STOP)
        if self._dispatcher is not None:
            if not drain:
                self._dispatcher.cancel()
            try:
                await self._dispatcher
            except asyncio.CancelledError:
                pass
            self._dispatcher = None
        if not drain and self._queue is not None:
            while not self._queue.empty():
                item = self._queue.get_nowait()
                if item is not self._STOP:
                    self._finalize_error(item, ServiceClosed("stopped"))
        self.telemetry.stopped_at = asyncio.get_running_loop().time()

    # ------------------------------------------------------------------
    # submit
    # ------------------------------------------------------------------
    def _cache_key(self, sreq: ServiceRequest) -> tuple:
        if sreq.analysis is not None:
            return ("x86", self.engine.request_key(sreq.analysis),
                    sreq.backend)
        h = sreq.hlo
        digest = hashlib.sha256(h.text.encode()).hexdigest()
        machine = self.engine.resolve_machine(h.machine)
        return ("hlo", machine.digest, digest, h.mode, h.ici_links,
                h.flop_dtype, h.working_set)

    async def submit(self, sreq: ServiceRequest) -> ServiceResponse:
        """Admit, enqueue and await one request.

        Cache hits return immediately (no admission cost — the cached
        answer consumes no queue capacity).  Cancellation: cancelling
        the task awaiting ``submit`` abandons the request; the
        dispatcher drops it from its cohort (counted per tenant as
        ``cancelled``) and its admission slot is released when the
        cohort containing it is finalized.
        """
        loop = asyncio.get_running_loop()
        now = loop.time()
        tc = self.telemetry.tenant(sreq.tenant)
        tc.submitted += 1
        if self._closed or self._queue is None:
            raise ServiceClosed("service not started or stopped")
        epoch = self.engine.registry.epoch
        if epoch != self._registry_epoch:
            self._registry_epoch = epoch
            self.cache.clear()
            self.telemetry.trace("cache_invalidated", epoch=epoch)
        key = self._cache_key(sreq)
        hit = self.cache.get(key, now)
        if hit is not None:
            tc.cache_hits += 1
            tc.completed += 1
            dt = loop.time() - now
            self.telemetry.total.observe(dt)
            return ServiceResponse(request=sreq, result=hit,
                                   cache_hit=True, total_s=dt,
                                   **ServiceResponse.provenance_of(hit))
        try:
            self.admission.admit(sreq.tenant, now)
        except AdmissionError:
            tc.rejected += 1
            self.telemetry.trace("rejected", tenant=sreq.tenant,
                                 tag=sreq.tag)
            raise
        tc.admitted += 1
        timeout = sreq.timeout_s if sreq.timeout_s is not None \
            else self.config.default_timeout_s
        pending = _Pending(request=sreq, future=loop.create_future(),
                           cache_key=key, t_submit=now,
                           deadline=now + timeout)
        self._queue.put_nowait(pending)
        try:
            return await asyncio.wait_for(
                asyncio.shield(pending.future), timeout)
        except asyncio.TimeoutError:
            pending.abandoned = True
            tc.deadline_exceeded += 1
            return ServiceResponse(
                request=sreq, error=DeadlineExceeded(
                    f"timeout {timeout}s elapsed in queue/dispatch"),
                total_s=loop.time() - now)
        except asyncio.CancelledError:
            pending.abandoned = True
            tc.cancelled += 1
            raise

    # ------------------------------------------------------------------
    # dispatcher
    # ------------------------------------------------------------------
    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        stop = False
        while not stop:
            item = await self._queue.get()
            if item is self._STOP:
                break
            batch = [item]
            if self.config.batch_window_s > 0:
                await asyncio.sleep(self.config.batch_window_s)
            while True:
                try:
                    nxt = self._queue.get_nowait()
                except asyncio.QueueEmpty:
                    break
                if nxt is self._STOP:
                    stop = True
                    break
                batch.append(nxt)
            self.telemetry.queue_depth.observe(float(len(batch)))
            t_form = loop.time()
            cohorts = form_cohorts(
                self.engine, [p.request for p in batch],
                max_cohort=self.config.max_cohort)
            self.telemetry.trace(
                "batch_formed", requests=len(batch),
                cohorts=len(cohorts))
            for key, idxs in cohorts:
                await self._dispatch_cohort(
                    key, [batch[i] for i in idxs], t_form)
            self.cache.purge(loop.time())

    def _finalize_error(self, pending: "_Pending",
                        err: BaseException) -> None:
        tc = self.telemetry.tenant(pending.request.tenant)
        if isinstance(err, DeadlineExceeded):
            tc.deadline_exceeded += 1
        else:
            tc.failed += 1
        self.admission.release(pending.request.tenant)
        if not pending.future.done():
            pending.future.set_result(ServiceResponse(
                request=pending.request, error=err))

    def _engine_dispatch_fn(self, key: tuple,
                            sreqs: list[ServiceRequest],
                            backend: str | None = None):
        """The blocking engine call for one cohort (runs on the
        default executor).  ``backend`` overrides the cohort's batch
        driver — the routing consult and hedged dispatch use it."""
        if key[0] == "x86":
            backend = backend or key[3] or self.config.backend
            reqs = [s.analysis for s in sreqs]
            return lambda: self.engine.predict_batch(reqs,
                                                     backend=backend)
        h0 = sreqs[0].hlo
        texts = [s.hlo.text for s in sreqs]
        machine = self.engine.resolve_machine(h0.machine)
        return lambda: self.engine.predict_hlo_batch(
            texts, ici_links=h0.ici_links, flop_dtype=h0.flop_dtype,
            mode=h0.mode, machine=machine,
            working_set=h0.working_set)

    def _backoff_s(self, attempt: int) -> float:
        """Full-jitter capped exponential backoff for retry ``attempt``
        (>= 1): uniform in ``[0, min(cap, base * 2**(attempt-1))]``
        from the seeded RNG, so retries decorrelate across cohorts but
        a replay is deterministic and no sleep exceeds the cap."""
        ceiling = min(self.config.retry_backoff_cap_s,
                      self.config.retry_backoff_s * (2 ** (attempt - 1)))
        return self._retry_rng.uniform(0.0, ceiling)

    def _hedge_delay_s(self) -> float:
        """The straggler threshold for hedged dispatch: configured, or
        derived from the measured dispatch-latency p99 (hedging only
        fires for dispatches already slower than ~99% of history)."""
        if self.config.hedge_delay_s is not None:
            return self.config.hedge_delay_s
        p99 = self.telemetry.dispatch.percentile(0.99)
        return p99 if p99 > 0 else max(self.config.batch_window_s, 0.01)

    def _route_start(self, key: tuple) -> str | None:
        """Pre-dispatch routing consult for one cohort: the healthiest
        start rung, or None to dispatch as requested.

        Uses the router's non-consuming :meth:`HealthRouter.preview` —
        the engine's own ``plan()`` at dispatch time stays the single
        scheduler of half-open probes, so the service consult can never
        double-spend a probe slot."""
        router = self.engine.router
        if router is None or key[0] != "x86" or key[2] != "simulate":
            return None
        requested = key[3] or self.config.backend or self.engine.sim_backend
        if requested not in LADDER:
            return None     # "auto"/None resolve on batch size downstream
        route = router.preview(self.engine.breakers, key[1],
                               ladder_from(requested))
        if route.routed_from and route.rungs:
            return route.rungs[0]
        return None

    async def _dispatch_attempt(self, key: tuple, fn, hedge_fn):
        """One governed dispatch attempt, optionally hedged.

        Without a hedge fn this is a plain bounded executor call.  With
        one, the primary runs alone for the hedge delay; if it is still
        going, the next-rung duplicate is launched and the first
        successful result wins — the loser's asyncio future is
        cancelled (the executor thread runs to completion; its result
        is discarded) and accounted in cohort-class telemetry."""
        loop = asyncio.get_running_loop()
        timeout = self.config.dispatch_timeout_s
        primary = asyncio.ensure_future(loop.run_in_executor(None, fn))
        if hedge_fn is None:
            return await asyncio.wait_for(primary, timeout)
        cls = self.telemetry.cohort_class(key)
        t0 = loop.time()
        done, _ = await asyncio.wait(
            {primary}, timeout=min(self._hedge_delay_s(), timeout))
        if primary in done:
            return primary.result()
        cls.hedges += 1
        self.telemetry.trace("hedge", cohort=class_name(key))
        hedge = asyncio.ensure_future(
            loop.run_in_executor(None, hedge_fn))
        tasks = {primary, hedge}
        last_exc: BaseException | None = None
        while tasks:
            remaining = timeout - (loop.time() - t0)
            if remaining <= 0:
                break
            done, tasks = await asyncio.wait(
                tasks, timeout=remaining,
                return_when=asyncio.FIRST_COMPLETED)
            if not done:
                break
            for t in done:
                if t.exception() is None:
                    for loser in tasks:
                        loser.cancel()
                    if t is hedge:
                        cls.hedge_wins += 1
                    return t.result()
                last_exc = t.exception()
        if tasks:       # timed out with dispatches still in flight
            for t in tasks:
                t.cancel()
            raise asyncio.TimeoutError
        assert last_exc is not None     # both completed, both failed
        raise last_exc

    async def _dispatch_cohort(self, key: tuple,
                               pendings: list["_Pending"],
                               t_form: float) -> None:
        loop = asyncio.get_running_loop()
        live: list[_Pending] = []
        for p in pendings:
            if p.abandoned:
                # submit() already counted deadline/cancel; just free
                # the admission slot
                self.admission.release(p.request.tenant)
            elif t_form > p.deadline:
                self._finalize_error(p, DeadlineExceeded(
                    "deadline elapsed before dispatch"))
            else:
                live.append(p)
        if not live:
            return
        cls = self.telemetry.cohort_class(key)
        cls.requests += len(live)
        self.telemetry.batch_size.observe(float(len(live)))
        # breaker-aware routing consult: where will this cohort start?
        # The consult is a pure preview — the engine's own plan() at
        # dispatch time performs the actual skip (and emits the
        # routed_from/probe provenance); the service records the
        # decision in telemetry and picks the hedge rung from it.
        start = self._route_start(key)
        if start is not None:
            cls.routed += 1
            self.telemetry.trace("routed", cohort=class_name(key),
                                 start=start)
        hedge_fn = None
        if self.config.hedge and key[0] == "x86" and key[2] == "simulate":
            healthiest = start or key[3] or self.config.backend \
                or self.engine.sim_backend
            rungs = ladder_from(healthiest) if healthiest in LADDER else ()
            if len(rungs) > 1:
                hedge_fn = self._engine_dispatch_fn(
                    key, [p.request for p in live], backend=rungs[1])
        fn = self._engine_dispatch_fn(key, [p.request for p in live])
        stats = self.engine.stats
        before = (stats.sim_group_dispatches, stats.sim_runs,
                  stats.hlo_misses)
        err: BaseException | None = None
        results = None
        t0 = loop.time()
        for attempt in range(1 + self.config.max_retries):
            if attempt:
                # per-tenant retry budget: a tenant out of budget fails
                # fast instead of amplifying a failing backend's load
                now_b = loop.time()
                granted: list[_Pending] = []
                for p in live:
                    if self.admission.try_retry(p.request.tenant, now_b):
                        granted.append(p)
                    else:
                        tc = self.telemetry.tenant(p.request.tenant)
                        tc.retry_budget_exhausted += 1
                        self.telemetry.trace(
                            "retry_budget_exhausted",
                            tenant=p.request.tenant,
                            cohort=class_name(key))
                        self._finalize_error(p, DispatchError(
                            "retry budget exhausted for tenant "
                            f"{p.request.tenant!r} (fail-fast; see "
                            "TenantPolicy.retry_rate_per_s)"))
                if len(granted) != len(live):
                    live = granted
                    if not live:
                        return
                    fn = self._engine_dispatch_fn(
                        key, [p.request for p in live])
                cls.retries += 1
                self.telemetry.trace("retry", cohort=class_name(key),
                                     attempt=attempt)
                # deadline-aware jittered sleep: never sleep past any
                # live request's remaining deadline
                sleep = self._backoff_s(attempt)
                sleep = max(0.0, min(
                    sleep, min(p.deadline for p in live) - loop.time()))
                self.telemetry.retry_sleep.observe(sleep)
                if sleep > 0:
                    await asyncio.sleep(sleep)
            try:
                results = await self._dispatch_attempt(key, fn, hedge_fn)
                err = None
                break
            except asyncio.TimeoutError as e:
                err = DispatchError(
                    f"dispatch timed out after "
                    f"{self.config.dispatch_timeout_s}s")
                err.__cause__ = e
            except Exception as e:        # engine-side failure
                err = DispatchError(str(e))
                err.__cause__ = e
        dt = loop.time() - t0
        cls.dispatches += 1
        cls.cost.observe(dt)
        self.telemetry.dispatch.observe(dt)
        after = (self.engine.stats.sim_group_dispatches,
                 self.engine.stats.sim_runs, self.engine.stats.hlo_misses)
        d_groups, d_sims, d_hlo = (a - b for a, b in zip(after, before))
        # one grouped simulate_many call = one compiled dispatch; the
        # small-batch tick-loop fallback = one dispatch per simulation;
        # each unique HLO module analyzed = one dispatch
        self.telemetry.engine_dispatches += \
            (d_groups if d_groups else d_sims) + d_hlo
        now = loop.time()
        if err is not None:
            self.telemetry.trace("dispatch_failed",
                                 cohort=class_name(key), error=str(err))
            for p in live:
                self._finalize_error(p, err)
            return
        for p, result in zip(live, results):
            self.cache.put(p.cache_key, result, now)
            if not p.abandoned:    # abandoned = accounted at submit
                self.telemetry.tenant(p.request.tenant).completed += 1
            self.admission.release(p.request.tenant)
            queue_s = t_form - p.t_submit
            total_s = now - p.t_submit
            self.telemetry.queue_wait.observe(queue_s)
            self.telemetry.total.observe(total_s)
            if not p.future.done():
                p.future.set_result(ServiceResponse(
                    request=p.request, result=result,
                    queue_s=queue_s, dispatch_s=dt, total_s=total_s,
                    cohort_size=len(live),
                    **ServiceResponse.provenance_of(result)))

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def export_stats(self, now: float | None = None) -> dict[str, Any]:
        """Telemetry + cross-request cache + engine cache counters as
        one JSON-serializable dict."""
        out = self.telemetry.export(now)
        out["cache"] = self.cache.stats()
        out["engine"] = self.engine.stats.as_dict()
        out["engine_hit_rates"] = {
            k: self.engine.stats.hit_rate(k)
            for k in ("result", "lookup", "lp", "hlo", "edge",
                      "program", "classify", "machine")}
        # degradation-ladder state: breaker opening/half-opening is
        # visible here (and in the bounded transition event log)
        out["breakers"] = self.engine.breakers.snapshot()
        out["faults"] = (self.engine.faults.summary()
                         if self.engine.faults is not None else None)
        # routing-policy state: plan/probe/floor counts + pending
        # probe windows (None when the engine has no router installed)
        out["router"] = (self.engine.router.snapshot()
                         if self.engine.router is not None else None)
        return out

    def slo_model(self) -> SloModel:
        """The analytic SLO self-model calibrated from this service's
        own telemetry (see repro.service.slo)."""
        return SloModel.from_telemetry(self.telemetry.export(),
                                       self.config.batch_window_s)

    def predict_slo(self) -> SloPrediction:
        """Shorthand: build the self-model and predict p50/p99."""
        return self.slo_model().predict()


@dataclass
class _Pending:
    request: ServiceRequest
    future: asyncio.Future
    cache_key: tuple
    t_submit: float
    deadline: float
    abandoned: bool = False


def replay(service: PredictionService,
           traffic: Sequence[tuple[float, ServiceRequest]],
           ) -> list[ServiceResponse]:
    """Synchronous mixed-traffic replay (the load harness entry point).

    ``traffic`` is ``[(offset_s, request), ...]`` with offsets relative
    to service start.  Starts the service, submits every request at its
    offset, drains, stops, and returns the responses in input order —
    admission rejections come back as error responses rather than
    raising, so a replay is never torn down by one throttled tenant.
    """
    async def _go() -> list[ServiceResponse]:
        await service.start()
        out: list[ServiceResponse | None] = [None] * len(traffic)

        async def one(i: int, offset: float, sreq: ServiceRequest):
            await asyncio.sleep(offset)
            try:
                out[i] = await service.submit(sreq)
            except (AdmissionError, ServiceClosed) as e:
                out[i] = ServiceResponse(request=sreq, error=e)

        await asyncio.gather(*(one(i, off, sreq)
                               for i, (off, sreq) in enumerate(traffic)))
        await service.stop()
        return out                    # type: ignore[return-value]

    return asyncio.run(_go())
