"""Bounded cross-request result cache (TTL + LRU size cap).

The engine's own memo caches are unbounded and live for the engine's
lifetime — right for a batch job, wrong for a persistent multi-tenant
service where kernels churn.  This cache fronts the engine with two
bounds:

* **TTL** — entries older than ``ttl_s`` are treated as absent (and
  reaped lazily on access / explicitly by ``purge``);
* **size** — at most ``max_entries`` live entries, evicting least
  recently *used* first.

Keys are the same content digests the engine memoizes on (machine
digest x kernel id x request knobs), so two tenants asking the same
question share one entry.  Like the admission controller, the cache
takes ``now`` from the caller — deterministic under test.

A cache is a redundancy, never a dependency: when a
:class:`~repro.core.faults.FaultInjector` is armed on it, an injected
``cache.get`` fault is served as a miss and an injected ``cache.put``
fault silently drops the store — the service keeps answering either
way (docs/robustness.md).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Hashable

from repro.core.faults import FaultAbort, FaultInjector, InjectedFault


class TTLCache:
    """LRU-of-bounded-size with per-entry TTL; O(1) get/put."""

    def __init__(self, max_entries: int = 4096,
                 ttl_s: float = float("inf"),
                 faults: FaultInjector | None = None):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.ttl_s = ttl_s
        self.faults = faults
        self._data: OrderedDict[Hashable, tuple[float, Any]] = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.expirations = 0
        self.fault_misses = 0
        self.fault_drops = 0

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Hashable, now: float = 0.0):
        """The cached value or ``None`` (expired entries count as
        misses and are dropped; an injected fault is contained as a
        miss)."""
        if self.faults is not None:
            try:
                self.faults.fire("cache.get")
            except FaultAbort:
                raise
            except InjectedFault:
                self.fault_misses += 1
                self.misses += 1
                return None
        entry = self._data.get(key)
        if entry is None:
            self.misses += 1
            return None
        stamp, value = entry
        if now - stamp > self.ttl_s:
            del self._data[key]
            self.expirations += 1
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key: Hashable, value: Any, now: float = 0.0) -> None:
        if self.faults is not None:
            try:
                self.faults.fire("cache.put")
            except FaultAbort:
                raise
            except InjectedFault:
                self.fault_drops += 1
                return
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = (now, value)
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)
            self.evictions += 1

    def purge(self, now: float) -> int:
        """Drop every expired entry; returns the count dropped."""
        dead = [k for k, (stamp, _) in self._data.items()
                if now - stamp > self.ttl_s]
        for k in dead:
            del self._data[k]
        self.expirations += len(dead)
        return len(dead)

    def invalidate(self, match: Callable[[Hashable], bool]) -> int:
        """Drop every entry whose key satisfies ``match``; returns the
        count dropped (the targeted form of :meth:`clear`, e.g. keys
        carrying a superseded machine digest)."""
        dead = [k for k in self._data if match(k)]
        for k in dead:
            del self._data[k]
        return len(dead)

    def clear(self) -> None:
        self._data.clear()

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict[str, float]:
        return {"entries": len(self._data), "hits": self.hits,
                "misses": self.misses, "hit_rate": self.hit_rate(),
                "evictions": self.evictions,
                "expirations": self.expirations,
                "fault_misses": self.fault_misses,
                "fault_drops": self.fault_drops}
