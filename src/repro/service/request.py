"""Service request/response envelopes.

A :class:`ServiceRequest` wraps one unit of work for the prediction
service: either an x86 :class:`~repro.core.engine.AnalysisRequest`
(single point or sweep cell) or an HLO module text (the serving
dry-run path), plus the multi-tenant envelope — tenant id, per-request
timeout, and the batch-dispatch backend hint.

Responses carry the raw engine result plus per-stage timing so the
load harness (``benchmarks/service_bench.py``) and the observability
layer can attribute latency to queueing vs batching vs dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.core.engine import AnalysisRequest


class DeadlineExceeded(Exception):
    """The request's timeout elapsed before a result was produced."""


class DispatchError(Exception):
    """The engine dispatch failed after the configured retries."""


class ServiceClosed(Exception):
    """submit() after stop(): the service no longer accepts work."""


@dataclass(frozen=True)
class HloRequest:
    """One HLO dry-run cell (the TPU analogue of AnalysisRequest)."""

    text: str
    machine: str = "tpu_v5e"
    mode: str = "analytic"
    ici_links: float = 1.0
    flop_dtype: str = "bf16"
    working_set: float | None = None


@dataclass(frozen=True)
class ServiceRequest:
    """One tenant-attributed unit of work.

    Exactly one of ``analysis`` / ``hlo`` must be set.  ``timeout_s``
    is the caller's deadline measured from submit; ``None`` means the
    service default.  ``backend`` overrides the batch-simulation driver
    for the cohort this request lands in (requests with different
    backends never share a cohort).
    """

    analysis: AnalysisRequest | None = None
    hlo: HloRequest | None = None
    tenant: str = "default"
    timeout_s: float | None = None
    backend: str | None = None
    tag: str = ""            # free-form label echoed into trace events

    def __post_init__(self):
        if (self.analysis is None) == (self.hlo is None):
            raise ValueError("exactly one of analysis=/hlo= must be set")

    @property
    def kind(self) -> str:
        return "x86" if self.analysis is not None else "hlo"


@dataclass
class ServiceResponse:
    """Result envelope: the engine result plus latency attribution."""

    request: ServiceRequest
    result: Any = None               # AnalysisResult | HloAnalysis
    error: BaseException | None = None
    cache_hit: bool = False          # served from the cross-request cache
    queue_s: float = 0.0             # submit -> cohort formation
    dispatch_s: float = 0.0          # engine batch dispatch (shared)
    total_s: float = 0.0             # submit -> response
    cohort_size: int = 0             # batch the request dispatched in
    # degradation provenance, copied from the engine result — a
    # degraded answer is never silently indistinguishable from a
    # full-fidelity one (docs/robustness.md)
    degraded: bool = False
    backend_used: str = ""           # fallback rung ("" = as requested)
    fault_trace_id: int = 0          # FaultInjector event id (0 = none)
    routed_from: str = ""            # rung the HealthRouter skipped
    #                                  pre-dispatch ("" = not routed)
    probe: bool = False              # answered by a scheduled half-open
    #                                  probe dispatch

    @property
    def ok(self) -> bool:
        return self.error is None

    @classmethod
    def provenance_of(cls, result: Any) -> dict[str, Any]:
        """The degradation fields carried by an engine result (empty
        defaults for result types without them, e.g. HloAnalysis)."""
        return {
            "degraded": bool(getattr(result, "degraded", False)),
            "backend_used": str(getattr(result, "backend_used", "")),
            "fault_trace_id": int(getattr(result, "fault_trace_id", 0)),
            "routed_from": str(getattr(result, "routed_from", "")),
            "probe": bool(getattr(result, "probe", False)),
        }
