from .pipeline import DataConfig, SyntheticTokenPipeline, make_pipeline
