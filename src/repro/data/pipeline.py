"""Deterministic, resumable, host-sharded data pipeline.

Design constraints for 1000+ node operation:
  * stateless indexing — batch contents are a pure function of
    (seed, step, host_shard), so restart/resume needs no iterator state in
    checkpoints, only the step counter;
  * host sharding — each host materialises only its slice of the global
    batch (process_index/process_count);
  * sequence packing — documents of random length are packed into fixed
    seq_len rows with EOS separators, like production LM loaders.

The token source is a seeded counter-based PRNG (threefry via
jax.random under the hood would force device work; we use numpy's
Philox which is also counter-based and cheap on host CPUs).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 1234
    mean_doc_len: int = 512
    eos_id: int = 0
    modality: str = "text"        # text | audio | vision
    frame_dim: int = 512
    n_patches: int = 0
    d_model: int = 0


class SyntheticTokenPipeline:
    """batch(step) -> {"tokens", "labels"} (+ modality extras)."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        if cfg.global_batch % process_count:
            raise ValueError("global batch must divide process count")
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count

    # -- stateless sampling ------------------------------------------
    def _rng(self, step: int, row: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=self.cfg.seed, counter=[step, row, 0, 0]))

    def _row(self, step: int, global_row: int) -> np.ndarray:
        cfg = self.cfg
        rng = self._rng(step, global_row)
        out = np.empty(cfg.seq_len + 1, np.int32)
        pos = 0
        while pos < cfg.seq_len + 1:
            doc_len = int(rng.exponential(cfg.mean_doc_len)) + 1
            doc = rng.integers(1, cfg.vocab_size,
                               size=min(doc_len, cfg.seq_len + 1 - pos),
                               dtype=np.int32)
            out[pos:pos + len(doc)] = doc
            pos += len(doc)
            if pos < cfg.seq_len + 1:
                out[pos] = cfg.eos_id
                pos += 1
        return out

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rows = []
        base = self.process_index * self.local_batch
        for i in range(self.local_batch):
            rows.append(self._row(step, base + i))
        arr = np.stack(rows)                       # (B_local, S+1)
        out = {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
        if cfg.modality == "audio":
            rng = self._rng(step, 1 << 20)
            out = {
                "frames": rng.standard_normal(
                    (self.local_batch, cfg.seq_len, cfg.frame_dim)
                ).astype(np.float32),
                "labels": out["labels"] % 504,
            }
        elif cfg.modality == "vision":
            rng = self._rng(step, 1 << 21)
            out["patches"] = rng.standard_normal(
                (self.local_batch, cfg.n_patches, cfg.d_model)
            ).astype(np.float32)
        return out


def make_pipeline(model_cfg, seq_len: int, global_batch: int,
                  process_index: int = 0, process_count: int = 1,
                  seed: int = 1234) -> SyntheticTokenPipeline:
    return SyntheticTokenPipeline(
        DataConfig(seq_len=seq_len, global_batch=global_batch,
                   vocab_size=model_cfg.vocab_size, seed=seed,
                   modality=model_cfg.modality,
                   n_patches=model_cfg.n_patches,
                   d_model=model_cfg.d_model),
        process_index, process_count)
