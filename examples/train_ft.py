"""End-to-end fault-tolerant training driver: trains a reduced model for a
few hundred steps with checkpointing, then simulates a preemption and
resumes from the latest checkpoint — the full production loop on CPU.

Run:  PYTHONPATH=src python examples/train_ft.py [--steps 200]
"""
import argparse
import logging
import shutil
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.launch.mesh import make_test_mesh
from repro.models.config import ShapeConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig

logging.basicConfig(level=logging.INFO,
                    format="%(asctime)s %(name)s %(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_")
    cfg = get_smoke_config(args.arch)
    shape = ShapeConfig("smoke", seq_len=128, global_batch=8,
                        kind="train")
    mesh = jax.sharding.Mesh(
        __import__("numpy").asarray(jax.devices()[:1]).reshape(1, 1),
        ("data", "model"))
    tcfg = TrainerConfig(steps=args.steps, checkpoint_dir=ckpt_dir,
                         checkpoint_every=50, log_every=20,
                         optimizer=AdamWConfig(lr=1e-3))

    # phase 1: train half the steps, then simulate a preemption
    trainer = Trainer(cfg, shape, mesh, tcfg)
    half = TrainerConfig(**{**tcfg.__dict__,
                            "steps": args.steps // 2})
    trainer.tcfg = half
    out1 = trainer.run()
    print(f"phase 1 done at step {out1['final_step']}, "
          f"loss {out1['metrics'][-1]['loss']:.4f}")

    # phase 2: fresh Trainer resumes from the checkpoint automatically
    trainer2 = Trainer(cfg, shape, mesh, tcfg)
    out2 = trainer2.run()
    print(f"phase 2 resumed and finished at step {out2['final_step']}, "
          f"loss {out2['metrics'][-1]['loss']:.4f}")
    first = out1["metrics"][0]["loss"]
    last = out2["metrics"][-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
