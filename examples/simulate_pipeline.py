"""The third prediction backend: cycle-level pipeline simulation.

Runs every paper kernel through all three backends — the analytic port
bound, the loop-carried-dependency bound, and the out-of-order pipeline
simulator — on both CPU models, then shows the vectorized batch driver
producing the same sweep in one struct-of-arrays pass.

Run:  PYTHONPATH=src python examples/simulate_pipeline.py
"""
from repro.core import (AnalysisRequest, compile_program, default_service,
                        extract_kernel, simulate_many)
from repro.core import paper_kernels as pk

CASES = {
    "triad_skl_O3": ("skl", pk.TRIAD_SKL_O3, 4),
    "triad_zen_O3": ("zen", pk.TRIAD_ZEN_O3, 2),
    "pi_skl_O1": ("skl", pk.PI_O1, 1),
    "pi_skl_O2": ("skl", pk.PI_O2, 1),
    "pi_skl_O3": ("skl", pk.PI_SKL_O3, 8),
    "pi_zen_O1": ("zen", pk.PI_O1, 1),
    "pi_zen_O3": ("zen", pk.PI_ZEN_O3, 2),
}


def main():
    svc = default_service()

    print("=" * 76)
    print("Three backends per kernel [cy/asm-iteration]")
    print("=" * 76)
    print(f"{'kernel':16s} {'port':>6s} {'LCD':>6s} {'sim':>6s}"
          f"  {'binding':<11s} {'sim bottleneck':<14s}")
    for name, (arch, src, unroll) in CASES.items():
        res = svc.predict(AnalysisRequest(
            kernel=src, arch=arch, unroll_factor=unroll, mode="simulate"))
        print(f"{name:16s} {res.port_bound_cycles:6.2f} "
              f"{res.lcd_cycles:6.2f} {res.bound_sim:6.2f}"
              f"  {res.binding:<11s} {res.sim_result.bottleneck:<14s}")

    print()
    print("Detailed simulator report for pi -O1 on Skylake (the paper's")
    print("Table V outlier, measured 9.02 cy/it):")
    res = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch="skl",
                                      mode="simulate"))
    print(res.sim_result.render())

    print()
    print("=" * 76)
    print("Vectorized batch driver: the same sweep in one SoA pass")
    print("=" * 76)
    # compile_program accepts an arch id directly: it resolves through
    # the architecture registry (cached MachineModel -> InstructionDB)
    programs = [compile_program(extract_kernel(src), arch)
                for arch, src, _ in CASES.values()]
    for name, sim in zip(CASES, simulate_many(programs)):
        print(f"{name:16s} {sim.cycles_per_iteration:6.2f} cy/it  "
              f"(converged={sim.converged}, {sim.bottleneck})")

    from repro.core.sim import has_jax
    if has_jax():
        print()
        print("Compiled backend (jax.jit, float64): same numbers to 1e-9")
        for name, sim in zip(CASES,
                             simulate_many(programs, backend="jit")):
            print(f"{name:16s} {sim.cycles_per_iteration:6.2f} cy/it")
        # a bulk sweep dispatches one compiled call per machine model:
        grid = svc.sweep({n: src for n, (_, src, _) in CASES.items()},
                         archs=("skl", "zen"), mode="simulate",
                         backend="jit")
        print(f"sweep: {len(grid)} cells, "
              f"{svc.stats.sim_group_dispatches} compiled dispatches "
              f"(see docs/performance.md and BENCH_sweep.json)")


if __name__ == "__main__":
    main()
