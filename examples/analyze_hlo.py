"""Analyze any (architecture x shape) cell with the OSACA-on-HLO engine —
the paper's workflow (extract kernel -> match instruction forms -> port
occupation table -> bottleneck) applied to a compiled JAX step.

Run:  PYTHONPATH=src python examples/analyze_hlo.py --arch qwen2.5-3b \
          --shape train_4k [--multi-pod] [--set remat=dots ...]

Note: spawns its own 512-device world; run as a standalone process.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse


def main():
    from repro.configs import ARCH_IDS
    from repro.core.engine import default_service
    from repro.launch.dryrun import _coerce
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step
    from repro.configs import get_config
    from repro.models.config import SHAPES
    from repro.parallel.sharding import make_rules

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--shape", choices=list(SHAPES), default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--set", action="append", default=[],
                    dest="overrides")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    for kv in args.overrides:
        k, _, v = kv.partition("=")
        cfg = cfg.with_updates(**{k: _coerce(v)})
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        step = build_step(cfg, SHAPES[args.shape], make_rules(mesh))
        print(f"lowering {step.name} for {args.arch} x {args.shape} on "
              f"{mesh.devices.size} chips ...")
        compiled = step.lower().compile()
        print("memory_analysis:", compiled.memory_analysis())
        # shared service: repeated runs over the same module (or the
        # serving dry-run on the same program) reuse this analysis
        analysis = default_service().predict_hlo(compiled.as_text())
    print(analysis.render(top=args.top))


if __name__ == "__main__":
    main()
