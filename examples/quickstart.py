"""Quickstart: the paper's workflow end to end in two minutes on CPU.

1. Analyze the paper's own Schoenauer-triad assembly with the OSACA
   engine (Skylake + Zen port models) — reproduces paper Table II/IV.
2. Train a reduced Qwen2.5-family model for a few steps.
3. Analyze the *compiled training step* with the same engine's TPU port
   model — the paper's technique applied to the framework itself.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import AnalysisRequest, default_service
from repro.core import paper_kernels as pk
from repro.configs import get_smoke_config
from repro.models import init_params, model_schema, train_loss


def main():
    svc = default_service()

    # -- 1. the paper's x86 analysis -----------------------------------
    print("=" * 72)
    print("OSACA analysis: Schoenauer triad, -O3, Skylake (paper Table II)")
    print("=" * 72)
    res = svc.predict(AnalysisRequest(kernel=pk.TRIAD_SKL_O3, arch="skl",
                                      unroll_factor=4))
    print(res.render())
    print()
    print("Same code on the AMD Zen model (paper Table I row 3):")
    res_zen = svc.predict(AnalysisRequest(kernel=pk.TRIAD_SKL_O3,
                                          arch="zen", unroll_factor=4))
    print(f"  predicted {res_zen.predicted_cycles:.2f} cy/asm-it "
          f"(paper: 4.00) — AVX double-pumping on Zen")
    print()
    print("pi at -O1: the case the paper's pure port model gets ~2x wrong")
    print("(Table V) — the unified engine's LCD bound fixes it:")
    res_pi = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch="skl"))
    print(f"  port bound {res_pi.port_bound_cycles:.2f} cy/it, "
          f"LCD {res_pi.lcd_cycles:.2f} cy/it -> predicted "
          f"{res_pi.predicted_cycles:.2f} ({res_pi.binding}-bound; "
          f"measured 9.02)")

    # -- 1b. machine models are data ------------------------------------
    print()
    print("Machine models are declarative artifacts (ISSUE 3): every")
    print("arch resolves through the registry and serializes to JSON —")
    from repro.core import get_model
    skl = get_model("skl")
    print(f"  skl: {len(skl.forms)} instruction forms, "
          f"{len(skl.ports)} ports, digest {skl.digest[:16]}")
    print(f"  shipped variants resolve too: clx = "
          f"{get_model('cascadelake').name!r} "
          f"(a derive() of skl in arch/models/cascadelake.json)")

    # -- 2. train a reduced model --------------------------------------
    print()
    print("=" * 72)
    print("Training a reduced qwen2.5-family model (CPU)")
    print("=" * 72)
    cfg = get_smoke_config("qwen2.5-3b")
    params = init_params(model_schema(cfg), jax.random.key(0))
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, tokens, labels):
        loss, grads = jax.value_and_grad(train_loss)(
            params, {"tokens": tokens, "labels": labels}, cfg)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    key = jax.random.key(1)
    tokens = jax.random.randint(key, (4, 128), 1, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    for i in range(5):
        params, opt, loss = step(params, opt, tokens, labels)
        print(f"  step {i}: loss {float(loss):.4f}")

    # -- 3. the paper's technique on the compiled step ------------------
    print()
    print("=" * 72)
    print("Port-model analysis of the compiled train step (TPU v5e model)")
    print("=" * 72)
    lowered = jax.jit(lambda p, o, t, l: step.__wrapped__(p, o, t, l)) \
        .lower(params, opt, tokens, labels)
    text = lowered.compile().as_text()
    analysis = svc.predict_hlo(text)
    print(analysis.render(top=8))


if __name__ == "__main__":
    main()
