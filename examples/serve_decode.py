"""Serve a reduced model with batched requests through the continuous-
batching engine (prefill + slotted decode with KV/SSM caches).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch mamba2-370m]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params, model_schema
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(model_schema(cfg), jax.random.key(0))
    engine = ServingEngine(cfg, params, n_slots=args.slots, max_len=96)

    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, size=16),
                    max_new_tokens=8)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    results = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.tokens) for r in results)
    for r in sorted(results, key=lambda r: r.rid):
        print(f"req {r.rid}: {len(r.tokens)} tokens "
              f"(prefill {r.prefill_s * 1e3:.1f} ms) {r.tokens[:8]}")
    print(f"{len(results)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
