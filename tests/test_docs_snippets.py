"""Runnable-docs check: every fenced ```python block in docs/api.md and
docs/simulation.md executes as written (the docs promise this), so the
documented signatures — including the ``mode`` parameter and
``AnalysisResult.bound_sim`` — cannot drift from the code."""
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_PAGES = ["docs/api.md", "docs/simulation.md", "docs/performance.md",
             "docs/frontend.md", "docs/ecm.md",
             "docs/serving-service.md", "docs/robustness.md"]


def _python_blocks(page: str) -> list[tuple[str, str]]:
    text = (ROOT / page).read_text(encoding="utf-8")
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip() == "```python":
            j = i + 1
            while j < len(lines) and lines[j].strip() != "```":
                j += 1
            blocks.append((f"{page}:{i + 1}", "\n".join(lines[i + 1:j])))
            i = j
        i += 1
    return blocks


SNIPPETS = [b for page in DOC_PAGES for b in _python_blocks(page)]


def test_docs_have_snippets():
    assert len(SNIPPETS) >= 4        # api.md worked snippets + simulation.md


@pytest.mark.parametrize("where,code",
                         SNIPPETS, ids=[w for w, _ in SNIPPETS])
def test_doc_snippet_runs(where, code):
    namespace: dict = {"__name__": f"doc_snippet<{where}>"}
    exec(compile(code, where, "exec"), namespace)
