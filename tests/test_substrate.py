"""Substrate behaviour: data determinism/resume, checkpoint atomicity +
reshard, AdamW correctness, straggler detection, preemption flag."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional [dev] dependency
    from repro.testing import given, settings, st

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.train.fault_tolerance import PreemptionSignal, StragglerMonitor


# ------------------------------------------------------------------ #
# data pipeline
# ------------------------------------------------------------------ #
def _pipe(**kw):
    cfg = DataConfig(seq_len=kw.pop("seq_len", 64),
                     global_batch=kw.pop("global_batch", 8),
                     vocab_size=1000, **kw)
    return cfg


def test_pipeline_deterministic_and_stateless():
    cfg = _pipe()
    p = SyntheticTokenPipeline(cfg)
    b1 = p.batch(7)
    b2 = SyntheticTokenPipeline(cfg).batch(7)  # fresh instance, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"],
                              p.batch(8)["tokens"])  # steps differ


def test_pipeline_host_sharding_partitions_global_batch():
    cfg = _pipe()
    full = SyntheticTokenPipeline(cfg).batch(3)["tokens"]
    parts = [SyntheticTokenPipeline(cfg, process_index=i,
                                    process_count=4).batch(3)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_labels_shifted():
    cfg = _pipe()
    p = SyntheticTokenPipeline(cfg)
    b = p.batch(0)
    # labels are the next-token stream: token[t+1] == label[t]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10_000), row=st.integers(0, 63))
def test_pipeline_rows_independent_of_batch_position(step, row):
    """Property: row contents depend only on (seed, step, global row)."""
    cfg = _pipe(global_batch=64)
    a = SyntheticTokenPipeline(cfg).batch(step)["tokens"][row]
    shard = SyntheticTokenPipeline(cfg, process_index=row // 16,
                                   process_count=4)
    b = shard.batch(step)["tokens"][row % 16]
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------------ #
# checkpointing
# ------------------------------------------------------------------ #
def _tree(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"step": jnp.int32(3)}}


def test_checkpoint_roundtrip_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(5, t)
    store.save(10, t)
    assert store.latest_step() == 10
    loaded = store.load(10, jax.eval_shape(lambda: t))
    np.testing.assert_allclose(np.asarray(loaded["params"]["w"]),
                               np.asarray(t["params"]["w"]))
    assert int(loaded["opt"]["step"]) == 3


def test_checkpoint_atomicity_tmpdir_invisible(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _tree())
    # a stale tmp dir (simulated crash) must not be listed as a step
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert store.steps() == [1]


def test_checkpoint_gc_keeps_last_k(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree())
    assert store.steps() == [3, 4]


def test_checkpoint_async_background(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(7, _tree(), background=True)
    store.wait()
    assert store.latest_step() == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _tree())
    bad = {"params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))},
           "opt": {"step": jnp.int32(0)}}
    with pytest.raises(ValueError):
        store.load(1, jax.eval_shape(lambda: bad))


# ------------------------------------------------------------------ #
# AdamW
# ------------------------------------------------------------------ #
def test_adamw_matches_manual_first_step():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                      weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.5])}
    state = adamw_init(params, cfg)
    new, state, gnorm = adamw_update(params, grads, state, cfg)
    # bias-corrected first step: mhat = g, vhat = g^2 -> delta ~ sign(g)
    expected = params["w"] - 0.1 * grads["w"] / (
        jnp.abs(grads["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.asarray(expected), rtol=1e-5)
    assert state["step"] == 1


def test_adamw_grad_clip_and_decay():
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.1)
    params = {"w": jnp.full((4,), 2.0)}
    grads = {"w": jnp.full((4,), 100.0)}
    state = adamw_init(params, cfg)
    new, _, gnorm = adamw_update(params, grads, state, cfg)
    assert float(gnorm) == pytest.approx(200.0)  # ||g||
    assert np.all(np.asarray(new["w"]) < 2.0)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.int32(0), warmup=10)) == 0.0
    assert float(cosine_schedule(jnp.int32(10), warmup=10)) \
        == pytest.approx(1.0, abs=1e-3)
    assert float(cosine_schedule(jnp.int32(10_000), warmup=10,
                                 total=10_000)) == pytest.approx(0.1)


# ------------------------------------------------------------------ #
# fault tolerance primitives
# ------------------------------------------------------------------ #
def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold_mads=3.0, evict_after=2)
    for step in range(3):
        times = {h: 1.0 + 0.01 * h for h in range(8)}
        times[5] = 5.0  # consistent straggler
        flagged = mon.record(step, times)
        assert [r.host for r in flagged] == [5]
    assert mon.hosts_to_evict() == [5]


def test_straggler_monitor_ignores_uniform_slowdown():
    mon = StragglerMonitor()
    flagged = mon.record(0, {h: 9.9 for h in range(8)})
    assert flagged == []


def test_preemption_signal_flag():
    sig = PreemptionSignal().install()
    try:
        assert not sig.fired
        sig.trigger()
        assert sig.fired
    finally:
        sig.uninstall()
