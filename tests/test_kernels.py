"""Per-kernel shape/dtype sweeps + hypothesis property tests, asserting
allclose against the pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional [dev] dependency
    from repro.testing import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.moe_gmm.ops import grouped_matmul
from repro.kernels.moe_gmm.ref import grouped_matmul_reference
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_reference


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.key(key), shape, jnp.float32)
    return x.astype(dtype)


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("S,Hq,Hkv,D,causal,window,softcap", [
    (128, 2, 2, 32, True, 0, 0.0),
    (128, 4, 1, 64, True, 0, 0.0),      # MQA
    (256, 4, 2, 64, False, 0, 0.0),     # bidirectional GQA
    (256, 2, 2, 64, True, 64, 0.0),     # sliding window
    (128, 2, 2, 32, True, 0, 30.0),     # logit softcap
])
def test_flash_attention_matches_reference(S, Hq, Hkv, D, causal, window,
                                           softcap, dtype, tol):
    B = 2
    q = _rand(1, (B, S, Hq, D), dtype)
    k = _rand(2, (B, S, Hkv, D), dtype)
    v = _rand(3, (B, S, Hkv, D), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        softcap=softcap, block_q=64, block_k=64,
                        interpret=True)
    r = attention_reference(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        softcap=softcap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=12, deadline=None)
@given(bq=st.sampled_from([32, 64, 128]),
       bk=st.sampled_from([32, 64, 128]),
       causal=st.booleans())
def test_flash_attention_block_size_invariance(bq, bk, causal):
    """Property: output independent of BlockSpec tiling."""
    B, S, H, D = 1, 128, 2, 32
    q = _rand(4, (B, S, H, D), jnp.float32)
    k = _rand(5, (B, S, H, D), jnp.float32)
    v = _rand(6, (B, S, H, D), jnp.float32)
    o1 = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                         interpret=True)
    o2 = flash_attention(q, k, v, causal=causal, block_q=S, block_k=S,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# ------------------------------------------------------------------ #
# SSD scan
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-5),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("S,H,P,N,chunk", [
    (128, 2, 16, 16, 32),
    (256, 4, 16, 32, 64),
    (64, 1, 32, 16, 64),
])
def test_ssd_scan_matches_recurrence(S, H, P, N, chunk, dtype, tol):
    B = 2
    x = _rand(7, (B, S, H, P), dtype)
    dt = jax.nn.softplus(_rand(8, (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(9, (H,), jnp.float32) * 0.5)
    da = dt * A
    bm = _rand(10, (B, S, N), dtype) * 0.3
    cm = _rand(11, (B, S, N), dtype) * 0.3
    y = ssd_scan(x, da, dt, bm.astype(jnp.float32),
                 cm.astype(jnp.float32), chunk=chunk, interpret=True)
    r = ssd_reference(
        x.astype(jnp.float32).transpose(0, 2, 1, 3),
        da.transpose(0, 2, 1), dt.transpose(0, 2, 1),
        bm.astype(jnp.float32), cm.astype(jnp.float32)
    ).transpose(0, 2, 1, 3)
    scale = float(jnp.max(jnp.abs(r))) + 1e-6
    np.testing.assert_allclose(np.asarray(y, np.float32) / scale,
                               np.asarray(r, np.float32) / scale,
                               atol=tol)


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([16, 32, 64, 128]))
def test_ssd_scan_chunk_invariance(chunk):
    """Property: chunked state passing is exact — chunk size must not
    change the result (the paper's A2-style decomposition check)."""
    B, S, H, P, N = 1, 128, 2, 16, 16
    x = _rand(12, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(13, (B, S, H), jnp.float32))
    da = dt * -0.5
    bm = _rand(14, (B, S, N), jnp.float32) * 0.3
    cm = _rand(15, (B, S, N), jnp.float32) * 0.3
    y1 = ssd_scan(x, da, dt, bm, cm, chunk=chunk, interpret=True)
    y2 = ssd_scan(x, da, dt, bm, cm, chunk=S, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------ #
# grouped expert GEMM
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("E,C,d,f,bc,bd,bf", [
    (4, 64, 128, 96, 32, 64, 32),
    (2, 128, 64, 64, 128, 64, 64),
    (8, 32, 256, 128, 32, 128, 128),
])
def test_grouped_matmul(E, C, d, f, bc, bd, bf, dtype, tol):
    x = _rand(16, (E, C, d), dtype)
    w = _rand(17, (E, d, f), dtype)
    y = grouped_matmul(x, w, block_c=bc, block_d=bd, block_f=bf,
                       interpret=True)
    r = grouped_matmul_reference(x, w)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(r, np.float32),
        atol=tol * d, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(e=st.integers(1, 6), seed=st.integers(0, 100))
def test_grouped_matmul_expert_independence(e, seed):
    """Property: expert e's output depends only on expert e's inputs."""
    E, C, d, f = 6, 32, 64, 32
    x = _rand(seed, (E, C, d), jnp.float32)
    w = _rand(seed + 1, (E, d, f), jnp.float32)
    y = grouped_matmul(x, w, block_c=32, block_d=64, block_f=32,
                       interpret=True)
    x2 = x.at[(e - 1) % E].set(0.0)
    y2 = grouped_matmul(x2, w, block_c=32, block_d=64, block_f=32,
                        interpret=True)
    others = np.array([i for i in range(E) if i != (e - 1) % E])
    np.testing.assert_allclose(np.asarray(y[others]),
                               np.asarray(y2[others]), atol=1e-6)
