"""Chaos suite: deterministic fault injection, the degradation ladder,
circuit breakers, and crash-safe resumable sweeps (docs/robustness.md).

The invariants pinned here:

* an engine with **no armed plan** is bit-identical to one with an
  empty plan (the fault layer is zero-cost when disarmed — the golden
  suites stay pinned);
* under a persistent injected backend failure, every request still
  resolves — demoted down the ladder or to the analytic floor — and
  the response carries ``degraded`` / ``backend_used`` /
  ``fault_trace_id`` provenance;
* breakers honor their cooldowns (closed -> open -> half_open, with an
  injectable clock, no sleeping);
* a killed, journaled sweep resumes **bit-for-bit** with zero
  re-dispatch of journaled machine groups;
* re-registering a machine model never serves a stale prediction
  (engine epoch check + service cache invalidation);
* the hypothesis schedule property: any random fault schedule replayed
  through the service resolves every admitted request exactly once —
  ``ok`` or a typed error, never a hang or a drop.

On a property failure the injector's event trace is written to
``FAULT_TRACE_PATH`` (when set) so CI can upload it as an artifact.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import math
import os
import time
from pathlib import Path
from types import SimpleNamespace

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional dev dependency
    from repro.testing import given, settings, st

from repro.core import AnalysisService, paper_kernels as pk
from repro.core.degrade import (BreakerBoard, BreakerConfig,
                                CircuitBreaker, validate_sims)
from repro.core.engine import AnalysisRequest
from repro.core.faults import (FAULT_POINTS, FaultAbort, FaultInjector,
                               FaultPlan, FaultSpec, InjectedFault)
from repro.core.sim import has_jax

needs_jax = pytest.mark.skipif(not has_jax(),
                               reason="jax not installed")

KERNELS = {"triad_skl": pk.TRIAD_SKL_O3, "pi_o2": pk.PI_O2}


def _dump_trace(injector: FaultInjector | None) -> None:
    """CI artifact hook: persist the fault-event trace on failure."""
    path = os.environ.get("FAULT_TRACE_PATH")
    if path and injector is not None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(injector.export(), f, indent=2)


# ----------------------------------------------------------------------
# plan / spec serialization
# ----------------------------------------------------------------------
def test_plan_json_round_trip_and_digest():
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail_n", count=2,
                  skip=1, match={"backend": "jit"}),
        FaultSpec(point="cache.get", mode="latency", delay_s=0.01),
        FaultSpec(point="engine.compile", mode="corrupt",
                  corrupt="negative", probability=0.5),
    ), seed=7)
    clone = FaultPlan.from_json(plan.to_json())
    assert clone == plan
    assert clone.digest == plan.digest
    assert FaultPlan(specs=plan.specs, seed=8).digest != plan.digest


@pytest.mark.parametrize("kwargs", [
    {"point": "engine.nope"},
    {"point": "engine.dispatch", "mode": "explode"},
    {"point": "engine.dispatch", "mode": "corrupt", "corrupt": "zero"},
    {"point": "engine.dispatch", "skip": -1},
    {"point": "engine.dispatch", "count": 0},
    {"point": "engine.dispatch", "probability": 1.5},
    {"point": "engine.dispatch", "delay_s": -0.1},
])
def test_spec_validation_fails_loudly(kwargs):
    with pytest.raises(ValueError):
        FaultSpec(**kwargs)


# ----------------------------------------------------------------------
# injector decision core
# ----------------------------------------------------------------------
def _fires(inj: FaultInjector, point: str, n: int, **ctx) -> int:
    fired = 0
    for _ in range(n):
        try:
            inj.fire(point, **ctx)
        except InjectedFault:
            fired += 1
    return fired


def test_fail_once_fires_exactly_once():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(point="engine.compile", mode="fail_once"),)))
    assert _fires(inj, "engine.compile", 10) == 1


def test_fail_n_with_skip():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail_n", count=3,
                  skip=2),)))
    outcomes = []
    for _ in range(8):
        try:
            inj.fire("engine.dispatch")
            outcomes.append("ok")
        except InjectedFault:
            outcomes.append("fault")
    assert outcomes == ["ok", "ok", "fault", "fault", "fault",
                        "ok", "ok", "ok"]


def test_latency_uses_injected_sleep():
    slept: list[float] = []
    inj = FaultInjector(
        FaultPlan(specs=(FaultSpec(point="cache.get", mode="latency",
                                   delay_s=0.25, count=2),)),
        sleep=slept.append)
    for _ in range(5):
        inj.fire("cache.get")        # latency never raises
    assert slept == [0.25, 0.25]
    assert [e.action for e in inj.events()] == ["delayed", "delayed"]


def test_corrupt_nan_and_negative():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="corrupt",
                  corrupt="nan", count=1),
        FaultSpec(point="engine.dispatch", mode="corrupt",
                  corrupt="negative"),)))
    v1, e1 = inj.corrupt("engine.dispatch", 4.0)
    assert math.isnan(v1) and e1 > 0
    v2, e2 = inj.corrupt("engine.dispatch", 4.0)
    assert v2 < 0 and e2 > e1
    # an unarmed point passes values through untouched
    v3, e3 = inj.corrupt("engine.traffic", 4.0)
    assert v3 == 4.0 and e3 == 0


def test_match_restricts_firing_to_context():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": "jit"}),)))
    inj.fire("engine.dispatch", backend="numpy")      # no match, no fire
    with pytest.raises(InjectedFault):
        inj.fire("engine.dispatch", backend="jit")


def test_probability_is_deterministic_across_injectors():
    plan = FaultPlan(specs=(
        FaultSpec(point="cache.put", mode="fail", probability=0.5),),
        seed=42)
    a = [bool(_fires(FaultInjector(plan), "cache.put", 1))
         for _ in range(1)]
    # same plan, same call order => identical decision streams
    one, two = FaultInjector(plan), FaultInjector(plan)
    seq1 = [bool(_fires(one, "cache.put", 1)) for _ in range(40)]
    seq2 = [bool(_fires(two, "cache.put", 1)) for _ in range(40)]
    assert seq1 == seq2
    assert any(seq1) and not all(seq1)    # the coin actually flips
    del a


def test_unknown_point_rejected_at_fire_time():
    inj = FaultInjector(FaultPlan())
    with pytest.raises(ValueError):
        inj.fire("engine.nope")
    with pytest.raises(ValueError):
        inj.corrupt("engine.nope", 1.0)


def test_trace_is_bounded_with_monotone_ids():
    inj = FaultInjector(
        FaultPlan(specs=(FaultSpec(point="cache.get", mode="fail"),)),
        trace_capacity=4)
    _fires(inj, "cache.get", 10)
    events = inj.events()
    assert len(events) == 4                       # bounded
    assert [e.id for e in events] == [7, 8, 9, 10]  # monotone, newest kept
    exp = inj.export()
    assert exp["plan_digest"] and exp["fired"] == [10]
    assert inj.summary()["fired_by_point"] == {"cache.get": 10}
    inj.reset()
    assert inj.events() == [] and inj.summary()["fired_by_point"] == {}


# ----------------------------------------------------------------------
# circuit breakers
# ----------------------------------------------------------------------
def test_breaker_honors_cooldown_with_fake_clock():
    clock = SimpleNamespace(t=0.0)
    br = CircuitBreaker(BreakerConfig(failure_threshold=2,
                                      cooldown_s=10.0),
                        clock=lambda: clock.t)
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed" and br.allow()    # below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clock.t = 9.9
    assert not br.allow()                          # cooldown not elapsed
    clock.t = 10.0
    assert br.allow() and br.state == "half_open"
    assert not br.allow()                          # one probe only
    br.record_failure()                            # probe failed
    assert br.state == "open" and not br.allow()
    clock.t = 25.0
    assert br.allow() and br.state == "half_open"
    br.record_success()
    assert br.state == "closed" and br.failures == 0 and br.allow()


def test_breaker_board_logs_transitions():
    clock = SimpleNamespace(t=0.0)
    board = BreakerBoard(BreakerConfig(failure_threshold=1,
                                       cooldown_s=5.0),
                         clock=lambda: clock.t)
    br = board.breaker("a" * 64, "jit")
    br.record_failure()
    clock.t = 6.0
    br.allow()
    br.record_success()
    transitions = [(e["from"], e["to"]) for e in board.events()]
    assert transitions == [("closed", "open"), ("open", "half_open"),
                           ("half_open", "closed")]
    snap = board.snapshot()
    assert snap["breakers"][f"{'a' * 12}/jit"]["state"] == "closed"


def test_validate_sims_flags_corrupt_output():
    prog = SimpleNamespace(kernel_id="k", port_bound_cycles=2.0)
    sim = lambda cpi: SimpleNamespace(cycles_per_iteration=cpi)  # noqa: E731
    assert validate_sims([sim(2.5)], [prog]) == []
    assert "non-finite" in validate_sims([sim(float("nan"))], [prog])[0]
    assert "negative" in validate_sims([sim(-1.0)], [prog])[0]
    assert "diverges above" in validate_sims([sim(2.0 * 51)], [prog])[0]
    assert "diverges below" in validate_sims([sim(2.0 / 51)], [prog])[0]
    # a zero analytic bound disables the divergence guard only
    free = SimpleNamespace(kernel_id="k", port_bound_cycles=0.0)
    assert validate_sims([sim(1000.0)], [free]) == []


# ----------------------------------------------------------------------
# engine: ladder, floor, provenance
# ----------------------------------------------------------------------
def _sim_reqs(scheduler: str = "uniform") -> list[AnalysisRequest]:
    return [AnalysisRequest(kernel=src, arch=arch, mode="simulate",
                            scheduler=scheduler)
            for arch, src in (("skl", pk.TRIAD_SKL_O3),
                              ("zen", pk.TRIAD_ZEN_O3),
                              ("skl", pk.PI_O2))]


def test_persistent_dispatch_fault_degrades_to_analytic_floor():
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail"),))
    svc = AnalysisService(sim_backend="numpy", faults=plan,
                          breaker_config=BreakerConfig(
                              failure_threshold=1, cooldown_s=3600.0))
    results = svc.predict_batch(_sim_reqs())
    clean = AnalysisService()
    for req, res in zip(_sim_reqs(), results):
        assert res.degraded and res.backend_used == "analytic"
        assert res.fault_trace_id > 0
        assert res.bound_sim == 0.0 and res.sim_result is None
        assert math.isfinite(res.predicted_cycles)
        # the floor is the analytic bound, bit-identical to a clean
        # analytic-mode prediction of the same cell
        ana = clean.predict(dataclasses.replace(req, mode="analytic"))
        assert res.predicted_cycles == ana.predicted_cycles
        assert res.binding == ana.binding
    assert svc.stats.degraded_results >= len(results)
    assert svc.faults.summary()["fired_by_point"]["engine.dispatch"] >= 1
    # the numpy breaker opened for both machine models
    states = {k: v["state"]
              for k, v in svc.breakers.snapshot()["breakers"].items()}
    assert states and all(s == "open" for s in states.values())


@needs_jax
def test_jit_failure_demotes_to_numpy_bit_identically():
    reqs = [AnalysisRequest(kernel=src, arch=arch, mode="simulate")
            for arch, src in (("skl", pk.TRIAD_SKL_O3),
                              ("zen", pk.TRIAD_ZEN_O3)) for _ in range(1)]
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": "jit"}),))
    faulty = AnalysisService(sim_backend="jit", faults=plan)
    degraded = faulty.predict_batch(reqs)
    clean = AnalysisService(sim_backend="numpy").predict_batch(reqs)
    for d, c in zip(degraded, clean):
        assert d.degraded and d.backend_used == "numpy"
        assert d.fault_trace_id > 0
        assert d.bound_sim == c.bound_sim        # numpy rung answered
        assert d.predicted_cycles == c.predicted_cycles


def test_corrupt_backend_output_is_caught_by_validator():
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="corrupt",
                  corrupt="nan"),))
    svc = AnalysisService(sim_backend="numpy", faults=plan,
                          breaker_config=BreakerConfig(
                              failure_threshold=1, cooldown_s=3600.0))
    results = svc.predict_batch(_sim_reqs())
    assert all(r.degraded for r in results)
    assert all(math.isfinite(r.predicted_cycles) for r in results)
    assert all(r.bound_sim >= 0.0 for r in results)


def test_single_predict_tick_fault_falls_to_floor():
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": "tick"}),))
    svc = AnalysisService(faults=plan)
    res = svc.predict(AnalysisRequest(kernel=pk.PI_O2, arch="skl",
                                      mode="simulate"))
    assert res.degraded and res.backend_used == "analytic"
    assert res.bound_sim == 0.0 and math.isfinite(res.predicted_cycles)
    assert svc.stats.degraded_results == 1


def test_compile_fault_degrades_only_affected_cells():
    # the first compile dies once; the ladder floor answers that cell,
    # every other cell is full fidelity
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.compile", mode="fail_once"),))
    svc = AnalysisService(sim_backend="numpy", faults=plan)
    results = svc.predict_batch(_sim_reqs())
    flags = [r.degraded for r in results]
    assert flags.count(True) == 1
    assert all(math.isfinite(r.predicted_cycles) for r in results)


def test_disarmed_plan_is_bit_identical_to_no_plan():
    baseline = AnalysisService(sim_backend="numpy")
    armed_empty = AnalysisService(sim_backend="numpy",
                                  faults=FaultPlan())
    # cache-layer faults must never touch engine results either
    reqs = _sim_reqs() + [AnalysisRequest(kernel=pk.PI_O1, arch="skl")]
    a = baseline.predict_batch(reqs)
    b = armed_empty.predict_batch(reqs)
    for x, y in zip(a, b):
        assert x.predicted_cycles == y.predicted_cycles
        assert x.bound_sim == y.bound_sim
        assert x.binding == y.binding
        assert not y.degraded and y.fault_trace_id == 0
    assert armed_empty.faults.events() == []


# ----------------------------------------------------------------------
# crash-safe resume
# ----------------------------------------------------------------------
def test_killed_sweep_resumes_bit_identical(tmp_path):
    sweep_kw = dict(archs=("skl", "zen"), schedulers=("uniform",),
                    mode="simulate")
    reference = AnalysisService(sim_backend="numpy").sweep(
        KERNELS, **sweep_kw)

    # the second machine-group dispatch dies like a SIGKILL
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="abort", skip=1),))
    killed = AnalysisService(sim_backend="numpy", faults=plan)
    with pytest.raises(FaultAbort):
        killed.sweep(KERNELS, journal=str(tmp_path), **sweep_kw)

    resumed_svc = AnalysisService(sim_backend="numpy")
    resumed = resumed_svc.sweep(KERNELS, journal=str(tmp_path),
                                resume_from=str(tmp_path), **sweep_kw)
    assert set(resumed) == set(reference)
    for k in reference:
        assert resumed[k].predicted_cycles == reference[k].predicted_cycles
        assert resumed[k].bound_sim == reference[k].bound_sim
        assert resumed[k].binding == reference[k].binding
        assert resumed[k].sim_result.cycles_per_iteration == \
            reference[k].sim_result.cycles_per_iteration
    # exactly one group replayed from the journal, one dispatched live
    assert resumed_svc.stats.journal_hits == 1
    assert resumed_svc.stats.sim_group_dispatches == 1


def test_resume_ignores_foreign_plan_and_torn_records(tmp_path):
    from repro.checkpoint.store import RecordJournal

    sweep_kw = dict(archs=("skl",), schedulers=("uniform",),
                    mode="simulate")
    first = AnalysisService(sim_backend="numpy")
    ref = first.sweep(KERNELS, journal=str(tmp_path), **sweep_kw)

    # crash debris: a stray tmp file and a torn (truncated) record
    (tmp_path / "rec_0000000099.json.tmp").write_text("{", encoding="utf-8")
    (tmp_path / "rec_0000000042.json").write_text('{"plan": "x",',
                                                  encoding="utf-8")
    # a record for a *different* plan must be inert
    RecordJournal(str(tmp_path)).append(
        {"plan": "deadbeef", "machine": "m", "programs": ["p"],
         "backend_used": "numpy", "degraded": False, "sims": None})

    resumed_svc = AnalysisService(sim_backend="numpy")
    resumed = resumed_svc.sweep(KERNELS, resume_from=str(tmp_path),
                                **sweep_kw)
    assert resumed_svc.stats.journal_hits == 1     # only the real record
    assert resumed_svc.stats.sim_group_dispatches == 0
    for k in ref:
        assert resumed[k].predicted_cycles == ref[k].predicted_cycles
        assert resumed[k].bound_sim == ref[k].bound_sim


def test_record_journal_append_is_atomic_and_ordered(tmp_path):
    from repro.checkpoint.store import RecordJournal

    j = RecordJournal(str(tmp_path))
    j.append({"n": 1})
    j.append({"n": 2})
    assert [r["n"] for r in j.records()] == [1, 2]
    assert not list(Path(tmp_path).glob("*.tmp"))  # no debris on success
    j.clear()
    assert j.records() == []


# ----------------------------------------------------------------------
# cache invalidation on model re-registration
# ----------------------------------------------------------------------
def _slowed(model):
    """The same machine with every uop port pressure doubled — the
    port bound doubles, so any stale cache entry is immediately visible
    as an unchanged prediction."""
    forms = tuple(dataclasses.replace(
        f, uops=tuple(dataclasses.replace(u, cycles=u.cycles * 2)
                      for u in f.uops))
        for f in model.forms)
    return model.derive(model.arch_id, forms=forms)


def test_reregistration_never_serves_stale_predictions():
    svc = AnalysisService()
    req = AnalysisRequest(kernel=pk.TRIAD_SKL_O3, arch="skl")
    before = svc.predict(req)
    # mutate the registry *directly* (not through svc.register, which
    # invalidates eagerly): only the epoch check protects this path
    model = svc.registry.model("skl")
    svc.registry.register(_slowed(model), replace=True)
    after = svc.predict(req)
    assert after.predicted_cycles != before.predicted_cycles
    assert after.predicted_cycles > before.predicted_cycles


def test_service_cache_invalidated_on_reregistration():
    from repro.service import (PredictionService, ServiceRequest,
                               replay)

    svc = PredictionService()
    req = ServiceRequest(analysis=AnalysisRequest(
        kernel=pk.TRIAD_SKL_O3, arch="skl"))
    [first] = replay(svc, [(0.0, req)])
    assert first.ok
    [warm] = replay(svc, [(0.0, req)])
    assert warm.cache_hit            # the TTL cache is working...
    model = svc.engine.registry.model("skl")
    svc.engine.registry.register(_slowed(model), replace=True)
    [fresh] = replay(svc, [(0.0, req)])
    assert fresh.ok and not fresh.cache_hit   # ...and was dropped
    assert fresh.result.predicted_cycles > first.result.predicted_cycles


# ----------------------------------------------------------------------
# service under faults: deadlines, cancellation
# ----------------------------------------------------------------------
def test_deadline_expired_member_dropped_under_dispatch_latency():
    from repro.service import (DeadlineExceeded, PredictionService,
                               ServiceConfig, ServiceRequest, replay)

    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="latency",
                  delay_s=0.5, count=1),))
    engine = AnalysisService(faults=plan)
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.01, dispatch_timeout_s=30.0))
    # request 2 lands while the dispatcher is stuck in request 1's
    # delayed dispatch; its 0.05s deadline expires in the queue
    traffic = [
        (0.0, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.PI_O1, arch="skl", mode="simulate"))),
        (0.1, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.PI_O2, arch="zen", mode="simulate"),
            timeout_s=0.05)),
    ]
    r1, r2 = replay(svc, traffic)
    assert r1.ok and not r1.degraded
    assert isinstance(r2.error, DeadlineExceeded)
    assert svc.telemetry.tenant("default").deadline_exceeded == 1
    assert engine.faults.summary()["fired_by_point"] == \
        {"engine.dispatch": 1}


def test_predict_async_cancellation_under_latency():
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="latency",
                  delay_s=0.4, count=1),))
    svc = AnalysisService(faults=plan)
    req = AnalysisRequest(kernel=pk.PI_O1, arch="skl", mode="simulate")

    async def go():
        task = asyncio.ensure_future(svc.predict_async(req))
        await asyncio.sleep(0.05)
        task.cancel()
        with pytest.raises(asyncio.CancelledError):
            await task
        # the abandoned executor call completes in the background and
        # fills the caches; a re-await is served without re-faulting
        return await svc.predict_async(req)

    res = asyncio.run(go())
    assert res.bound_sim > 0 and not res.degraded
    assert svc.faults.summary()["fired_by_point"] == \
        {"engine.dispatch": 1}


def test_predict_async_timeout_then_retry_succeeds():
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="latency",
                  delay_s=0.4, count=1),))
    svc = AnalysisService(faults=plan)
    req = AnalysisRequest(kernel=pk.PI_O1, arch="skl", mode="simulate")

    async def go():
        return await svc.predict_async(req, timeout=0.1, retries=2,
                                       backoff_s=0.01)

    res = asyncio.run(go())
    assert res.bound_sim > 0 and not res.degraded


# ----------------------------------------------------------------------
# model artifact lint (tools/check_models.py hardening)
# ----------------------------------------------------------------------
def _load_check_models():
    import importlib.util
    path = Path(__file__).resolve().parent.parent / "tools" / \
        "check_models.py"
    spec = importlib.util.spec_from_file_location("check_models_tool",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_models_rejects_nan_and_negative_constants():
    tool = _load_check_models()
    from repro.core.arch.registry import default_registry

    model = default_registry().model("skl")
    errs: list[str] = []
    tool.check_numbers(model, "skl", errs)
    assert errs == []                      # shipped artifact is clean

    f0 = dataclasses.replace(model.forms[0], latency=float("nan"))
    u0 = dataclasses.replace(model.forms[1].uops[0], cycles=-2.0)
    f1 = dataclasses.replace(model.forms[1],
                             uops=(u0,) + model.forms[1].uops[1:])
    lv = dataclasses.replace(model.hierarchy.levels[0],
                             load_bw=float("nan"))
    hz = dataclasses.replace(model.hierarchy,
                             levels=(lv,) + model.hierarchy.levels[1:])
    bad = model.derive(model.arch_id,
                       forms=(f0, f1) + model.forms[2:], hierarchy=hz)
    errs = []
    tool.check_numbers(bad, "bad", errs)
    text = "\n".join(errs)
    assert "latency" in text
    assert "port pressure" in text
    assert "hierarchy level 0" in text
    assert len(errs) == 3


# ----------------------------------------------------------------------
# the schedule property: no request ever hangs or vanishes
# ----------------------------------------------------------------------
_POINTS = [p for p in FAULT_POINTS]
_MODES = ["fail", "fail_once", "fail_n", "latency", "corrupt"]

_spec_st = st.builds(
    FaultSpec,
    point=st.sampled_from(_POINTS),
    mode=st.sampled_from(_MODES),
    count=st.one_of(st.none(), st.integers(min_value=1, max_value=3)),
    skip=st.integers(min_value=0, max_value=2),
    delay_s=st.just(0.01),
    corrupt=st.sampled_from(["nan", "negative"]),
    probability=st.sampled_from([0.5, 1.0]),
)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(st.lists(_spec_st, min_size=0, max_size=4),
       st.integers(min_value=0, max_value=2**16))
def test_any_schedule_resolves_every_request_exactly_once(specs, seed):
    """Replay a fixed traffic mix under an arbitrary (non-abort) fault
    schedule: every request comes back exactly once, ``ok`` or a typed
    error — never dropped, never duplicated — and every ok result is
    finite."""
    from repro.service import (PredictionService, ServiceConfig,
                               ServiceRequest, replay)

    plan = FaultPlan(specs=tuple(specs), seed=seed)
    engine = AnalysisService(
        faults=plan,
        breaker_config=BreakerConfig(failure_threshold=1,
                                     cooldown_s=0.01))
    svc = PredictionService(engine, ServiceConfig(batch_window_s=0.005))
    traffic = [
        (0.0, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.PI_O1, arch="skl", mode="simulate"))),
        (0.0, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.PI_O1, arch="zen", mode="simulate"))),
        (0.01, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.PI_O2, arch="skl"))),
        (0.01, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.TRIAD_SKL_O3, arch="skl", mode="simulate",
            working_set=64.0 * 2**20))),
        (0.02, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.PI_O2, arch="skl"))),      # duplicate of #3
    ]
    try:
        resps = replay(svc, traffic)
        assert len(resps) == len(traffic)
        for r in resps:
            assert r is not None
            assert r.ok or r.error is not None
            if r.ok:
                assert math.isfinite(r.result.predicted_cycles)
                if r.degraded:
                    assert r.backend_used
    except Exception:
        _dump_trace(engine.faults)
        raise
