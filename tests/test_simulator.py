"""Cycle-level pipeline simulator (repro.core.sim): steady-state
convergence, bound relations against the analytic backends, degenerate
windows, the vectorized batch driver, the service's ``mode="simulate"``
path, and the schedule_balanced empty-port fix it builds on."""
import dataclasses

import pytest

from repro.core import (AnalysisRequest, AnalysisService, analyze,
                        extract_kernel)
from repro.core import paper_kernels as pk
from repro.core.arch.skylake import SKYLAKE, build_skylake_db
from repro.core.arch.zen import ZEN, build_zen_db
from repro.core.ports import PipelineParams, PortModel, U
from repro.core.scheduler import (SCHEDULERS, schedule_balanced,
                                  schedule_uniform)
from repro.core.sim import (DagNode, SimProgram, SimUop, compile_program,
                            frontend_schedule, schedule_dag, simulate,
                            simulate_many)

SKL = build_skylake_db()
ZENDB = build_zen_db()

PAPER_CASES = [
    ("skl", pk.TRIAD_SKL_O3), ("zen", pk.TRIAD_ZEN_O3),
    ("skl", pk.PI_O1), ("zen", pk.PI_O1),
    ("skl", pk.PI_O2), ("zen", pk.PI_O2),
    ("skl", pk.PI_SKL_O3), ("zen", pk.PI_ZEN_O3),
]


def _db(arch):
    return SKL if arch == "skl" else ZENDB


# ------------------------------------------------------------------ #
# Steady-state convergence + bound relations on the paper kernels
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch,src", PAPER_CASES)
def test_paper_kernels_converge(arch, src):
    res = simulate(compile_program(extract_kernel(src), _db(arch)))
    assert res.converged, res
    assert res.cycles_per_iteration > 0
    assert res.bottleneck in ("frontend", "ports", "dependencies")


@pytest.mark.parametrize("arch,src", PAPER_CASES)
def test_sim_respects_analytic_lower_bounds(arch, src):
    """The simulation can refine the *uniform* port bound downwards
    (discrete dispatch beats averaging — the paper's own Table VII
    remark), but it can never beat the LCD bound or the optimal
    (balanced-LP) port bound, and it may only exceed the analytic
    combination through front-end / finite-window effects."""
    db = _db(arch)
    kern = extract_kernel(src)
    ana = analyze(kern, db)
    bal = analyze(kern, db, scheduler="balanced")
    prog = compile_program(kern, db)
    sim = simulate(prog).cycles_per_iteration
    assert sim >= ana.lcd_cycles - 1e-6
    assert sim >= bal.port_bound_cycles - 1e-6
    # upper side: bounded by resources + chain + integer-cycle rounding
    ceiling = max(ana.port_bound_cycles, ana.lcd_cycles,
                  prog.frontend_cycles)
    assert sim <= ceiling * 1.15 + 1.0


def test_acceptance_dependency_free_and_lcd_bound_within_15pct():
    """ISSUE acceptance: one dependency-free and one LCD-bound paper
    kernel simulate within 15% of the analytic prediction they refine."""
    # dependency-free: Zen -O3 triad (analytic combined bound 2.00)
    triad = analyze(extract_kernel(pk.TRIAD_ZEN_O3), ZENDB)
    sim_t = simulate(compile_program(extract_kernel(pk.TRIAD_ZEN_O3),
                                     ZENDB)).cycles_per_iteration
    assert triad.binding == "throughput"
    assert abs(sim_t - triad.predicted_cycles) / triad.predicted_cycles \
        <= 0.15
    # LCD-bound: pi -O1 on Skylake (analytic combined bound 9.00)
    pi = analyze(extract_kernel(pk.PI_O1), SKL)
    sim_p = simulate(compile_program(extract_kernel(pk.PI_O1),
                                     SKL)).cycles_per_iteration
    assert pi.binding == "latency"
    assert abs(sim_p - pi.predicted_cycles) / pi.predicted_cycles <= 0.15


def test_pi_o1_simulation_matches_measurement():
    """The simulator reproduces the store->load chain pacing that the
    paper could only measure (9.02 cy/it on SKL, 11.48 on Zen)."""
    skl = simulate(compile_program(extract_kernel(pk.PI_O1), SKL))
    assert skl.cycles_per_iteration == pytest.approx(9.0)
    assert skl.bottleneck == "dependencies"
    zen = simulate(compile_program(extract_kernel(pk.PI_O1), ZENDB))
    assert abs(zen.cycles_per_iteration - 11.48) / 11.48 < 0.1


def test_frontend_binds_wide_kernel():
    """More uops than the issue width can sustain at the port bound:
    the simulated steady state sits at the front-end bound, above the
    analytic prediction (the uiCA-motivated gap).  With the SKL
    front-end model, micro-fusion packs the 9 uops into 7 issue slots
    (fused loads + split store), so the bound drops from 9/4 to 7/4
    and the steady state lands on the 2.0-cycle port bound."""
    prog = compile_program(extract_kernel(pk.TRIAD_SKL_O3), SKL)
    res = simulate(prog)
    assert res.frontend_cycles == pytest.approx(7 / 4)
    assert res.cycles_per_iteration >= res.frontend_cycles
    assert res.cycles_per_iteration == pytest.approx(2.0)
    assert res.bottleneck == "frontend"
    # with every front-end feature off, one uop is one slot again and
    # the pre-front-end bound (and steady state) come back exactly
    off = dataclasses.replace(
        res.params, predecode_width=0, decode_width=0,
        complex_decode_width=1, dsb_width=0, dsb_size=0, lsd_size=0,
        macro_fusion=False, micro_fusion=False, move_elimination=False,
        mispredict_penalty=0.0)
    res_off = simulate(prog, off)
    assert frontend_schedule(prog, off).n_slots == 9
    assert res_off.cycles_per_iteration == pytest.approx(2.5)


# ------------------------------------------------------------------ #
# Degenerate cases
# ------------------------------------------------------------------ #
def test_empty_kernel():
    res = simulate(compile_program([], SKL))
    assert res.cycles_per_iteration == 0.0
    assert res.converged and res.bottleneck == "empty"


def test_branch_only_kernel_has_no_uops():
    kern = extract_kernel(pk.marked(".L1:\n        jne .L1\n"))
    prog = compile_program(kern, SKL)
    assert not prog.uops
    assert simulate(prog).cycles_per_iteration == 0.0


def test_single_uop_kernel():
    kern = extract_kernel(pk.marked("""
.L1:
        vmulsd  %xmm1, %xmm2, %xmm3
        jne     .L1
"""))
    res = simulate(compile_program(kern, SKL))
    assert res.converged
    # one 2-port uop per iteration: dispatches every other half... the
    # steady state is one uop per cycle at worst
    assert res.cycles_per_iteration <= 1.0 + 1e-9


def test_rob_of_size_one_serializes():
    params = PipelineParams(issue_width=1, rob_size=1,
                            scheduler_size=1, retire_width=1)
    kern = extract_kernel(pk.marked("""
.L1:
        vmulsd  %xmm1, %xmm2, %xmm3
        vmulsd  %xmm4, %xmm5, %xmm6
        jne     .L1
"""))
    res = simulate(compile_program(kern, SKL), params=params,
                   max_iterations=16)
    # each uop must retire (latency 4) before the next can issue
    assert res.cycles_per_iteration >= 8.0
    assert res.converged


def test_window_params_matter():
    """Shrinking the scheduler window can only slow the kernel down."""
    prog = compile_program(extract_kernel(pk.PI_SKL_O3), SKL)
    wide = simulate(prog)
    narrow = simulate(prog, params=PipelineParams(
        issue_width=4, rob_size=16, scheduler_size=4, retire_width=4))
    assert narrow.cycles_per_iteration >= wide.cycles_per_iteration - 1e-9


# ------------------------------------------------------------------ #
# Vectorized batch driver
# ------------------------------------------------------------------ #
def test_batch_matches_scalar_on_paper_kernels():
    progs = [compile_program(extract_kernel(src), _db(arch))
             for arch, src in PAPER_CASES]
    batch = simulate_many(progs)
    for prog, br in zip(progs, batch):
        sr = simulate(prog)
        assert br.converged
        # same steady state up to one discrete-dispatch bubble
        assert abs(br.cycles_per_iteration - sr.cycles_per_iteration) \
            <= max(0.26, 0.1 * sr.cycles_per_iteration), \
            (br.cycles_per_iteration, sr.cycles_per_iteration)


def test_batch_respects_zero_uop_producer_chains():
    """An unmatched instruction (zero uops, latency 1) in the middle of
    a loop-carried chain must not erase the chain in the vectorized
    driver: its edges are composed away at pack time."""
    prog = SimProgram(
        model=SKL.model, n_instructions=3,
        uops=(SimUop(0, ("0", "1"), 1.0), SimUop(2, ("0", "1"), 1.0)),
        latency=(3.0, 1.0, 3.0),
        edges=((0, 1, 3.0, False),    # instr0 -> zero-uop instr1
               (1, 2, 1.0, False),    # zero-uop instr1 -> instr2
               (2, 0, 3.0, True)))    # wrap: chain length 3+1+3 = 7
    scalar = simulate(prog)
    batch, = simulate_many([prog])
    assert scalar.cycles_per_iteration == pytest.approx(7.0)
    assert batch.cycles_per_iteration == pytest.approx(
        scalar.cycles_per_iteration)
    assert batch.bottleneck == "dependencies"


def test_batch_groups_mixed_architectures():
    progs = [compile_program(extract_kernel(pk.PI_O1), SKL),
             compile_program(extract_kernel(pk.PI_O1), ZENDB),
             compile_program([], SKL)]
    out = simulate_many(progs)
    assert out[0].cycles_per_iteration == pytest.approx(9.0)
    assert out[1].cycles_per_iteration >= 11.0
    assert out[2].bottleneck == "empty"


# ------------------------------------------------------------------ #
# AnalysisService mode="simulate"
# ------------------------------------------------------------------ #
def test_service_simulate_mode_and_cache_hit():
    svc = AnalysisService()
    req = AnalysisRequest(kernel=pk.PI_O1, arch="skl", mode="simulate")
    r1 = svc.predict(req)
    assert r1.bound_sim == pytest.approx(9.0)
    assert r1.sim_result is not None and r1.sim_result.converged
    assert r1.predicted_cycles == pytest.approx(9.0)
    assert svc.stats.sim_runs == 1
    r2 = svc.predict(req)
    assert r2 is r1                      # result-cache hit
    assert svc.stats.sim_runs == 1       # simulator not re-run
    assert svc.stats.result_hits == 1
    # the analytic cell is shared: an analytic request hits the cache
    ra = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch="skl"))
    assert ra.bound_sim == 0.0 and ra.sim_result is None


def test_service_simulate_three_way_binding():
    svc = AnalysisService()
    # window effects: sim above both analytic bounds -> "simulation"
    # (triad no longer qualifies — micro-fusion drops its issue bound
    # below the port bound, so the sim agrees with the analytic 2.0)
    r = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch="zen",
                                    mode="simulate"))
    assert r.binding == "simulation"
    assert r.bound_sim > max(r.port_bound_cycles, r.lcd_cycles)
    assert "Simulated (cycle-level)" in r.render()
    rt = svc.predict(AnalysisRequest(kernel=pk.TRIAD_SKL_O3, arch="skl",
                                     unroll_factor=4, mode="simulate"))
    assert rt.binding == "throughput"
    assert rt.bound_sim == pytest.approx(
        max(rt.port_bound_cycles, rt.lcd_cycles))
    # LCD bound: the simulation agrees with the latency constraint
    r2 = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch="skl",
                                     mode="simulate"))
    assert r2.binding == "latency"
    # sim below the uniform port bound (discrete dispatch beats the
    # averaging, paper Sec. III-B): the deviation is also "simulation"
    r3 = svc.predict(AnalysisRequest(kernel=pk.PI_O2, arch="skl",
                                     mode="simulate"))
    assert r3.bound_sim < r3.port_bound_cycles
    assert r3.binding == "simulation"
    assert r3.predicted_cycles == pytest.approx(r3.bound_sim)


def test_service_simulate_through_batch_and_sweep():
    svc = AnalysisService()
    out = svc.predict_batch([
        AnalysisRequest(kernel=pk.PI_O1, arch="skl", mode="simulate"),
        AnalysisRequest(kernel=pk.PI_O2, arch="skl", mode="simulate")])
    assert all(o.sim_result is not None for o in out)
    grid = svc.sweep({"pi_o1": pk.PI_O1}, archs=("skl", "zen"),
                     mode="simulate")
    assert len(grid) == 2
    assert all(r.bound_sim > 0 for r in grid.values())


def test_service_rejects_unknown_mode():
    svc = AnalysisService()
    with pytest.raises(ValueError, match="unknown mode"):
        svc.predict(AnalysisRequest(kernel=pk.PI_O1, mode="emulate"))
    with pytest.raises(ValueError, match="unknown mode"):
        svc.predict_hlo("HloModule m", mode="emulate")


def test_simulation_cache_is_scheduler_free():
    """The tick-loop ignores the analytic scheduler knob, so a
    multi-scheduler sweep must run each (arch, kernel) simulation once."""
    svc = AnalysisService()
    svc.sweep({"pi_o1": pk.PI_O1}, archs=("skl",),
              schedulers=("uniform", "balanced"), mode="simulate")
    assert svc.stats.sim_runs == 1


# ------------------------------------------------------------------ #
# schedule_balanced / schedule_uniform empty-port fix
# ------------------------------------------------------------------ #
_EMPTY_MODEL = PortModel(name="test", ports=("0", "1"))


def test_uniform_scheduler_handles_empty_port_uops():
    from repro.core.ports import Uop as RealUop
    out = schedule_uniform(_EMPTY_MODEL,
                           [(0, U("0")), (1, RealUop(ports=()))])
    assert out[0].assignment == {"0": 1.0}
    assert out[1].assignment == {}


def test_balanced_scheduler_handles_all_empty_port_uops():
    from repro.core.ports import Uop as RealUop
    uops = [(i, RealUop(ports=())) for i in range(3)]
    out = schedule_balanced(_EMPTY_MODEL, uops)   # crashed before the fix
    assert len(out) == 3
    assert all(s.assignment == {} for s in out)


def test_balanced_scheduler_mixed_empty_and_routable():
    from repro.core.ports import Uop as RealUop
    uops = [(0, RealUop(ports=())), (1, U("0|1")), (2, U("0|1")),
            (3, RealUop(ports=()))]
    out = schedule_balanced(_EMPTY_MODEL, uops)
    assert len(out) == 4
    by_idx = {s.instr_index: s for s in out}
    assert by_idx[0].assignment == {} and by_idx[3].assignment == {}
    total = sum(sum(s.assignment.values()) for s in out)
    assert total == pytest.approx(2.0)
    # min-max load is 1.0 per port
    loads = {"0": 0.0, "1": 0.0}
    for s in out:
        for p, c in s.assignment.items():
            loads[p] += c
    assert max(loads.values()) == pytest.approx(1.0, abs=1e-6)


def test_balanced_scheduler_results_unchanged_by_memoization():
    """The deque/memo rework must not change any LP solution."""
    kern = extract_kernel(pk.PI_O2)
    res = analyze(kern, SKL, scheduler="balanced")
    # optimal min-max load for pi -O2 is 4.0 (paper Sec. III-B: the
    # averaged model's 4.25 is not a strict lower bound)
    assert res.port_bound_cycles == pytest.approx(4.0, abs=0.01)


# ------------------------------------------------------------------ #
# DAG scheduler (HLO/TPU path)
# ------------------------------------------------------------------ #
def test_schedule_dag_bounds():
    nodes = [
        DagNode("a", {"MXU": 2.0, "HBM": 1.0}),
        DagNode("b", {"MXU": 2.0}),
        DagNode("c", {"HBM": 3.0}, deps=("a",)),
    ]
    sched = schedule_dag(nodes)
    overlap = 4.0        # MXU total
    critical = 2.0 + 3.0  # a -> c
    serial = 8.0
    assert sched.makespan >= max(overlap, critical) - 1e-12
    assert sched.makespan <= serial + 1e-12
    assert sched.bottleneck_port in ("MXU", "HBM")


def test_schedule_dag_empty():
    assert schedule_dag([]).makespan == 0.0


_HLO_CHAIN = """
HloModule test, entry_computation_layout={()->f32[2048,2048]{1,0}}

ENTRY %main.1 () -> f32[2048,2048] {
  %a = f32[2048,2048]{1,0} constant({...})
  %d = f32[2048,2048]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %s = f32[2048,2048]{1,0} add(%d, %d)
}
"""


def test_predict_hlo_simulate_mode():
    svc = AnalysisService()
    ana = svc.predict_hlo(_HLO_CHAIN)
    sim = svc.predict_hlo(_HLO_CHAIN, mode="simulate")
    assert ana.terms.sim_s == 0.0
    assert ana.terms.bound_sim == ana.terms.bound_combined
    assert sim.terms.sim_s > 0.0
    assert sim.terms.bound_sim >= sim.terms.bound_combined - 1e-15
    assert sim.terms.bound_sim <= sim.terms.bound_serial * (1 + 1e-9)
    assert "scheduled" in sim.render()
    # distinct cache cells, both memoized
    assert svc.predict_hlo(_HLO_CHAIN) is ana
    assert svc.predict_hlo(_HLO_CHAIN, mode="simulate") is sim
