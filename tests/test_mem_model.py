"""Property and unit tests for the ECM memory-hierarchy backend.

Covers the four layers of ``repro.core.mem``:

* stream extraction from parsed kernels (strides, widths, load/store
  classification, the stride-0 scalar-spill case),
* the two interchangeable traffic estimators — the analytic
  layer-condition/streaming model and the LRU set-associative cache
  simulator — which must agree within 5% on randomized streaming
  patterns (hypothesis),
* the ECM composition through the engine: ``working_set <= L1`` must
  reproduce every in-core bound *bit-exactly* under both estimators,
  and predictions must be monotone in the working set,
* ``MachineModel`` integration: hierarchy serialization round-trips,
  ``derive`` preserves it, the digest keys on it, and
  ``tools/check_models.py`` enumerates malformed hierarchy artifacts.
"""
import importlib.util
from pathlib import Path

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional [dev] dependency
    from repro.testing import given, settings, st

from repro.core import (AnalysisRequest, AnalysisService, MachineModel,
                        default_service, extract_kernel, get_model,
                        parse_assembly)
from repro.core import paper_kernels as pk
from repro.core.mem import (AccessStream, CacheLevel, MemoryHierarchy,
                            compose_ecm, extract_streams, predict_traffic,
                            simulate_traffic)

SERVICE = default_service()

# small toy hierarchy so the cache simulator's measuring pass is cheap
TOY_HZ = MemoryHierarchy(levels=(
    CacheLevel("L1", 4096, ways=4, load_bw=0.5, store_bw=1.0),
    CacheLevel("L2", 16384, ways=8, load_bw=1.0, store_bw=2.0),
    CacheLevel("MEM", None, ways=1, load_bw=4.0, store_bw=4.0),
))

PAPER_CASES = (
    ("skl", pk.TRIAD_SKL_O3, 4), ("zen", pk.TRIAD_ZEN_O3, 2),
    ("skl", pk.PI_O1, 1), ("skl", pk.PI_O2, 1), ("skl", pk.PI_SKL_O3, 8),
    ("zen", pk.PI_O1, 1), ("zen", pk.PI_O2, 1), ("zen", pk.PI_ZEN_O3, 2),
)


# ------------------------------------------------------------------ #
# stream extraction
# ------------------------------------------------------------------ #
def test_triad_skl_streams():
    """The -O3 SKL triad walks four ymm streams at 32 B/iteration:
    three loads (b, c, d) and one store (a)."""
    kernel = extract_kernel(pk.TRIAD_SKL_O3)
    streams = extract_streams(kernel)
    assert len(streams) == 4
    assert all(s.stride == 32.0 and s.width == 32 for s in streams)
    assert sum(s.has_store for s in streams) == 1
    assert sum(s.has_load and not s.has_store for s in streams) == 3


def test_pi_o1_scalar_spill_is_stride_zero():
    """pi -O1 keeps the accumulator in a (%rsp) slot: one read-modify-
    write stream that never advances — no cache traffic at any level."""
    kernel = extract_kernel(pk.PI_O1)
    streams = extract_streams(kernel)
    assert any(s.stride == 0.0 and s.has_load and s.has_store
               for s in streams)
    assert all(s.lines_per_iteration(64) == 0.0 for s in streams
               if s.stride == 0.0)


def test_store_vs_rmw_classification():
    """A mov-family memory destination is a plain store; any other
    memory destination is read-modify-write (load + store)."""
    plain = extract_streams(parse_assembly(
        "vmovapd %ymm0, (%r14)\nadd $32, %r14"))
    assert plain[0].has_store and not plain[0].has_load
    rmw = extract_streams(parse_assembly(
        "addq $1, (%r14)\nadd $8, %r14"))
    assert rmw[0].has_store and rmw[0].has_load


def test_unrolled_displacements_are_one_stream():
    """Distinct displacements off one (base, index, scale) expression
    are a single stream with several accesses per iteration."""
    src = ("vmovapd (%r13), %ymm0\n"
           "vmovapd 32(%r13), %ymm1\n"
           "add $64, %r13")
    streams = extract_streams(parse_assembly(src))
    assert len(streams) == 1
    assert streams[0].n_accesses == 2
    assert streams[0].stride == 64.0
    assert streams[0].lines_per_iteration(64) == 1.0


def test_sparse_stream_opens_one_line_per_access():
    """A stride past the span of its accesses touches at most
    n_accesses fresh lines per iteration, not stride/line."""
    s = AccessStream(base="r8", index=None, scale=1, stride=4096.0,
                     width=8, n_accesses=1, has_load=True,
                     has_store=False)
    assert s.lines_per_iteration(64) == 1.0


# ------------------------------------------------------------------ #
# traffic estimators: analytic vs cache simulator
# ------------------------------------------------------------------ #
_stream_strategy = st.builds(
    lambda i, width, n_acc, kind: AccessStream(
        base=f"r{i}", index=None, scale=1,
        stride=float(width * n_acc), width=width, n_accesses=n_acc,
        has_load=kind in ("load", "both"),
        has_store=kind in ("store", "both")),
    st.integers(0, 7), st.sampled_from([8, 16, 32, 64]),
    st.integers(1, 4), st.sampled_from(["load", "store", "both"]))


@settings(max_examples=40, deadline=None)
@given(streams=st.lists(_stream_strategy, min_size=1, max_size=4,
                        unique_by=lambda s: s.base),
       working_set=st.sampled_from([2048.0, 8192.0, 65536.0]))
def test_analytic_agrees_with_cachesim(streams, working_set):
    """The acceptance criterion: on streaming patterns the analytic
    layer-condition model and the LRU cache simulator agree within 5%
    on total transfer cycles, and per-link within half a line."""
    analytic = predict_traffic(tuple(streams), TOY_HZ, working_set)
    sim = simulate_traffic(tuple(streams), TOY_HZ, working_set)
    ta, ts = analytic.transfer_cycles, sim.transfer_cycles
    assert analytic.resident == sim.resident
    if ta == ts == 0.0:
        return
    assert abs(ta - ts) / max(ta, ts) <= 0.05, (ta, ts, streams)


def test_estimators_bit_equal_on_the_paper_triads():
    """On the actual paper kernels (pure unit-stride streaming) the two
    estimators agree to the digit at every hierarchy level."""
    for arch, src, unroll in (("skl", pk.TRIAD_SKL_O3, 4),
                              ("zen", pk.TRIAD_ZEN_O3, 2)):
        hz = get_model(arch).hierarchy
        streams = extract_streams(parse_assembly(src))
        for ws in (16e3, 128e3, 2e6, 64e6):
            a = predict_traffic(streams, hz, ws)
            s = simulate_traffic(streams, hz, ws)
            assert a.transfer_cycles == pytest.approx(
                s.transfer_cycles, abs=1e-9), (arch, ws)


def test_write_allocate_doubles_store_stream_load_traffic():
    """With write-allocate a store-only stream loads every line before
    writing it back; without, it streams straight through."""
    store = (AccessStream(base="r8", index=None, scale=1, stride=64.0,
                          width=64, n_accesses=1, has_load=False,
                          has_store=True),)
    wa = predict_traffic(store, TOY_HZ, 8192.0)
    assert wa.levels[0].load_lines == 1.0    # allocate
    assert wa.levels[0].store_lines == 1.0   # write-back
    no_wa = MemoryHierarchy(levels=(
        CacheLevel("L1", 4096, ways=4, write_allocate=False),
        CacheLevel("MEM", None, ways=1),
    ))
    nt = predict_traffic(store, no_wa, 8192.0)
    assert nt.levels[0].load_lines == 0.0
    assert nt.levels[0].store_lines == 1.0


# ------------------------------------------------------------------ #
# ECM composition through the engine
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("traffic_model", ["analytic", "cachesim"])
@pytest.mark.parametrize("arch,src,unroll", PAPER_CASES)
def test_l1_working_set_is_bit_exact(arch, src, unroll, traffic_model):
    """working_set <= L1 ⇒ every existing bound is reproduced
    bit-for-bit under both traffic estimators: the hierarchy model
    degrades exactly to the paper's infinite-L1 assumption."""
    base = SERVICE.predict(AnalysisRequest(
        kernel=src, arch=arch, unroll_factor=unroll))
    res = SERVICE.predict(AnalysisRequest(
        kernel=src, arch=arch, unroll_factor=unroll,
        working_set=16.0 * 1024, traffic_model=traffic_model))
    assert res.predicted_cycles == base.predicted_cycles
    assert res.port_bound_cycles == base.port_bound_cycles
    assert res.lcd_cycles == base.lcd_cycles
    assert res.port_totals == base.port_totals
    assert res.binding == base.binding
    assert res.ecm_result is not None
    assert res.bound_ecm == base.predicted_cycles


def test_hierarchy_less_machine_ignores_working_set():
    """A model without a hierarchy (the paper's original assumption)
    silently skips the ECM composition — same result, no ecm_result."""
    svc = AnalysisService()
    svc.register(get_model("skl").derive("skl-nohz", hierarchy=None))
    res = svc.predict(AnalysisRequest(
        kernel=pk.TRIAD_SKL_O3, arch="skl-nohz", unroll_factor=4,
        working_set=64.0 * 2**20))
    base = SERVICE.predict(AnalysisRequest(
        kernel=pk.TRIAD_SKL_O3, arch="skl", unroll_factor=4))
    assert res.ecm_result is None
    assert res.bound_ecm == 0.0
    assert res.predicted_cycles == base.predicted_cycles


@settings(max_examples=25, deadline=None)
@given(sets=st.lists(st.floats(1024.0, 256.0 * 2**20), min_size=2,
                     max_size=6))
def test_ecm_monotone_in_working_set(sets):
    """Growing the working set can only add transfer terms: the ECM
    prediction is non-decreasing in the working set (both archs)."""
    for arch, src, unroll in (("skl", pk.TRIAD_SKL_O3, 4),
                              ("zen", pk.TRIAD_ZEN_O3, 2)):
        preds = [SERVICE.predict(AnalysisRequest(
            kernel=src, arch=arch, unroll_factor=unroll,
            working_set=ws)).bound_ecm for ws in sorted(sets)]
        assert preds == sorted(preds), (arch, sets, preds)


def test_ecm_sweep_shares_the_fast_path():
    """``sweep(working_set=...)`` rides the planner fast path: the ECM
    post-pass adds traffic-cache entries but zero extra sim dispatches
    relative to the same sweep without a working set."""
    svc = AnalysisService()
    kernels = {"triad": pk.TRIAD_SKL_O3, "pi": pk.PI_O1}
    svc.sweep(kernels, archs=("skl", "zen"), mode="simulate")
    before = (svc.stats.sim_runs, svc.stats.sim_group_dispatches)
    rows = svc.sweep(kernels, archs=("skl", "zen"), mode="simulate",
                     working_set=64.0 * 2**20)
    after = (svc.stats.sim_runs, svc.stats.sim_group_dispatches)
    assert after == before
    assert any(r.ecm_result is not None for r in rows.values())
    # the triad cells carry live ECM terms; pi's spill stream does not
    assert rows[("triad", "skl", "uniform")].binding == "memory"


def test_invalid_requests_are_rejected():
    with pytest.raises(ValueError):
        SERVICE.predict(AnalysisRequest(
            kernel=pk.PI_O1, arch="skl", working_set=-1.0))
    with pytest.raises(ValueError):
        SERVICE.predict(AnalysisRequest(
            kernel=pk.PI_O1, arch="skl", working_set=1024.0,
            traffic_model="psychic"))


def test_compose_ecm_rule():
    """cycles = max(T_incore, T_nOL + sum of link terms)."""
    t = predict_traffic(
        (AccessStream(base="r8", index=None, scale=1, stride=64.0,
                      width=64, n_accesses=1, has_load=True,
                      has_store=False),),
        TOY_HZ, 65536.0)
    ecm = compose_ecm(t_incore=2.0, t_nol=1.0, traffic=t)
    assert ecm.cycles == max(2.0, 1.0 + t.transfer_cycles)
    assert ecm.transfer_cycles == t.transfer_cycles
    assert ecm.notation().startswith("{2.00 || 1.00 | ")


# ------------------------------------------------------------------ #
# MachineModel integration: serialization, derive, digest, validation
# ------------------------------------------------------------------ #
_level_sets = st.lists(st.integers(2, 4096), min_size=2, max_size=4,
                       unique=True)
_bw = st.floats(0.25, 8.0)


@st.composite
def _hierarchies(draw):
    sets = sorted(draw(_level_sets))
    levels = []
    for i, n_sets in enumerate(sets[:-1]):
        levels.append(CacheLevel(
            name=f"L{i + 1}", size_bytes=64 * 8 * n_sets, ways=8,
            load_bw=draw(_bw), store_bw=draw(_bw),
            write_allocate=draw(st.booleans())))
    levels.append(CacheLevel(name="MEM", size_bytes=None, ways=1,
                             load_bw=draw(_bw), store_bw=draw(_bw)))
    return MemoryHierarchy(levels=tuple(levels))


@settings(max_examples=30, deadline=None)
@given(hz=_hierarchies())
def test_hierarchy_roundtrip_derive_digest(hz):
    """Any valid hierarchy survives the MachineModel JSON round trip
    bit-exactly (equal objects, equal digests) and rides through
    ``derive`` untouched."""
    assert hz.validate() == []
    assert MemoryHierarchy.from_dict(hz.to_dict()) == hz
    model = get_model("skl").derive("skl-hz", hierarchy=hz)
    clone = MachineModel.from_json(model.to_json())
    assert clone == model
    assert clone.digest == model.digest
    assert clone.hierarchy == hz
    derived = model.derive("skl-hz2")
    assert derived.hierarchy == hz
    assert derived.digest != model.digest        # arch_id differs


def test_digest_keys_on_the_hierarchy():
    """Two models differing only in their hierarchy must not collide:
    the digest is the distributed-cache key for ECM predictions."""
    skl = get_model("skl")
    assert skl.hierarchy is not None
    stripped = skl.derive("skl-x", hierarchy=None)
    changed = skl.derive(
        "skl-x", hierarchy=MemoryHierarchy(levels=(
            skl.hierarchy.levels[0],
            skl.hierarchy.levels[-1])))
    same = skl.derive("skl-x", hierarchy=skl.hierarchy)
    assert len({stripped.digest, changed.digest, same.digest}) == 3


def test_shipped_hierarchies_are_valid():
    """Every registry model either has no hierarchy or a structurally
    valid one (same checks tools/check_models.py runs in CI)."""
    from repro.core import default_registry
    for arch_id in default_registry().ids():
        hz = get_model(arch_id).hierarchy
        if hz is not None:
            assert hz.validate() == [], arch_id


def _load_check_models():
    path = Path(__file__).resolve().parent.parent / "tools" / \
        "check_models.py"
    spec = importlib.util.spec_from_file_location("check_models_mem",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_models_enumerates_malformed_hierarchy():
    """A malformed hierarchy artifact is reported defect-by-defect by
    the CI model checker, not swallowed or crashed on."""
    cm = _load_check_models()
    bad = get_model("skl").derive("skl-bad", hierarchy=MemoryHierarchy(
        levels=(
            CacheLevel("L1", 32768, ways=8),
            CacheLevel("L2", 16384, ways=8),          # shrinks
            CacheLevel("L3", 65536, ways=8, load_bw=-1.0),  # bad bw
            CacheLevel("MEM", 2 ** 30, ways=1),       # bounded last
        )))
    errors = []
    cm.check_model(bad, "unit-test", errors)
    text = "\n".join(errors)
    assert "hierarchy" in text
    assert "not strictly larger" in text
    assert "bandwidths must be positive" in text
    assert "must be unbounded" in text
    good = get_model("skl")
    ok_errors = []
    cm.check_model(good, "unit-test", ok_errors)
    assert ok_errors == []


def test_hierarchy_construction_rejects_garbage():
    with pytest.raises(ValueError):
        MemoryHierarchy(levels=())
    with pytest.raises(ValueError):
        MemoryHierarchy(levels=(CacheLevel("L1", 1024),
                                CacheLevel("L1", None)))
    with pytest.raises(ValueError):
        CacheLevel.from_dict({"name": "L1", "size_bytes": 1024,
                              "surprise": 1})
