"""End-to-end trainer: loss decreases, checkpoint/resume determinism,
preemption handling, serving engine round trip."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.config import ShapeConfig
from repro.optim import AdamWConfig
from repro.serving import Request, ServingEngine
from repro.train import Trainer, TrainerConfig


def _mesh():
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _tiny_cfg():
    return get_smoke_config("qwen2.5-3b").with_updates(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_head=32,
        d_ff=128, attn_chunk_q=32, attn_chunk_kv=32, loss_chunk=32)


_SHAPE = ShapeConfig("tiny", seq_len=64, global_batch=4, kind="train")


def _tcfg(tmp_path, steps):
    return TrainerConfig(steps=steps, checkpoint_dir=str(tmp_path),
                         checkpoint_every=10, log_every=5,
                         async_checkpoint=False,
                         optimizer=AdamWConfig(lr=2e-3))


def test_training_reduces_loss(tmp_path):
    trainer = Trainer(_tiny_cfg(), _SHAPE, _mesh(), _tcfg(tmp_path, 30))
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert out["final_step"] == 30
    assert losses[-1] < losses[0] - 0.05, losses
    assert not out["interrupted"]


def test_resume_from_checkpoint_is_deterministic(tmp_path):
    cfg, mesh = _tiny_cfg(), _mesh()
    # run A: 20 steps straight through
    a_dir = tmp_path / "a"
    out_a = Trainer(cfg, _SHAPE, mesh, _tcfg(a_dir, 20)).run()
    # run B: 10 steps, stop, new Trainer resumes to 20
    b_dir = tmp_path / "b"
    Trainer(cfg, _SHAPE, mesh, _tcfg(b_dir, 10)).run()
    out_b = Trainer(cfg, _SHAPE, mesh, _tcfg(b_dir, 20)).run()
    # stateless data pipeline + checkpointed state => identical history
    la = {m["step"]: m["loss"] for m in out_a["metrics"]}
    lb = {m["step"]: m["loss"] for m in out_b["metrics"]}
    common = sorted(set(la) & set(lb) & {15, 19})
    assert common
    for s in common:
        assert la[s] == pytest.approx(lb[s], rel=1e-4), (s, la[s], lb[s])


def test_preemption_checkpoints_and_resumes(tmp_path):
    cfg, mesh = _tiny_cfg(), _mesh()
    trainer = Trainer(cfg, _SHAPE, mesh, _tcfg(tmp_path, 50))
    # fire the preemption flag after a few steps via the monitor hook
    orig_record = trainer.monitor.record

    def record_and_preempt(step, times):
        if step == 7:
            trainer.preemption.trigger()
        return orig_record(step, times)

    trainer.monitor.record = record_and_preempt
    out = trainer.run()
    assert out["interrupted"] and out["final_step"] <= 8
    assert trainer.store.latest_step() is not None
    # resume finishes the job
    out2 = Trainer(cfg, _SHAPE, mesh, _tcfg(tmp_path, 12)).run()
    assert out2["final_step"] == 12 and not out2["interrupted"]


def test_serving_engine_deterministic_roundtrip():
    cfg = _tiny_cfg()
    from repro.models import init_params, model_schema
    params = init_params(model_schema(cfg), jax.random.key(0))
    engine = ServingEngine(cfg, params, n_slots=2, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(1, cfg.vocab_size, 8),
                    max_new_tokens=4) for i in range(3)]
    r1 = engine.run(list(reqs))
    engine2 = ServingEngine(cfg, params, n_slots=2, max_len=64)
    r2 = engine2.run(list(reqs))
    assert [r.tokens for r in sorted(r1, key=lambda r: r.rid)] == \
           [r.tokens for r in sorted(r2, key=lambda r: r.rid)]
    assert all(len(r.tokens) >= 1 for r in r1)
