"""Golden-table regression suite for the ECM memory-hierarchy table.

``benchmarks.paper_tables.ecm_table`` runs every paper kernel through
the ECM composer at a working set resident in each level of the shipped
SKL/Zen cache hierarchies (L1/L2/L3/MEM).  This module pins the whole
table against committed golden values: any change to the stream
extractor, the traffic model, the hierarchy constants, or the T_nOL
port-occupation rule that moves a paper-kernel prediction shows up here
as an explicit diff, not as silent drift.

Two structural invariants ride along: an L1-resident working set must
reproduce the in-core prediction bit-exactly (the paper's infinite-L1
assumption recovered), and predictions must grow monotonically as the
working set climbs the hierarchy.

On mismatch the failing rows are also written to a machine-readable
diff file (``ECM_GOLDEN_DIFF_PATH``, default ``ecm-golden-diff.json``
in the repo root) which CI uploads as an artifact.
"""
import json
import os
from pathlib import Path

import pytest

from benchmarks import paper_tables

# ------------------------------------------------------------------ #
# The golden table.  ``ecm_cy_it`` is per *source* iteration; the ECM
# notation strings are per assembly iteration,
# {T_OL || T_nOL | T_L1L2 | T_L2L3 | T_L3Mem}.  Regenerate with
#   PYTHONPATH=src:. python -c \
#     "from benchmarks.paper_tables import ecm_table; \
#      [print(r) for r in ecm_table()]"
# and update ONLY when a change to the model is intended and understood.
# ------------------------------------------------------------------ #
GOLDEN = {
    #                       ecm_cy_it  transfer  binding
    "triad_skl_O3@L1":  (0.500, 0.00, "throughput"),
    "triad_skl_O3@L2":  (1.250, 3.00, "memory"),
    "triad_skl_O3@L3":  (2.750, 9.00, "memory"),
    "triad_skl_O3@MEM": (6.500, 24.00, "memory"),
    "triad_zen_O3@L1":  (1.000, 0.00, "throughput"),
    "triad_zen_O3@L2":  (1.750, 1.50, "memory"),
    "triad_zen_O3@L3":  (3.625, 5.25, "memory"),
    "triad_zen_O3@MEM": (8.000, 14.00, "memory"),
    # the pi kernels accumulate in registers; their only memory operand
    # is a stride-0 (%rsp) scalar that stays L1-resident at any working
    # set, so the ECM bound collapses to the in-core bound at all levels
    "pi_skl_O1@L1":  (9.000, 0.00, "latency"),
    "pi_skl_O1@L2":  (9.000, 0.00, "latency"),
    "pi_skl_O1@L3":  (9.000, 0.00, "latency"),
    "pi_skl_O1@MEM": (9.000, 0.00, "latency"),
    "pi_skl_O2@L1":  (4.250, 0.00, "throughput"),
    "pi_skl_O2@L2":  (4.250, 0.00, "throughput"),
    "pi_skl_O2@L3":  (4.250, 0.00, "throughput"),
    "pi_skl_O2@MEM": (4.250, 0.00, "throughput"),
    "pi_skl_O3@L1":  (2.000, 0.00, "throughput"),
    "pi_skl_O3@L2":  (2.000, 0.00, "throughput"),
    "pi_skl_O3@L3":  (2.000, 0.00, "throughput"),
    "pi_skl_O3@MEM": (2.000, 0.00, "throughput"),
    "pi_zen_O1@L1":  (11.500, 0.00, "latency"),
    "pi_zen_O1@L2":  (11.500, 0.00, "latency"),
    "pi_zen_O1@L3":  (11.500, 0.00, "latency"),
    "pi_zen_O1@MEM": (11.500, 0.00, "latency"),
    "pi_zen_O2@L1":  (4.000, 0.00, "throughput"),
    "pi_zen_O2@L2":  (4.000, 0.00, "throughput"),
    "pi_zen_O2@L3":  (4.000, 0.00, "throughput"),
    "pi_zen_O2@MEM": (4.000, 0.00, "throughput"),
    "pi_zen_O3@L1":  (2.000, 0.00, "throughput"),
    "pi_zen_O3@L2":  (2.000, 0.00, "throughput"),
    "pi_zen_O3@L3":  (2.000, 0.00, "throughput"),
    "pi_zen_O3@MEM": (2.000, 0.00, "throughput"),
}

# full ECM notations pinned for the memory-resident triads — the one
# place every per-link term is live (per assembly iteration)
GOLDEN_NOTATION = {
    "triad_skl_O3@MEM": "{2.00 || 2.00 | 3.00 | 6.00 | 15.00}",
    "triad_zen_O3@MEM": "{2.00 || 2.00 | 1.50 | 3.75 | 8.75}",
}

ABS_TOL = 1e-9
LEVELS = ("L1", "L2", "L3", "MEM")


def _diff_path() -> Path:
    root = Path(__file__).resolve().parent.parent
    return Path(os.environ.get("ECM_GOLDEN_DIFF_PATH",
                               root / "ecm-golden-diff.json"))


@pytest.fixture(scope="module")
def ecm_rows():
    rows = {r["name"].split("/", 1)[1]: r
            for r in paper_tables.ecm_table()}
    yield rows


def _check_rows(rows):
    """Compare against GOLDEN; return the list of mismatch records."""
    diffs = []
    for name, (ecm, transfer, binding) in GOLDEN.items():
        row = rows.get(name)
        if row is None:
            diffs.append({"kernel": name, "field": "row",
                          "expected": "present", "got": "missing"})
            continue
        checks = [
            ("ecm_cy_it", ecm, row["ecm_cy_it"]),
            ("transfer_cy", transfer, row["transfer_cy"]),
            ("binding", binding, row["binding"]),
            ("resident", name.split("@", 1)[1], row["resident"]),
        ]
        if name in GOLDEN_NOTATION:
            checks.append(("notation", GOLDEN_NOTATION[name],
                           row["notation"]))
        for field, exp, got in checks:
            equal = (abs(got - exp) <= ABS_TOL
                     if isinstance(exp, float) else got == exp)
            if not equal:
                diffs.append({"kernel": name, "field": field,
                              "expected": exp, "got": got})
    return diffs


def test_ecm_table_matches_golden(ecm_rows):
    assert set(ecm_rows) == set(GOLDEN), (
        "kernel x level set drifted vs golden table")
    diffs = _check_rows(ecm_rows)
    if diffs:
        path = _diff_path()
        path.write_text(json.dumps(
            {"golden": {k: list(v) for k, v in GOLDEN.items()},
             "diffs": diffs}, indent=2) + "\n", encoding="utf-8")
        pytest.fail(f"{len(diffs)} ECM golden mismatch(es), diff "
                    f"written to {path}:\n"
                    + "\n".join(f"  {d['kernel']}.{d['field']}: expected "
                                f"{d['expected']!r}, got {d['got']!r}"
                                for d in diffs))


def test_l1_resident_recovers_in_core_prediction(ecm_rows):
    """Working set inside L1 ⇒ the ECM bound IS the in-core bound: the
    model degrades to the paper's infinite-L1 assumption bit-exactly."""
    for name, row in ecm_rows.items():
        if not name.endswith("@L1"):
            continue
        assert row["transfer_cy"] == 0.0, name
        assert row["ecm_cy_it"] * 1.0 == pytest.approx(
            row["incore_cy"] / _unroll(name), abs=0), name
        assert row["binding"] != "memory", name


def test_predictions_monotone_in_working_set(ecm_rows):
    """Climbing the hierarchy can only add transfer cycles — the ECM
    prediction is non-decreasing in the working set."""
    kernels = {n.split("@", 1)[0] for n in ecm_rows}
    for kernel in kernels:
        seq = [ecm_rows[f"{kernel}@{lv}"]["ecm_cy_it"] for lv in LEVELS]
        assert seq == sorted(seq), (kernel, seq)


def test_memory_binds_the_cache_resident_triads(ecm_rows):
    """Beyond L1 the triads are data-transfer bound on both archs; the
    register-resident pi kernels never are."""
    for arch in ("skl", "zen"):
        for lv in ("L2", "L3", "MEM"):
            assert ecm_rows[f"triad_{arch}_O3@{lv}"]["binding"] \
                == "memory"
    assert all(r["binding"] != "memory" for n, r in ecm_rows.items()
               if n.startswith("pi_"))


def _unroll(name: str) -> int:
    kernel = name.split("@", 1)[0]
    return paper_tables.KERNEL_CASES[kernel][2]


def test_no_stale_diff_artifact_on_success(ecm_rows):
    """A green run must not leave a stale diff file behind (CI only
    uploads it on failure, but a leftover from a previous red run would
    be misleading)."""
    if not _check_rows(ecm_rows) and _diff_path().exists():
        _diff_path().unlink()
    assert not (_check_rows(ecm_rows) and not _diff_path().exists())
