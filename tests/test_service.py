"""Unit tests for the persistent prediction service (``repro.service``).

One class of tests per layer:

* admission control — bounded global/tenant depth, token-bucket rate
  limiting, slot release (pure bookkeeping, caller-supplied clock);
* cross-request cache — LRU eviction order, TTL expiry, purge,
  hit-rate accounting;
* telemetry — histogram percentiles, per-tenant counters, export
  shape;
* SLO self-model — busy-period response times against hand-computed
  fixed points, mixture quantiles against closed-form CDF inverses,
  calibration from a synthetic telemetry export;
* service lifecycle — deadline expiry, dispatch retry/failure
  surfaced as responses, closed-service submits, cancellation
  bookkeeping, ``export_stats`` shape;
* engine robustness hooks — ``predict_async`` timeout/retry semantics
  and ``drop_results`` program reuse (the sweep-bench cache gate).
"""
import asyncio
import time

import pytest

from repro.core import AnalysisRequest, AnalysisService
from repro.core import paper_kernels as pk
from repro.service import (AdmissionController, AdmissionError,
                           DeadlineExceeded, DispatchError, FlowSpec,
                           HloRequest, LatencyHistogram,
                           PredictionService, ServiceClosed,
                           ServiceConfig, ServiceRequest, SloModel,
                           TTLCache, TenantPolicy,
                           busy_period_response, mixture_quantile,
                           replay)


# ---------------------------------------------------------------- admission

def test_admission_global_depth():
    ac = AdmissionController(max_queue_depth=2)
    ac.admit("a", now=0.0)
    ac.admit("b", now=0.0)
    with pytest.raises(AdmissionError) as ei:
        ac.admit("c", now=0.0)
    assert ei.value.reason == "queue_depth"
    assert ei.value.tenant == "c"
    ac.release("a")
    ac.admit("c", now=0.0)       # slot freed
    assert ac.total_in_flight == 2


def test_admission_tenant_depth():
    ac = AdmissionController(
        max_queue_depth=100,
        default_policy=TenantPolicy(max_in_flight=2))
    ac.admit("a", 0.0)
    ac.admit("a", 0.0)
    with pytest.raises(AdmissionError) as ei:
        ac.admit("a", 0.0)
    assert ei.value.reason == "tenant_depth"
    ac.admit("b", 0.0)           # other tenants unaffected


def test_admission_rate_limit_refills():
    ac = AdmissionController(
        max_queue_depth=100,
        default_policy=TenantPolicy(max_in_flight=100,
                                    rate_per_s=10.0, burst=2.0))
    ac.admit("a", 0.0)
    ac.admit("a", 0.0)           # burst of 2 OK
    with pytest.raises(AdmissionError) as ei:
        ac.admit("a", 0.0)
    assert ei.value.reason == "rate"
    # 0.1 s later one token has refilled
    ac.admit("a", 0.1)
    with pytest.raises(AdmissionError):
        ac.admit("a", 0.1)


def test_admission_per_tenant_policy_overrides_default():
    ac = AdmissionController(
        max_queue_depth=100,
        default_policy=TenantPolicy(max_in_flight=1),
        per_tenant={"vip": TenantPolicy(max_in_flight=3)})
    ac.admit("vip", 0.0)
    ac.admit("vip", 0.0)
    ac.admit("vip", 0.0)
    with pytest.raises(AdmissionError):
        ac.admit("other", 0.0) or ac.admit("other", 0.0)


def test_release_never_goes_negative():
    ac = AdmissionController()
    ac.release("ghost")
    assert ac.total_in_flight == 0
    ac.admit("a", 0.0)
    ac.release("a")
    ac.release("a")
    assert ac.total_in_flight == 0


# -------------------------------------------------------------------- cache

def test_ttl_cache_lru_eviction():
    c = TTLCache(max_entries=2)
    c.put("a", 1, now=0)
    c.put("b", 2, now=0)
    assert c.get("a", now=0) == 1    # refresh a
    c.put("c", 3, now=0)             # evicts b (least recently used)
    assert c.get("b", now=0) is None
    assert c.get("a", now=0) == 1
    assert c.get("c", now=0) == 3
    assert c.stats()["evictions"] == 1


def test_ttl_cache_expiry_and_purge():
    c = TTLCache(max_entries=10, ttl_s=1.0)
    c.put("a", 1, now=0.0)
    c.put("b", 2, now=0.5)
    assert c.get("a", now=0.9) == 1
    assert c.get("a", now=1.1) is None      # expired
    assert c.expirations == 1
    assert c.purge(now=2.0) == 1            # reaps b
    assert len(c) == 0


def test_ttl_cache_hit_rate():
    c = TTLCache()
    assert c.hit_rate() == 0.0
    c.put("k", "v")
    c.get("k")
    c.get("nope")
    assert c.hit_rate() == pytest.approx(0.5)


# ---------------------------------------------------------------- telemetry

def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for ms in range(1, 101):
        h.observe(ms / 1000.0)
    d = h.as_dict()
    assert d["count"] == 100
    # log-bucketed: percentiles are approximate, but must bracket
    assert 0.03 <= d["p50_s"] <= 0.08
    assert 0.08 <= d["p99_s"] <= 0.15
    assert d["max_s"] == pytest.approx(0.1)
    assert d["mean_s"] == pytest.approx(0.0505, rel=0.01)


def test_latency_histogram_empty():
    d = LatencyHistogram().as_dict()
    assert d["count"] == 0
    assert d["p99_s"] == 0.0


# ---------------------------------------------------------------- SLO model

def test_busy_period_no_interference_is_cost():
    assert busy_period_response(FlowSpec("a", 2.0, 10.0), []) == \
        pytest.approx(2.0)


def test_busy_period_hand_computed_fixed_point():
    # flow C=1 T=4; interferer C=1 T=2 J=2:
    #   w  = 1 + ceil((w+2)/2)        -> w = 4
    #   v0 = ceil((v0+2)/2)           -> v0 = 2, R = v0 + C = 3
    flow = FlowSpec("a", 1.0, 4.0)
    other = FlowSpec("b", 1.0, 2.0, jitter_s=2.0)
    assert busy_period_response(flow, [other]) == pytest.approx(3.0)


def test_busy_period_zero_jitter_misses_simultaneous_release():
    # the subtlety the service's calibration must compensate for:
    # with zero jitter an interferer contributes nothing at v=0
    flow = FlowSpec("a", 1.0, 4.0)
    other = FlowSpec("b", 1.0, 2.0, jitter_s=0.0)
    assert busy_period_response(flow, [other]) == pytest.approx(1.0)


def test_busy_period_unstable_is_inf():
    flow = FlowSpec("a", 1.0, 1.5)
    other = FlowSpec("b", 1.0, 2.0)
    assert busy_period_response(flow, [other]) == float("inf")


def test_mixture_quantile_single_uniform():
    assert mixture_quantile([(1.0, 0.0, 1.0)], 0.5) == \
        pytest.approx(0.5, abs=1e-6)
    assert mixture_quantile([(1.0, 0.0, 1.0)], 0.99) == \
        pytest.approx(0.99, abs=1e-6)


def test_mixture_quantile_two_class_closed_form():
    classes = [(0.5, 0.0, 1.0), (0.5, 1.0, 3.0)]
    assert mixture_quantile(classes, 0.25) == pytest.approx(0.5,
                                                            abs=1e-6)
    assert mixture_quantile(classes, 0.75) == pytest.approx(2.0,
                                                            abs=1e-6)


def test_mixture_quantile_degenerate():
    assert mixture_quantile([], 0.5) == 0.0
    assert mixture_quantile([(1.0, 2.0, 2.0)], 0.99) == \
        pytest.approx(2.0)


def test_slo_model_from_synthetic_telemetry():
    export = {
        "elapsed_s": 10.0,
        "cohort_classes": {
            "x86/aaaa/simulate/numpy": {
                "dispatches": 5, "requests": 80,
                "cost": {"mean_s": 0.4}},
            "hlo/bbbb/analytic/none": {
                "dispatches": 10, "requests": 20,
                "cost": {"mean_s": 0.01}},
            "dead/class": {"dispatches": 0, "requests": 0,
                           "cost": {"mean_s": 0.0}},
        },
    }
    model = SloModel.from_telemetry(export, window_s=0.02)
    assert len(model.flows) == 2          # dispatch-free class dropped
    by_name = {f.name: f for f in model.flows}
    sim = by_name["x86/aaaa/simulate/numpy"]
    assert sim.cost_s == pytest.approx(0.4)
    assert sim.period_s == pytest.approx(2.0)   # 10 s / 5 dispatches
    assert sim.share == pytest.approx(0.8)
    assert sim.jitter_s == pytest.approx(0.02)

    pred = model.predict()
    assert 0.0 < pred.p50_s <= pred.p99_s
    assert pred.utilization == pytest.approx(0.4 / 2.0 + 0.01 / 1.0)
    assert set(pred.per_class) == set(by_name)


# ----------------------------------------------------------- request shapes

def test_service_request_requires_exactly_one_payload():
    with pytest.raises(ValueError):
        ServiceRequest()
    with pytest.raises(ValueError):
        ServiceRequest(analysis=AnalysisRequest(kernel=pk.PI_O1,
                                                arch="skl"),
                       hlo=HloRequest(text="HloModule x"))
    assert ServiceRequest(analysis=AnalysisRequest(
        kernel=pk.PI_O1, arch="skl")).kind == "x86"
    assert ServiceRequest(hlo=HloRequest(text="HloModule x")).kind \
        == "hlo"


# -------------------------------------------------------- service lifecycle

def _req(unroll: int = 1, tenant: str = "t") -> ServiceRequest:
    return ServiceRequest(analysis=AnalysisRequest(
        kernel=pk.PI_O1, arch="skl", unroll_factor=unroll),
        tenant=tenant)


def test_submit_on_stopped_service_raises():
    svc = PredictionService()

    async def go():
        with pytest.raises(ServiceClosed):
            await svc.submit(_req())

    asyncio.run(go())


def test_replay_basic_and_cache_hit():
    svc = PredictionService(config=ServiceConfig(batch_window_s=0.005))
    resps = replay(svc, [(0.0, _req()), (0.0, _req(unroll=2))])
    assert all(r.ok for r in resps)
    assert all(not r.cache_hit for r in resps)
    assert all(r.cohort_size >= 1 for r in resps)
    # second replay on the same (warm) service: pure cache hits
    resps2 = replay(svc, [(0.0, _req()), (0.0, _req(unroll=2))])
    assert all(r.ok and r.cache_hit for r in resps2)
    assert resps2[0].result is resps[0].result
    stats = svc.export_stats()
    assert stats["cache"]["hits"] == 2
    assert stats["tenants"]["t"]["completed"] == 4


def test_deadline_exceeded_comes_back_as_response():
    svc = PredictionService(config=ServiceConfig(
        batch_window_s=0.005, max_retries=0))
    real = svc.engine.predict_batch

    def slow(reqs, backend=None):
        time.sleep(0.4)
        return real(reqs, backend=backend)

    svc.engine.predict_batch = slow
    resp = replay(svc, [(0.0, ServiceRequest(
        analysis=AnalysisRequest(kernel=pk.PI_O1, arch="skl"),
        timeout_s=0.05))])[0]
    assert not resp.ok
    assert isinstance(resp.error, DeadlineExceeded)
    assert svc.telemetry.tenant("default").deadline_exceeded == 1


def test_dispatch_retry_then_success():
    svc = PredictionService(config=ServiceConfig(
        batch_window_s=0.005, max_retries=2, retry_backoff_s=0.01))
    real = svc.engine.predict_batch
    calls = {"n": 0}

    def flaky(reqs, backend=None):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return real(reqs, backend=backend)

    svc.engine.predict_batch = flaky
    resp = replay(svc, [(0.0, _req())])[0]
    assert resp.ok
    assert calls["n"] == 2


def test_dispatch_permanent_failure_is_dispatch_error():
    svc = PredictionService(config=ServiceConfig(
        batch_window_s=0.005, max_retries=1, retry_backoff_s=0.01))

    def broken(reqs, backend=None):
        raise RuntimeError("boom")

    svc.engine.predict_batch = broken
    resp = replay(svc, [(0.0, _req())])[0]
    assert not resp.ok
    assert isinstance(resp.error, DispatchError)
    assert "boom" in str(resp.error)
    assert svc.telemetry.tenant("t").failed == 1
    # admission slot was released despite the failure
    assert svc.admission.total_in_flight == 0


def test_rejected_requests_surface_in_replay():
    svc = PredictionService(config=ServiceConfig(
        batch_window_s=0.005,
        default_policy=TenantPolicy(max_in_flight=1, rate_per_s=1.0,
                                    burst=1.0)))
    resps = replay(svc, [(0.0, _req(unroll=1 + i)) for i in range(6)])
    rejected = [r for r in resps if isinstance(r.error, AdmissionError)]
    served = [r for r in resps if r.ok]
    assert rejected and served
    assert svc.telemetry.tenant("t").rejected == len(rejected)


def test_export_stats_shape():
    svc = PredictionService(config=ServiceConfig(batch_window_s=0.005))
    replay(svc, [(0.0, _req())])
    stats = svc.export_stats()
    for key in ("elapsed_s", "stages", "batch_size", "queue_depth",
                "tenants", "cohort_classes", "engine_dispatches",
                "cache", "engine_hit_rates", "traces"):
        assert key in stats, key
    assert stats["stages"]["dispatch"]["count"] >= 1
    (cls,) = stats["cohort_classes"].values()
    assert cls["dispatches"] == 1
    assert cls["requests"] == 1
    model = svc.slo_model()
    assert model.flows
    pred = svc.predict_slo()
    assert pred.p99_s >= pred.p50_s >= 0.0


# ------------------------------------------------- engine robustness hooks

def test_predict_async_timeout():
    engine = AnalysisService()

    def slow(request):
        time.sleep(0.5)

    engine.predict = slow

    async def go():
        with pytest.raises(asyncio.TimeoutError):
            await engine.predict_async(
                AnalysisRequest(kernel=pk.PI_O1, arch="skl"),
                timeout=0.05)

    asyncio.run(go())


def test_predict_async_retries_transient_then_succeeds():
    engine = AnalysisService()
    real = engine.predict
    calls = {"n": 0}

    def flaky(request):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return real(request)

    engine.predict = flaky

    async def go():
        return await engine.predict_async(
            AnalysisRequest(kernel=pk.PI_O1, arch="skl"),
            retries=2, backoff_s=0.01)

    result = asyncio.run(go())
    assert calls["n"] == 2
    assert result.predicted_cycles > 0


def test_predict_async_never_retries_value_error():
    engine = AnalysisService()
    calls = {"n": 0}

    def bad(request):
        calls["n"] += 1
        raise ValueError("no such arch")

    engine.predict = bad

    async def go():
        with pytest.raises(ValueError):
            await engine.predict_async(
                AnalysisRequest(kernel=pk.PI_O1, arch="skl"),
                retries=5, backoff_s=0.01)

    asyncio.run(go())
    assert calls["n"] == 1


def test_drop_results_keeps_compiled_programs():
    engine = AnalysisService()
    req = AnalysisRequest(kernel=pk.PI_O1, arch="skl", mode="simulate")
    engine.predict(req)
    sims_before = engine.stats.sim_runs
    hits_before = engine.stats.program_hits
    engine.drop_results()
    engine.predict(req)                      # re-simulates ...
    assert engine.stats.sim_runs == sims_before + 1
    assert engine.stats.program_hits > hits_before   # ... same program


# ------------------------------------------- targeted cache invalidation

def test_ttl_cache_invalidate_match_drops_only_matching_keys():
    c = TTLCache(max_entries=16)
    for digest in ("aaa", "bbb"):
        for i in range(3):
            c.put((digest, i), f"{digest}/{i}", now=0)
    dropped = c.invalidate(lambda k: k[0] == "aaa")
    assert dropped == 3
    assert all(c.get(("aaa", i), now=0) is None for i in range(3))
    assert all(c.get(("bbb", i), now=0) == f"bbb/{i}" for i in range(3))
    # no-op matcher drops nothing
    assert c.invalidate(lambda k: False) == 0
    assert len(c) == 3


def test_registry_epoch_invalidation_under_concurrent_submit():
    """A machine-model re-registration mid-traffic must clear the
    cross-request cache at the next submit — a stale entry keyed on a
    superseded digest is never served — while in-flight submits all
    still resolve exactly once."""
    svc = PredictionService(config=ServiceConfig(batch_window_s=0.005))

    async def go():
        await svc.start()
        r1 = await svc.submit(_req())
        assert r1.ok and not r1.cache_hit
        r2 = await svc.submit(_req())
        assert r2.ok and r2.cache_hit            # warm
        # the epoch bump lands while a burst is in flight; replacing
        # with the *same* model still supersedes (epoch bumps), so the
        # recomputed answer must be identical — only the cache entry
        # dies
        async def reregister():
            reg = svc.engine.registry
            reg.register(reg.model("skl"), replace=True)

        results = await asyncio.gather(
            reregister(),
            *(svc.submit(_req(unroll=2 + (i % 3))) for i in range(9)))
        resps = results[1:]
        assert all(r.ok for r in resps)
        assert len(resps) == 9                   # exactly once each
        # the pre-registration entry for _req() must not be served
        r3 = await svc.submit(_req())
        assert r3.ok and not r3.cache_hit
        assert r3.result.predicted_cycles == r1.result.predicted_cycles
        assert any(t["event"] == "cache_invalidated"
                   for t in svc.telemetry.traces)
        await svc.stop()

    asyncio.run(go())
