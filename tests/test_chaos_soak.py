"""Nightly chaos soak: randomized seeded fault schedules replayed
through a routing-enabled service (docs/robustness.md).

Gated on ``CHAOS_SOAK=1`` so the PR-blocking chaos-smoke job stays
fast; the CI ``chaos-soak`` job (``schedule:`` / ``workflow_dispatch``)
runs it nightly with many seeds and uploads the fault traces and
breaker transition logs as artifacts.

Each soak round draws a fault schedule from its seed (every mode the
injector knows except ``abort`` — kill/resume is pinned separately by
the journal suite), replays a mixed traffic burst against an engine
with the :class:`HealthRouter` enabled and aggressive breakers, and
holds the PR 9 + PR 10 invariants jointly:

* every admitted request resolves exactly once — ``ok`` or a typed
  error, never a hang, drop, or duplicate;
* every ok result is finite, and degraded/routed results carry their
  ``backend_used`` / ``routed_from`` provenance;
* the router never dispatches against a rung whose breaker it chose to
  skip (routed cohorts cost zero attempts on the skipped rung while it
  stays open);
* retry sleeps stay under the backoff cap.

On any violation the injector's event trace and the breaker board
snapshot are written to ``FAULT_TRACE_PATH`` / ``BREAKER_LOG_PATH``
(when set) for artifact upload.
"""
from __future__ import annotations

import json
import math
import os
import random

import pytest

from repro.core import AnalysisService, paper_kernels as pk
from repro.core.degrade import BreakerConfig, HealthRouter
from repro.core.engine import AnalysisRequest
from repro.core.faults import FAULT_POINTS, FaultPlan, FaultSpec
from repro.service import (PredictionService, ServiceConfig,
                           ServiceRequest, replay)
from repro.service.request import HloRequest

pytestmark = pytest.mark.skipif(
    not os.environ.get("CHAOS_SOAK"),
    reason="nightly soak; set CHAOS_SOAK=1 to run")

_MODES = ["fail", "fail_once", "fail_n", "latency", "corrupt"]
_HLO = """
HloModule soak, entry_computation_layout={()->f32[64,64]{1,0}}

ENTRY %main.1 () -> f32[64,64] {
  %a = f32[64,64]{1,0} constant({...})
  ROOT %d = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def _random_plan(seed: int) -> FaultPlan:
    rng = random.Random(seed)
    specs = []
    for _ in range(rng.randint(1, 4)):
        specs.append(FaultSpec(
            point=rng.choice(list(FAULT_POINTS)),
            mode=rng.choice(_MODES),
            count=rng.choice([None, 1, 2, 3]),
            skip=rng.randint(0, 2),
            delay_s=0.01,
            corrupt=rng.choice(["nan", "negative"]),
            probability=rng.choice([0.5, 1.0]),
        ))
    return FaultPlan(specs=tuple(specs), seed=seed)


def _traffic(rng: random.Random):
    cells = [("skl", pk.TRIAD_SKL_O3), ("zen", pk.TRIAD_ZEN_O3),
             ("skl", pk.PI_O1), ("zen", pk.PI_O2),
             ("skl", pk.PI_SKL_O3), ("zen", pk.PI_ZEN_O3)]
    traffic = []
    for i in range(rng.randint(12, 24)):
        arch, src = cells[rng.randrange(len(cells))]
        traffic.append((rng.uniform(0, 0.05), ServiceRequest(
            analysis=AnalysisRequest(
                kernel=src, arch=arch,
                mode=rng.choice(["simulate", "analytic"])),
            tenant=rng.choice(["a", "b"]), tag=f"soak{i}")))
    for i in range(rng.randint(1, 3)):
        traffic.append((rng.uniform(0, 0.05), ServiceRequest(
            hlo=HloRequest(text=_HLO), tenant="hlo", tag=f"h{i}")))
    traffic.sort(key=lambda t: t[0])
    return traffic


def _dump_artifacts(engine: AnalysisService, seed: int) -> None:
    trace = os.environ.get("FAULT_TRACE_PATH")
    if trace:
        with open(trace, "a", encoding="utf-8") as f:
            json.dump({"seed": seed, **engine.faults.export()}, f)
            f.write("\n")
    blog = os.environ.get("BREAKER_LOG_PATH")
    if blog:
        with open(blog, "a", encoding="utf-8") as f:
            json.dump({"seed": seed,
                       "board": engine.breakers.snapshot(),
                       "router": engine.router.snapshot()
                       if engine.router else None}, f)
            f.write("\n")


SOAK_SEEDS = range(int(os.environ.get("CHAOS_SOAK_SEED0", "0")),
                   int(os.environ.get("CHAOS_SOAK_SEED0", "0"))
                   + int(os.environ.get("CHAOS_SOAK_ROUNDS", "25")))


@pytest.mark.parametrize("seed", SOAK_SEEDS)
def test_soak_round_resolves_everything_with_routing(seed):
    plan = _random_plan(seed)
    engine = AnalysisService(
        faults=plan, router=HealthRouter(),
        breaker_config=BreakerConfig(failure_threshold=1,
                                     cooldown_s=0.02))
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.005, max_retries=2, retry_backoff_s=0.005,
        retry_backoff_cap_s=0.02, retry_seed=seed))
    rng = random.Random(seed ^ 0x5eed)
    traffic = _traffic(rng)
    try:
        resps = replay(svc, traffic)
        assert len(resps) == len(traffic)
        for r in resps:
            assert r is not None
            assert r.ok or r.error is not None      # typed, never hung
            if r.ok:
                if r.request.analysis is not None:
                    assert math.isfinite(r.result.predicted_cycles)
                if r.degraded:
                    assert r.backend_used
                if r.routed_from:
                    assert r.routed_from != r.backend_used
        # governed sleeps never exceed the cap
        assert svc.telemetry.retry_sleep.max <= 0.02 + 1e-9
        # the router's ledger stays internally consistent and
        # serializable under arbitrary schedules
        snap = engine.router.snapshot()
        json.dumps(snap)
        assert snap["stats"]["routed"] + snap["stats"]["probes"] \
            + snap["stats"]["floor_routes"] <= snap["stats"]["plans"] \
            + snap["stats"]["probes"]
        assert engine.stats.routed_groups <= snap["stats"]["routed"] \
            + snap["stats"]["probes"]
    except Exception:
        _dump_artifacts(engine, seed)
        raise
    _dump_artifacts(engine, seed)
