"""Validate the faithful reproduction against the paper's own numbers
(Tables I, II, IV, V, VI, VII of Laukemann et al., PMBS 2018)."""
import pytest

from repro.core import analyze, analyze_latency, extract_kernel
from repro.core.arch.skylake import STORE_FORWARD_LATENCY as SKL_SLF
from repro.core.arch.skylake import build_skylake_db
from repro.core.arch.zen import STORE_FORWARD_LATENCY as ZEN_SLF
from repro.core.arch.zen import build_zen_db
from repro.core import paper_kernels as pk

SKL = build_skylake_db()
ZEN = build_zen_db()


def _run(db, source, unroll=1):
    kern = extract_kernel(source)
    res = analyze(kern, db, unroll_factor=unroll)
    assert not res.missing, (
        "unmatched instruction forms: "
        + ", ".join(m.instruction.form for m in res.missing))
    return res


# ------------------------------------------------------------------ #
# Table I — triad throughput predictions (per assembly iteration)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("compiled_for,flag", list(pk.TABLE1))
def test_table1_triad_predictions(compiled_for, flag):
    unroll, exp_zen, exp_skl, _iaca = pk.TABLE1[(compiled_for, flag)]
    src = pk.TRIAD_KERNELS[(compiled_for, flag)]
    res_skl = _run(SKL, src, unroll)
    res_zen = _run(ZEN, src, unroll)
    assert res_skl.predicted_cycles == pytest.approx(exp_skl, abs=0.01)
    assert res_zen.predicted_cycles == pytest.approx(exp_zen, abs=0.01)


# ------------------------------------------------------------------ #
# Table II — SKL port occupation for the -O3 triad
# ------------------------------------------------------------------ #
def test_table2_port_totals():
    res = _run(SKL, pk.TRIAD_SKL_O3, unroll=4)
    for port, expected in pk.TABLE2_TOTALS.items():
        assert res.port_totals[port] == pytest.approx(expected, abs=0.01), \
            f"port {port}"
    assert res.bottleneck_port in ("2", "3")
    # per-row spot checks against the printed table
    rows = {r.instruction.text.split()[0] + str(i): r
            for i, r in enumerate(res.rows)}
    fma = next(r for r in res.rows
               if r.instruction.mnemonic.startswith("vfmadd"))
    assert fma.occupation == pytest.approx(
        {"0": .5, "1": .5, "2": .5, "3": .5}, abs=1e-9) or all(
        abs(fma.occupation.get(p, 0) - v) < 1e-9
        for p, v in {"0": .5, "1": .5, "2": .5, "3": .5}.items())
    store = next(r for r in res.rows if r.instruction.writes_memory())
    assert store.occupation.get("4", 0) == pytest.approx(1.0)
    assert store.occupation.get("2", 0) == pytest.approx(0.5)
    assert store.occupation.get("7", 0) == 0.0  # paper models no P7 AGU


# ------------------------------------------------------------------ #
# Table IV — Zen port occupation for the -O3 triad, incl. hidden load
# ------------------------------------------------------------------ #
def test_table4_port_totals_and_hidden_load():
    res = _run(ZEN, pk.TRIAD_ZEN_O3, unroll=2)
    for port, expected in pk.TABLE4_TOTALS.items():
        assert res.port_totals[port] == pytest.approx(expected, abs=0.01), \
            f"port {port}"
    # the first load's AGU uops are hidden behind the store (parenthesised
    # in the paper's Table IV)
    first_load = res.rows[0]
    assert first_load.instruction.mnemonic == "vmovaps"
    assert first_load.hidden_occupation.get("8", 0) == pytest.approx(0.5)
    assert first_load.hidden_occupation.get("9", 0) == pytest.approx(0.5)
    # visible occupation excludes the hidden AGU part but keeps the FP uop
    assert first_load.occupation.get("8", 0) == 0.0
    assert first_load.occupation.get("0", 0) == pytest.approx(0.25)
    assert res.predicted_cycles == pytest.approx(2.00, abs=0.01)


# ------------------------------------------------------------------ #
# Table V — pi benchmark predictions (per source iteration)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch,flag", list(pk.TABLE5))
def test_table5_pi_predictions(arch, flag):
    unroll, _iaca, exp_osaca, measured = pk.TABLE5[(arch, flag)]
    db = SKL if arch == "skl" else ZEN
    res = _run(db, pk.PI_KERNELS[(arch, flag)], unroll)
    # the paper's OSACA column is the pure throughput (port) bound
    assert res.port_bound_per_source_iteration == pytest.approx(
        exp_osaca, abs=0.01)
    if flag == "O1":
        # the store->load forwarded accumulator chain binds: the unified
        # engine predicts above the pure port bound and within 5% of the
        # measurement the paper could only report as an outlier
        assert res.binding == "latency"
        assert res.cycles_per_source_iteration > \
            res.port_bound_per_source_iteration
        assert abs(res.cycles_per_source_iteration - measured) \
            / measured < 0.05
    else:
        # register accumulator: the port bound remains the prediction
        assert res.binding == "throughput"
        assert res.cycles_per_source_iteration == pytest.approx(
            exp_osaca, abs=0.01)


def test_table5_bottleneck_is_divider_for_o2_o3():
    for arch, flag in (("skl", "O2"), ("skl", "O3"),
                       ("zen", "O2"), ("zen", "O3")):
        db = SKL if arch == "skl" else ZEN
        unroll = pk.TABLE5[(arch, flag)][0]
        res = _run(db, pk.PI_KERNELS[(arch, flag)], unroll)
        if (arch, flag) == ("skl", "O2"):
            # paper: averaged-port model puts P0 (4.25) above DV (4.0) —
            # "not a strictly lower bound" case discussed in Sec. III-B
            assert res.bottleneck_port == "0"
        else:
            assert res.bottleneck_port in ("0DV", "3DV")


# ------------------------------------------------------------------ #
# Tables VI, VII — pi port occupation on SKL
# ------------------------------------------------------------------ #
def test_table6_totals():
    res = _run(SKL, pk.PI_SKL_O3, unroll=8)
    for port, expected in pk.TABLE6_TOTALS.items():
        assert res.port_totals[port] == pytest.approx(expected, abs=0.01), \
            f"port {port}"
    assert res.predicted_cycles == pytest.approx(16.0, abs=0.01)
    assert res.cycles_per_source_iteration == pytest.approx(2.0, abs=0.01)


def test_table7_totals():
    res = _run(SKL, pk.PI_O2, unroll=1)
    for port, expected in pk.TABLE7_TOTALS.items():
        assert res.port_totals[port] == pytest.approx(expected, abs=0.01), \
            f"port {port}"
    assert res.predicted_cycles == pytest.approx(4.25, abs=0.01)


# ------------------------------------------------------------------ #
# Beyond-paper: LCD analysis explains the -O1 pi anomaly (Sec. III-B)
# ------------------------------------------------------------------ #
def test_pi_o1_loop_carried_dependency_explains_measurement():
    kern = extract_kernel(pk.PI_O1)
    lcd_skl = analyze_latency(kern, SKL, store_forward_latency=SKL_SLF)
    # store->load forward (5.0) + vaddsd latency (4.0) = 9.0 ~ measured 9.02
    assert lcd_skl.loop_carried_cycles == pytest.approx(9.0, abs=0.01)
    measured = pk.TABLE5[("skl", "O1")][3]
    assert abs(lcd_skl.loop_carried_cycles - measured) / measured < 0.05

    lcd_zen = analyze_latency(kern, ZEN, store_forward_latency=ZEN_SLF)
    # SLF 8.5 + vaddsd latency 3.0 = 11.5 ~ measured 11.48
    measured_zen = pk.TABLE5[("zen", "O1")][3]
    assert abs(lcd_zen.loop_carried_cycles - measured_zen) / measured_zen \
        < 0.05


def test_pi_o2_register_accumulator_has_small_lcd():
    kern = extract_kernel(pk.PI_O2)
    lcd = analyze_latency(kern, SKL, store_forward_latency=SKL_SLF)
    # accumulator chain is one vaddsd -> 4 cy < port bound 4.25
    assert lcd.loop_carried_cycles <= 4.25


# ------------------------------------------------------------------ #
# Sec. II-C — FMA instruction-form entries match the paper's DB lines
# ------------------------------------------------------------------ #
def test_fma_database_entries_match_paper():
    from repro.core.isa import parse_assembly
    ins = parse_assembly("vfmadd132pd (%rax), %xmm0, %xmm1")[0]
    zen_e = ZEN.lookup(ins)
    assert zen_e.throughput == 0.5 and zen_e.latency == 5.0
    occ = zen_e.occupation_uniform(ZEN.model)
    assert {p: v for p, v in occ.items() if v} == pytest.approx(
        {"0": 0.5, "1": 0.5, "8": 0.5, "9": 0.5})
    skl_e = SKL.lookup(ins)
    assert skl_e.throughput == 0.5 and skl_e.latency == 4.0
    occ = skl_e.occupation_uniform(SKL.model)
    assert {p: v for p, v in occ.items() if v} == pytest.approx(
        {"0": 0.5, "1": 0.5, "2": 0.5, "3": 0.5})
