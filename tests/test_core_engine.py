"""Unit + property tests for the paper engine itself (parser, marker
extraction, schedulers, database lookup, HLO analyzer)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional [dev] dependency
    from repro.testing import given, settings, st

from repro.core import analyze, extract_kernel, parse_assembly
from repro.core.arch.skylake import SKYLAKE, build_skylake_db
from repro.core.arch.zen import build_zen_db
from repro.core.database import E, InstructionDB
from repro.core.hlo.analyzer import analyze_hlo
from repro.core.hlo.parser import parse_module
from repro.core.kernel import find_marked_region
from repro.core.ports import PortModel, U
from repro.core.scheduler import schedule_balanced, schedule_uniform


# ------------------------------------------------------------------ #
# x86 parsing
# ------------------------------------------------------------------ #
def test_att_operand_order_and_types():
    ins = parse_assembly("vfmadd132pd 0(%r13,%rax), %ymm3, %ymm0")[0]
    assert ins.mnemonic == "vfmadd132pd"
    assert ins.signature == ("ymm", "ymm", "mem")  # Intel order
    mem = ins.operands[2]
    assert mem.base == "r13" and mem.index == "rax" and \
        mem.displacement == 0


def test_att_suffix_stripping_and_imm():
    ins = parse_assembly("addl $1, %ecx")[0]
    assert ins.mnemonic == "add"
    assert ins.signature == ("r32", "imm")
    assert parse_assembly("cmpq %rbp, %rax")[0].mnemonic == "cmp"
    assert parse_assembly("vmovss %xmm0, (%rsp)")[0].mnemonic == "vmovss"


def test_intel_syntax_parsing():
    ins = parse_assembly("vaddpd ymm0, ymm1, [rax+rcx*8+16]",
                         syntax="intel")[0]
    assert ins.signature == ("ymm", "ymm", "mem")
    mem = ins.operands[2]
    assert mem.base == "rax" and mem.index == "rcx" and mem.scale == 8 \
        and mem.displacement == 16


def test_marker_extraction():
    src = ("nop\nmovl $111, %ebx\n.byte 100,103,144\n"
           "vaddpd %ymm0, %ymm1, %ymm2\n"
           "movl $222, %ebx\n.byte 100,103,144\nret\n")
    assert find_marked_region(src) is not None
    kern = extract_kernel(src)
    assert [i.mnemonic for i in kern] == ["vaddpd"]


def test_loop_detection_without_markers():
    src = ("mov $0, %eax\n.L1:\nvmulpd %ymm0, %ymm1, %ymm1\n"
           "addl $1, %eax\ncmpl $100, %eax\njl .L1\nret\n")
    kern = extract_kernel(src)
    assert [i.mnemonic for i in kern] == ["vmulpd", "add", "cmp", "jl"]


# ------------------------------------------------------------------ #
# schedulers
# ------------------------------------------------------------------ #
def test_uniform_scheduler_splits_evenly():
    model = PortModel("m", ("a", "b"))
    out = schedule_uniform(model, [(0, U("a|b", 1.0))])
    assert out[0].assignment == {"a": 0.5, "b": 0.5}


def test_balanced_scheduler_beats_uniform_on_asymmetric_mix():
    """The paper's assumption-2 example: add on {a,b}, mul on {a} —
    uniform loads a with 1.5, the balanced (IACA-like) scheduler
    achieves 1.0 by pushing the add to b."""
    model = PortModel("m", ("a", "b"))
    uops = [(0, U("a|b")), (1, U("a"))]
    uni = model.zero_occupation()
    for s in schedule_uniform(model, uops):
        for p, c in s.assignment.items():
            uni[p] += c
    bal = model.zero_occupation()
    for s in schedule_balanced(model, uops):
        for p, c in s.assignment.items():
            bal[p] += c
    assert max(uni.values()) == pytest.approx(1.5)
    assert max(bal.values()) == pytest.approx(1.0, abs=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["a", "b", "c", "a|b", "b|c",
                                           "a|b|c"]),
                          st.floats(0.25, 4.0)),
                min_size=1, max_size=6))
def test_balanced_scheduler_is_optimal(uop_spec):
    """Property: the flow-based min-max schedule is never worse than any
    of 200 random feasible assignments, and conserves cycles."""
    import random
    model = PortModel("m", ("a", "b", "c"))
    uops = [(i, U(ports, cyc)) for i, (ports, cyc) in enumerate(uop_spec)]
    sched = schedule_balanced(model, uops)
    totals = model.zero_occupation()
    for s in sched:
        for p, c in s.assignment.items():
            totals[p] += c
    bound = max(totals.values())
    # cycles conserved per uop
    for s, (_, u) in zip(sched, uops):
        assert sum(s.assignment.values()) == pytest.approx(u.cycles,
                                                           rel=1e-6)
    rng = random.Random(0)
    for _ in range(200):
        t = model.zero_occupation()
        for _, u in uops:
            t[rng.choice(u.ports)] += u.cycles
        assert bound <= max(t.values()) + 1e-6


# ------------------------------------------------------------------ #
# database lookup
# ------------------------------------------------------------------ #
def test_db_lookup_gpr_collapse_and_default():
    db = build_skylake_db()
    ins64 = parse_assembly("addq $32, %rax")[0]
    ins32 = parse_assembly("addl $1, %ecx")[0]
    assert db.lookup(ins64) is db.lookup(ins32)
    shl = parse_assembly("shlq $3, %rdx")[0]
    assert db.lookup(shl) is not None  # wildcard default entry


def test_missing_form_generates_benchmark_stub():
    db = build_skylake_db()
    kern = parse_assembly("vexoticop %ymm0, %ymm1, %ymm2")
    res = analyze(kern, db)
    assert len(res.missing) == 1
    stub = res.missing[0].benchmark_spec()
    assert "vexoticop" in stub and "latency" in stub


def test_zen_double_pump_derivation():
    db = build_zen_db()
    xmm = db.lookup(parse_assembly("vaddpd %xmm1, %xmm2, %xmm3")[0])
    ymm = db.lookup(parse_assembly("vaddpd %ymm1, %ymm2, %ymm3")[0])
    assert ymm.throughput == pytest.approx(2 * xmm.throughput)
    assert sum(u.cycles for u in ymm.uops) == pytest.approx(
        2 * sum(u.cycles for u in xmm.uops))


# ------------------------------------------------------------------ #
# HLO parsing / analyzer
# ------------------------------------------------------------------ #
_HLO = """
HloModule test, entry_computation_layout={()->f32[8,8]{1,0}}

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ip, %d)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main.1 () -> f32[8,8] {
  %c = f32[8,8]{1,0} constant({...})
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%zero, %c)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond.1, body=%body.1
  %ar = f32[8,8]{1,0} all-reduce(%c), replica_groups={{0,1,2,3}}, to_apply=%add_comp
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_parser_and_trip_counts():
    ops, entry = parse_module(_HLO)
    assert entry == "main.1"
    kinds = {o.kind for o in ops}
    assert "while" in kinds and "dot" in kinds
    a = analyze_hlo(_HLO)
    # dot: 2*8*8*8 flops, executed 12 times (trip count from condition)
    assert a.mxu_flops == pytest.approx(2 * 8 * 8 * 8 * 12)
    # all-reduce over 4 devices: 2 * 256B * 3/4
    assert a.ici_bytes == pytest.approx(2 * 256 * 3 / 4)
    assert "all-reduce" in a.collective_breakdown


def test_hlo_operand_resolution_by_name():
    ops, _ = parse_module(_HLO)
    dot = next(o for o in ops if o.kind == "dot")
    assert dot.operand_shapes and dot.operand_shapes[0].dims == (8, 8)
