"""Unified prediction engine: LCD integration in analyze() and the
batched AnalysisService (caching, batch/sweep/async entry points)."""
import asyncio

import pytest

from repro.core import (AnalysisRequest, AnalysisService, analyze,
                        analyze_latency, default_service, extract_kernel)
from repro.core import paper_kernels as pk
from repro.core.arch.skylake import SKYLAKE, build_skylake_db
from repro.core.arch.zen import ZEN

SKL = build_skylake_db()


def _marked(body: str) -> str:
    return pk.marked(body)


# ------------------------------------------------------------------ #
# LCD integration in analyze()
# ------------------------------------------------------------------ #
# A store->load forwarded accumulator chain: the paper's pi -O1 pattern
# reduced to its essence.
_STACK_ACCUM = _marked("""
.L1:
        vaddsd  (%rsp), %xmm0, %xmm1
        vmovsd  %xmm1, (%rsp)
        addl    $1, %eax
        cmpl    $100, %eax
        jne     .L1
""")


def test_store_load_chain_predicts_latency_bound():
    res = analyze(extract_kernel(_STACK_ACCUM), SKL)
    # chain = store->load forward (5.0) + vaddsd latency (4.0)
    assert res.lcd_cycles == pytest.approx(
        SKYLAKE.store_forward_latency + 4.0)
    assert res.lcd_cycles > res.port_bound_cycles
    assert res.binding == "latency"
    assert res.predicted_cycles == pytest.approx(res.lcd_cycles)
    # both bounds visible in the rendered report
    out = res.render()
    assert "Loop-carried dependency" in out
    assert "latency-bound" in out


def test_dependency_free_kernel_predicts_port_bound():
    # unrolled triad: streaming loads/stores, the only loop-carried chain
    # is the 1-cycle index increment
    res = analyze(extract_kernel(pk.TRIAD_SKL_O3), SKL, unroll_factor=4)
    assert res.binding == "throughput"
    assert res.predicted_cycles == pytest.approx(res.port_bound_cycles)
    assert res.lcd_cycles < res.port_bound_cycles
    assert res.port_bound_cycles == pytest.approx(2.00, abs=0.01)


def test_zero_idiom_breaks_dependency_chain():
    chained = _marked("""
.L1:
        vcvtsi2sd       %eax, %xmm0, %xmm0
        vdivsd  %xmm1, %xmm0, %xmm0
        addl    $1, %eax
        cmpl    $100, %eax
        jne     .L1
""")
    broken = _marked("""
.L1:
        vxorpd  %xmm0, %xmm0, %xmm0
        vcvtsi2sd       %eax, %xmm0, %xmm0
        vdivsd  %xmm1, %xmm0, %xmm0
        addl    $1, %eax
        cmpl    $100, %eax
        jne     .L1
""")
    # without the zeroing idiom, vcvtsi2sd's merge semantics chain each
    # iteration's divide into the next
    lcd_chained = analyze_latency(extract_kernel(chained), SKL)
    lcd_broken = analyze_latency(extract_kernel(broken), SKL)
    assert lcd_chained.loop_carried_cycles >= 14.0  # vdivsd latency
    assert lcd_broken.loop_carried_cycles <= 1.0    # only the index add
    res = analyze(extract_kernel(broken), SKL)
    assert res.binding == "throughput"


def test_latency_bound_can_be_disabled():
    res = analyze(extract_kernel(_STACK_ACCUM), SKL, latency_bound=False)
    assert res.latency_result is None
    assert res.predicted_cycles == pytest.approx(res.port_bound_cycles)
    assert res.binding == "throughput"


# ------------------------------------------------------------------ #
# Regression: the paper's pi -O1 Table V outlier (Sec. III-B)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch,measured", [("skl", 9.02), ("zen", 11.48)])
def test_pi_o1_regression_predicts_above_port_bound(arch, measured):
    svc = AnalysisService()
    res = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch=arch))
    assert res.predicted_cycles > res.port_bound_cycles
    assert res.binding == "latency"
    assert abs(res.predicted_cycles - measured) / measured < 0.05
    # expected chain: store->load forward into the stack accumulator add
    assert res.lcd_cycles == pytest.approx(
        (SKYLAKE if arch == "skl" else ZEN).store_forward_latency
        + (4.0 if arch == "skl" else 3.0))


# ------------------------------------------------------------------ #
# AnalysisService: memoization + batch/sweep/async entry points
# ------------------------------------------------------------------ #
def test_service_memoizes_results_and_lookups():
    svc = AnalysisService()
    req = AnalysisRequest(kernel=pk.TRIAD_SKL_O3, arch="skl",
                          unroll_factor=4)
    r1 = svc.predict(req)
    r2 = svc.predict(req)
    assert r1 is r2
    assert svc.stats.result_hits == 1
    assert svc.stats.result_misses == 1
    assert svc.stats.lookup_misses > 0
    svc.cache_clear()
    assert svc.stats.result_hits == 0
    r3 = svc.predict(req)
    assert r3 is not r1
    assert r3.predicted_cycles == pytest.approx(r1.predicted_cycles)


def test_service_memoizes_balanced_lp_across_unrolls():
    svc = AnalysisService()
    svc.predict(AnalysisRequest(kernel=pk.TRIAD_SKL_O3, arch="skl",
                                scheduler="balanced", unroll_factor=4))
    assert svc.stats.lp_misses > 0 and svc.stats.lp_hits == 0
    # different result-cache key, identical uop spec -> LP solves reused
    svc.predict(AnalysisRequest(kernel=pk.TRIAD_SKL_O3, arch="skl",
                                scheduler="balanced", unroll_factor=1))
    assert svc.stats.lp_hits > 0


def test_service_batch_preserves_order():
    svc = AnalysisService()
    reqs = [AnalysisRequest(kernel=pk.PI_O1, arch="skl"),
            AnalysisRequest(kernel=pk.PI_O2, arch="skl"),
            AnalysisRequest(kernel=pk.PI_O1, arch="zen")]
    out = svc.predict_batch(reqs)
    assert [r.model.name for r in out] == \
        ["Intel Skylake", "Intel Skylake", "AMD Zen"]
    par = svc.predict_batch(reqs, parallel=True)
    assert [r.predicted_cycles for r in par] == \
        [r.predicted_cycles for r in out]


def test_service_sweep_grid():
    svc = AnalysisService()
    grid = svc.sweep(
        {"pi_o1": pk.PI_O1, "pi_o2": pk.PI_O2},
        archs=("skl", "zen"), schedulers=("uniform", "balanced"))
    assert len(grid) == 8
    assert grid[("pi_o1", "skl", "uniform")].binding == "latency"
    assert grid[("pi_o2", "skl", "uniform")].binding == "throughput"
    # balanced scheduler can only lower the port bound
    for name in ("pi_o1", "pi_o2"):
        for arch in ("skl", "zen"):
            assert grid[(name, arch, "balanced")].port_bound_cycles \
                <= grid[(name, arch, "uniform")].port_bound_cycles + 1e-6


def test_service_async_entry_point():
    svc = AnalysisService()

    async def go():
        a, b = await asyncio.gather(
            svc.predict_async(AnalysisRequest(kernel=pk.PI_O1,
                                              arch="skl")),
            svc.predict_async(AnalysisRequest(kernel=pk.PI_O2,
                                              arch="skl")))
        return a, b

    a, b = asyncio.run(go())
    assert a.binding == "latency" and b.binding == "throughput"


def test_service_accepts_parsed_kernels_and_custom_dbs():
    svc = AnalysisService()
    kern = tuple(extract_kernel(pk.PI_O2))
    r = svc.predict(AnalysisRequest(kernel=kern, arch="skylake"))
    assert r.port_bound_cycles == pytest.approx(4.25, abs=0.01)
    svc.register_db("myskl", build_skylake_db())
    r2 = svc.predict(AnalysisRequest(kernel=kern, arch="myskl"))
    assert r2.port_bound_cycles == pytest.approx(4.25, abs=0.01)


def test_register_db_invalidates_cached_results():
    from repro.core.arch.zen import build_zen_db
    svc = AnalysisService()
    req = AnalysisRequest(kernel=pk.PI_O2, arch="skl")
    before = svc.predict(req)
    assert before.model.name == "Intel Skylake"
    # registering under an alias spelling must shadow "skl" too
    svc.register_db("skylake", build_zen_db())
    after = svc.predict(req)
    assert after is not before
    assert after.model.name == "AMD Zen"


def test_result_cache_distinguishes_syntax():
    svc = AnalysisService()
    src = "vaddpd ymm0, ymm1, [rax+rcx*8+16]"
    att_fail = svc.predict(AnalysisRequest(kernel=src, arch="skl"))
    intel = svc.predict(AnalysisRequest(kernel=src, arch="skl",
                                        syntax="intel"))
    assert intel is not att_fail
    assert not intel.missing  # parses cleanly as Intel syntax


def test_result_cache_distinguishes_parsed_operand_order():
    from repro.core import parse_assembly
    svc = AnalysisService()
    # same source text, same signature — but opposite dst/src under the
    # two syntaxes; the parsed instructions must not share a cache slot
    src = "mov rax, rbx"
    att = tuple(parse_assembly(src))             # dst = rbx (AT&T order)
    intel = tuple(parse_assembly(src, syntax="intel"))  # dst = rax
    assert att[0].text == intel[0].text
    ra = svc.predict(AnalysisRequest(kernel=att, arch="skl"))
    ri = svc.predict(AnalysisRequest(kernel=intel, arch="skl"))
    assert ra is not ri


def test_default_service_is_shared():
    assert default_service() is default_service()


# ------------------------------------------------------------------ #
# HLO path: combined max(overlap, critical-path) bound
# ------------------------------------------------------------------ #
# An MXU-bound dot feeding an HBM-bound elementwise op: under perfect
# overlap the two phases could hide each other, but the data dependency
# serializes them — the critical-path bound is the TPU analogue of the
# x86 loop-carried-dependency chain.
_HLO = """
HloModule test, entry_computation_layout={()->f32[2048,2048]{1,0}}

ENTRY %main.1 () -> f32[2048,2048] {
  %a = f32[2048,2048]{1,0} constant({...})
  %d = f32[2048,2048]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %s = f32[2048,2048]{1,0} add(%d, %d)
}
"""


def test_hlo_critical_path_and_combined_bound():
    svc = AnalysisService()
    a = svc.predict_hlo(_HLO)
    assert a.terms.critical_path_s > a.terms.bound_overlap
    assert a.terms.bound_combined == pytest.approx(
        a.terms.critical_path_s)
    assert a.terms.binding == "critical-path"
    assert a.terms.bound_combined <= a.terms.bound_serial * (1 + 1e-12)
    out = a.render()
    assert "critical path" in out and "max(overlap, chain)" in out
    # memoized by module digest
    assert svc.predict_hlo(_HLO) is a
    assert svc.stats.hlo_hits == 1


def test_hlo_parallel_ops_stay_throughput_bound():
    hlo = """
HloModule test, entry_computation_layout={()->f32[64,64]{1,0}}

ENTRY %main.1 () -> f32[64,64] {
  %a = f32[64,64]{1,0} constant({...})
  %b = f32[64,64]{1,0} constant({...})
  %x = f32[64,64]{1,0} add(%a, %a)
  %y = f32[64,64]{1,0} add(%b, %b)
  ROOT %d = f32[64,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    a = AnalysisService().predict_hlo(hlo)
    # independent ops: the chain is just the heaviest single op, below
    # the summed per-port occupation
    assert a.terms.critical_path_s <= a.terms.bound_overlap * (1 + 1e-12)
    assert a.terms.binding == "throughput"


def test_serving_engine_dryrun_estimate_uses_combined_bound():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import init_params, model_schema
    from repro.serving.engine import ServingEngine

    cfg = get_smoke_config("qwen2.5-3b")
    params = init_params(model_schema(cfg), jax.random.key(0))
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64)
    svc = AnalysisService()
    est = eng.dryrun_estimate(prompt_len=16, service=svc)
    assert est["prefill_s"] > 0 and est["decode_s_per_token"] > 0
    assert est["prefill_s"] == pytest.approx(
        est["prefill"].terms.bound_combined)
    assert est["tokens_per_s_per_slot"] == pytest.approx(
        1.0 / est["decode_s_per_token"])
    assert svc.stats.hlo_misses == 2  # prefill + decode, one pass each
