"""JIT-compiled vectorized sweep engine: numpy/jit/pallas backend
parity (1e-9), the grouped predict_batch/sweep planner, memoized
preprocessing counters, and the bounded steady-state detector."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional dev dependency
    from repro.testing import given, settings, st

from repro.core import (AnalysisRequest, AnalysisService, extract_kernel)
from repro.core import paper_kernels as pk
from repro.core.arch.skylake import build_skylake_db
from repro.core.arch.zen import build_zen_db
from repro.core.scheduler import SCHEDULERS
from repro.core.sim import (SimProgram, SimUop, compile_program, has_jax,
                            simulate, simulate_many)
from repro.core.sim.batch import _jit_compatible, _steady_state

SKL = build_skylake_db()
ZEN = build_zen_db()

PAPER_KERNELS = {
    "triad_skl": pk.TRIAD_SKL_O3, "triad_zen": pk.TRIAD_ZEN_O3,
    "pi_o1": pk.PI_O1, "pi_o2": pk.PI_O2,
    "pi_skl_o3": pk.PI_SKL_O3, "pi_zen_o3": pk.PI_ZEN_O3,
}

needs_jax = pytest.mark.skipif(not has_jax(), reason="jax not installed")


def _paper_programs():
    progs = []
    for src in PAPER_KERNELS.values():
        for db in (SKL, ZEN):
            progs.append(compile_program(extract_kernel(src), db))
    return progs


# ------------------------------------------------------------------ #
# Backend parity: numpy vs jit (vs pallas) to 1e-9
# ------------------------------------------------------------------ #
@needs_jax
def test_driver_parity_numpy_vs_jit_on_paper_kernels():
    progs = _paper_programs()
    rn = simulate_many(progs, backend="numpy")
    rj = simulate_many(progs, backend="jit")
    for n, j in zip(rn, rj):
        assert abs(n.cycles_per_iteration - j.cycles_per_iteration) \
            <= 1e-9
        assert n.converged == j.converged
        assert n.bottleneck == j.bottleneck


@needs_jax
def test_driver_parity_pallas_interpret():
    """The Pallas arbitration step (interpreter mode off-TPU) must be
    arithmetically identical to the inline lax formulation."""
    progs = [compile_program(extract_kernel(pk.PI_O1), SKL),
             compile_program(extract_kernel(pk.PI_O2), SKL)]
    rj = simulate_many(progs, backend="jit")
    rp = simulate_many(progs, backend="pallas")
    for j, p in zip(rj, rp):
        assert abs(j.cycles_per_iteration - p.cycles_per_iteration) \
            <= 1e-9


@needs_jax
@pytest.mark.parametrize("scheduler", sorted(SCHEDULERS))
@pytest.mark.parametrize("arch", ["skl", "zen"])
def test_service_sweep_parity_all_kernels(arch, scheduler):
    """Service-level parity: every paper kernel, each architecture and
    every registered scheduler — numpy and jit sweeps agree to 1e-9 on
    the simulated bound and bit-for-bit on the analytic bounds."""
    svc_np = AnalysisService(sim_backend="numpy")
    svc_jit = AnalysisService(sim_backend="jit")
    gn = svc_np.sweep(PAPER_KERNELS, archs=(arch,),
                      schedulers=(scheduler,), mode="simulate")
    gj = svc_jit.sweep(PAPER_KERNELS, archs=(arch,),
                       schedulers=(scheduler,), mode="simulate")
    assert gn.keys() == gj.keys()
    for key in gn:
        a, b = gn[key], gj[key]
        assert abs(a.bound_sim - b.bound_sim) <= 1e-9, key
        assert a.port_bound_cycles == b.port_bound_cycles
        assert a.lcd_cycles == b.lcd_cycles
        assert a.binding == b.binding


def test_sweep_backend_numpy_matches_legacy_pi_anchor():
    """The grouped numpy sweep still reproduces the paper anchors
    (pi -O1: 9.0 cy/it SKL, ~11.5 Zen)."""
    svc = AnalysisService(sim_backend="numpy")
    grid = svc.sweep({"pi_o1": pk.PI_O1}, archs=("skl", "zen"),
                     mode="simulate")
    assert grid[("pi_o1", "skl", "uniform")].bound_sim == \
        pytest.approx(9.0)
    assert grid[("pi_o1", "zen", "uniform")].bound_sim >= 11.0


# ------------------------------------------------------------------ #
# Property test: random padded batches mixing architectures
# ------------------------------------------------------------------ #
def _random_program(draw, db):
    n_instr = draw(st.integers(min_value=1, max_value=5))
    model = db.model
    uops = []
    latency = []
    for idx in range(n_instr):
        latency.append(float(draw(st.integers(1, 5))))
        for _ in range(draw(st.integers(0, 2))):
            ports = draw(st.sets(st.sampled_from(model.ports),
                                 min_size=1, max_size=2))
            uops.append(SimUop(instr_index=idx,
                               ports=tuple(sorted(ports)),
                               cycles=float(draw(st.integers(1, 2)))))
    edges = []
    for _ in range(draw(st.integers(0, 4))):
        src = draw(st.integers(0, n_instr - 1))
        dst = draw(st.integers(0, n_instr - 1))
        w = float(draw(st.integers(0, 4)))
        wrap = draw(st.booleans())
        if src == dst and not wrap:
            continue            # intra self-loop is not a dependency
        if src > dst and not wrap:
            src, dst = dst, src  # intra edges point forward
        edges.append((src, dst, w, wrap))
    return SimProgram(model=model, n_instructions=n_instr,
                      uops=tuple(uops), latency=tuple(latency),
                      edges=tuple(edges))


@needs_jax
@settings(max_examples=10, deadline=None)
@given(st.data())
def test_property_random_mixed_arch_batches(data):
    """numpy and jit agree to 1e-9 on random padded batches that mix
    machine models, uop counts, port sets and dependency shapes."""
    n = data.draw(st.integers(min_value=2, max_value=6))
    progs = [_random_program(data.draw,
                             data.draw(st.sampled_from([SKL, ZEN])))
             for _ in range(n)]
    rn = simulate_many(progs, backend="numpy", n_iterations=48)
    rj = simulate_many(progs, backend="jit", n_iterations=48)
    for a, b in zip(rn, rj):
        assert abs(a.cycles_per_iteration - b.cycles_per_iteration) \
            <= 1e-9
        assert a.converged == b.converged


# ------------------------------------------------------------------ #
# Grouped planner: dispatch counts, dedupe, caches
# ------------------------------------------------------------------ #
def test_sweep_dispatches_once_per_machine_group():
    svc = AnalysisService(sim_backend="numpy")
    grid = svc.sweep(PAPER_KERNELS, archs=("skl", "zen"),
                     schedulers=("uniform", "balanced"), mode="simulate")
    assert len(grid) == len(PAPER_KERNELS) * 4
    # 24 cells -> 12 unique (arch, kernel) programs -> 2 model groups
    assert svc.stats.sim_runs == len(PAPER_KERNELS) * 2
    assert svc.stats.sim_group_dispatches == 2
    assert svc.stats.program_misses == len(PAPER_KERNELS) * 2
    # the analytic LCD pass and the simulator share the edge memo
    assert svc.stats.edge_hits > 0
    assert svc.stats.hit_rate("edge") > 0


def test_predict_batch_dedupes_and_fills_result_cache():
    svc = AnalysisService(sim_backend="numpy")
    req = AnalysisRequest(kernel=pk.PI_O1, arch="skl", mode="simulate")
    out = svc.predict_batch([req, req, req])
    assert out[0] is out[1] is out[2]
    # mirrors the sequential path: the simulate cell plus its implicit
    # analytic base are the two misses; the duplicates are hits
    assert svc.stats.result_misses == 2
    assert svc.stats.result_hits == 2
    # the single-request path now serves the batch-computed cell
    assert svc.predict(req) is out[0]


def test_predict_batch_mixed_modes_preserves_order():
    svc = AnalysisService(sim_backend="numpy")
    reqs = [AnalysisRequest(kernel=pk.PI_O1, arch="skl"),
            AnalysisRequest(kernel=pk.PI_O2, arch="skl",
                            mode="simulate"),
            AnalysisRequest(kernel=pk.PI_O1, arch="zen")]
    out = svc.predict_batch(reqs)
    assert [r.model.name for r in out] == \
        ["Intel Skylake", "Intel Skylake", "AMD Zen"]
    assert out[0].sim_result is None
    assert out[1].sim_result is not None
    assert out[1].bound_sim > 0


def test_planner_falls_back_for_exotic_programs():
    """Programs the compiled driver cannot take (non-contiguous
    same-instruction slots) run on the reference path instead."""
    model = SKL.model
    prog = SimProgram(
        model=model, n_instructions=2,
        uops=(SimUop(0, ("0",)), SimUop(1, ("1",)), SimUop(0, ("0",))),
        latency=(1.0, 1.0), edges=())
    assert not _jit_compatible([prog], model.pipeline)
    contiguous = SimProgram(
        model=model, n_instructions=2,
        uops=(SimUop(0, ("0",)), SimUop(0, ("0",)), SimUop(1, ("1",))),
        latency=(1.0, 1.0), edges=())
    assert _jit_compatible([contiguous], model.pipeline)
    # simulate_many routes the exotic program to numpy — individually,
    # without downgrading compatible programs sharing its group
    paper = compile_program(extract_kernel(pk.PI_O1), SKL)
    out = simulate_many([prog, paper, contiguous], backend="jit")
    ref = simulate_many([prog, paper, contiguous], backend="numpy")
    for o, r in zip(out, ref):
        assert abs(o.cycles_per_iteration - r.cycles_per_iteration) \
            <= 1e-9
    assert out[1].cycles_per_iteration == pytest.approx(9.0)


def test_sim_program_digest_is_content_addressed():
    p1 = compile_program(extract_kernel(pk.PI_O1), SKL)
    p2 = compile_program(extract_kernel(pk.PI_O1), SKL)
    p3 = compile_program(extract_kernel(pk.PI_O2), SKL)
    assert p1.digest == p2.digest
    assert p1.digest != p3.digest


# ------------------------------------------------------------------ #
# Memoized preprocessing + machine resolution
# ------------------------------------------------------------------ #
def test_service_dependency_edges_memoized():
    svc = AnalysisService()
    e1 = svc.dependency_edges(pk.PI_O1, "skl")
    assert svc.stats.edge_misses == 1 and svc.stats.edge_hits == 0
    e2 = svc.dependency_edges(pk.PI_O1, "skl")
    assert e2 is e1
    assert svc.stats.edge_hits == 1
    # alias spelling resolves to the same machine digest
    assert svc.dependency_edges(pk.PI_O1, "skylake") is e1


def test_classify_memo_counts():
    svc = AnalysisService()
    assert svc._classify_memo(9.0, 2.0, 4.75) == "dependencies"
    assert svc._classify_memo(9.0, 2.0, 4.75) == "dependencies"
    assert svc.stats.classify_misses == 1
    assert svc.stats.classify_hits == 1


def test_resolve_machine_memoized_and_invalidated():
    from repro.core import MachineModel, get_model
    svc = AnalysisService()
    m1 = svc.resolve_machine("skl")
    m2 = svc.resolve_machine("skl")
    assert m1 is m2
    assert svc.stats.machine_misses == 1
    assert svc.stats.machine_hits == 1
    # registering over the id drops the resolution cache
    svc.register(MachineModel.from_json(get_model("zen").to_json())
                 .derive("skl"))
    m3 = svc.resolve_machine("skl")
    assert m3.name == m1.name or m3 is not m1


def test_predict_hlo_batch_single_resolution_and_dedupe():
    hlo = """
HloModule test, entry_computation_layout={()->f32[64,64]{1,0}}

ENTRY %main.1 () -> f32[64,64] {
  %a = f32[64,64]{1,0} constant({...})
  ROOT %d = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    svc = AnalysisService()
    out = svc.predict_hlo_batch([hlo, hlo, hlo])
    assert out[0] is out[1] is out[2]
    assert svc.stats.hlo_misses == 1 and svc.stats.hlo_hits == 0
    assert svc.stats.machine_misses == 1   # resolved once per batch


# ------------------------------------------------------------------ #
# Steady-state detector
# ------------------------------------------------------------------ #
def test_steady_state_caps_scan_and_reports_non_convergence():
    """A trajectory with no periodic pattern must come back with an
    explicit ``converged=False`` and the documented tail-slope
    fallback, not a silently promoted plateau."""
    rng = np.random.RandomState(0)
    drift = np.cumsum(1.0 + rng.rand(64))      # aperiodic deltas
    periodic = np.arange(64) * 3.0             # exact period-1 pattern
    iter_end = np.stack([drift, periodic])
    cpi, conv = _steady_state(iter_end, warmup=4, max_period=4)
    assert not conv[0]
    deltas = np.diff(iter_end[0, 4:])
    assert cpi[0] == pytest.approx(deltas[len(deltas) // 2:].mean())
    assert conv[1]
    assert cpi[1] == pytest.approx(3.0)


def test_pipeline_detector_bounded_history_same_results():
    """The bounded-deque rework of the reference detector must not
    change any steady state (paper anchor: pi -O1 at 9.0 on SKL)."""
    res = simulate(compile_program(extract_kernel(pk.PI_O1), SKL))
    assert res.converged
    assert res.cycles_per_iteration == pytest.approx(9.0)
    # long non-periodic run: detector terminates with explicit flag
    prog = compile_program(extract_kernel(pk.TRIAD_SKL_O3), SKL)
    res2 = simulate(prog, max_iterations=8)
    assert res2.iterations <= 8 or not res2.converged
