"""Health-aware dispatch: breaker-aware routing, retry governance, and
journal compaction (docs/robustness.md).

The invariants pinned here:

* the :class:`HealthRouter` starts every dispatch at the healthiest
  rung — an open rung is skipped *before* a dispatch is paid, a rung
  whose cooldown has elapsed gets at most one scheduled probe per
  window, and when every rung is unhealthy the group takes the
  analytic floor with zero dispatch attempts;
* with the router disabled (the default) the engine is bit-identical
  to the pre-routing behavior — provenance fields stay empty;
* routed results carry ``routed_from`` / ``probe`` provenance end to
  end (engine ``AnalysisResult`` and service ``ServiceResponse``);
* service retries are governed: capped full-jitter backoff, recorded
  sleeps, per-tenant retry budgets that fail fast with an explicit
  reason, and hedged dispatch that races the next rung against a
  straggling primary;
* journal compaction folds loose records into sealed, digest-verified
  segments — readback is ordered, torn segments are skipped, resumed
  sweeps stay bit-identical with zero re-dispatch, and the live file
  count stays bounded by the segment size.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time

import pytest

from repro.core import AnalysisService, paper_kernels as pk
from repro.core.degrade import (BreakerBoard, BreakerConfig,
                                HealthRouter, RoutePlan, RouterConfig)
from repro.core.engine import AnalysisRequest
from repro.core.faults import FaultAbort, FaultPlan, FaultSpec
from repro.core.journal import SweepJournal
from repro.core.sim import has_jax
from repro.checkpoint.store import RecordJournal
from repro.service import (DispatchError, PredictionService,
                           ServiceConfig, ServiceRequest, TenantPolicy,
                           replay)
from repro.service.request import HloRequest

needs_jax = pytest.mark.skipif(not has_jax(),
                               reason="jax not installed")

KERNELS = {"triad_skl": pk.TRIAD_SKL_O3, "pi_o2": pk.PI_O2}


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def _sim_reqs() -> list[AnalysisRequest]:
    return [AnalysisRequest(kernel=src, arch=arch, mode="simulate")
            for arch, src in (("skl", pk.TRIAD_SKL_O3),
                              ("zen", pk.TRIAD_ZEN_O3),
                              ("skl", pk.PI_O2))]


# ----------------------------------------------------------------------
# HealthRouter unit semantics (fake clock, no engine)
# ----------------------------------------------------------------------
def test_route_plan_healthy_start():
    clock = FakeClock()
    board = BreakerBoard(BreakerConfig(), clock=clock)
    router = HealthRouter(clock=clock)
    plan = router.plan(board, "d" * 64, ("jit", "numpy"))
    assert plan == RoutePlan(("jit", "numpy"), "", False)
    assert router.stats["plans"] == 1 and router.stats["routed"] == 0


def test_route_skips_open_rung_without_dispatch():
    clock = FakeClock()
    board = BreakerBoard(BreakerConfig(failure_threshold=1,
                                       cooldown_s=10.0), clock=clock)
    board.breaker("d" * 64, "jit").record_failure()     # open
    router = HealthRouter(clock=clock)
    plan = router.plan(board, "d" * 64, ("jit", "numpy"))
    assert plan.rungs == ("numpy",)
    assert plan.routed_from == "jit" and not plan.probe
    assert router.stats["routed"] == 1
    # the skipped breaker never transitioned: no dispatch was paid
    assert board.breaker("d" * 64, "jit").state == "open"


def test_probe_slot_consumed_once_per_window():
    clock = FakeClock()
    board = BreakerBoard(BreakerConfig(failure_threshold=1,
                                       cooldown_s=10.0), clock=clock)
    board.breaker("d" * 64, "jit").record_failure()
    clock.t = 11.0                                      # cooldown over
    router = HealthRouter(clock=clock)
    # preview never consumes the slot
    seen = router.preview(board, "d" * 64, ("jit", "numpy"))
    assert seen.rungs[0] == "jit" and seen.probe
    assert router.stats["probes"] == 0
    first = router.plan(board, "d" * 64, ("jit", "numpy"))
    assert first.rungs[0] == "jit" and first.probe
    # same window: all other traffic routes below the probing rung
    second = router.plan(board, "d" * 64, ("jit", "numpy"))
    assert second.rungs == ("numpy",)
    assert second.routed_from == "jit" and not second.probe
    # next window: a new probe is scheduled
    clock.t = 21.5
    third = router.plan(board, "d" * 64, ("jit", "numpy"))
    assert third.probe and third.rungs[0] == "jit"
    assert router.stats["probes"] == 2


def test_route_floor_when_every_rung_open():
    clock = FakeClock()
    board = BreakerBoard(BreakerConfig(failure_threshold=1,
                                       cooldown_s=10.0), clock=clock)
    for rung in ("jit", "numpy"):
        board.breaker("d" * 64, rung).record_failure()
    router = HealthRouter(clock=clock)
    plan = router.plan(board, "d" * 64, ("jit", "numpy"))
    assert plan.rungs == () and plan.routed_from == "jit"
    assert router.stats["floor_routes"] == 1


def test_router_json_round_trip_and_reset():
    router = HealthRouter(RouterConfig(probe_interval_s=7.5))
    clone = HealthRouter.from_json(router.to_json())
    assert clone.config == router.config
    assert json.loads(router.to_json()) == router.to_dict()
    router.stats["plans"] = 3
    router.reset()
    assert router.stats == {"plans": 0, "routed": 0, "probes": 0,
                            "floor_routes": 0}
    with pytest.raises(ValueError):
        RouterConfig(probe_interval_s=-1.0)


# ----------------------------------------------------------------------
# engine integration
# ----------------------------------------------------------------------
def test_router_disabled_and_healthy_router_are_bit_identical():
    reqs = _sim_reqs()
    plain = AnalysisService(sim_backend="numpy").predict_batch(reqs)
    routed = AnalysisService(sim_backend="numpy",
                             router=HealthRouter()).predict_batch(reqs)
    for a, b in zip(plain, routed):
        assert a.predicted_cycles == b.predicted_cycles
        assert a.bound_sim == b.bound_sim
        assert (b.routed_from, b.probe) == ("", False)
        assert (a.routed_from, a.probe) == ("", False)


def test_batch_routes_around_open_rung_with_zero_attempts():
    # pallas and jit both die on their first (and only) attempts; from
    # then on the router starts every cohort at numpy without paying a
    # dispatch against the open rungs
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": "pallas"}),
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": "jit"}),))
    svc = AnalysisService(sim_backend="pallas", faults=plan,
                          router=HealthRouter(),
                          breaker_config=BreakerConfig(
                              failure_threshold=1, cooldown_s=3600.0))
    first = svc.predict_batch(_sim_reqs())
    assert all(r.degraded and r.backend_used == "numpy" for r in first)
    attempts_after_trip = dict(svc.stats.rung_attempts)
    svc.drop_results()
    second = svc.predict_batch(_sim_reqs())
    for res in second:
        assert res.routed_from == "pallas" and not res.probe
        assert res.degraded and res.backend_used == "numpy"
        assert math.isfinite(res.predicted_cycles)
    # zero new attempts against the open rungs, numpy attempts grew
    assert svc.stats.rung_attempts.get("pallas", 0) == \
        attempts_after_trip.get("pallas", 0)
    assert svc.stats.rung_attempts.get("jit", 0) == \
        attempts_after_trip.get("jit", 0)
    assert svc.stats.rung_attempts["numpy"] > \
        attempts_after_trip["numpy"]
    assert svc.stats.routed_groups >= 2
    clean = AnalysisService(sim_backend="numpy").predict_batch(
        _sim_reqs())
    for d, c in zip(second, clean):
        assert d.predicted_cycles == c.predicted_cycles


def test_tick_floor_and_scheduled_probe():
    # tick's only fallback is the analytic floor; the fault dies once,
    # so after the cooldown the router schedules exactly one probe and
    # the probe's answer is full fidelity, flagged probe=True
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail", count=1,
                  match={"backend": "tick"}),))
    svc = AnalysisService(faults=plan, router=HealthRouter(),
                          breaker_config=BreakerConfig(
                              failure_threshold=1, cooldown_s=0.05))
    req = AnalysisRequest(kernel=pk.PI_O2, arch="skl", mode="simulate")
    res = svc.predict(req)
    assert res.degraded and res.backend_used == "analytic"
    # while the breaker is open (cooldown pending) the router floors
    # the request without a dispatch attempt
    attempts = svc.stats.rung_attempts.get("tick", 0)
    svc.drop_results()
    res2 = svc.predict(req)
    assert res2.degraded and res2.backend_used == "analytic"
    assert svc.stats.rung_attempts.get("tick", 0) == attempts
    time.sleep(0.08)
    svc.drop_results()
    res3 = svc.predict(req)
    assert res3.probe and not res3.degraded
    # the probe answered on the requested rung: a clean, full-fidelity
    # result (backend_used stays empty like any undegraded answer)
    assert res3.sim_result is not None
    assert svc.stats.probe_dispatches == 1


# ----------------------------------------------------------------------
# service integration: routing provenance, budgets, hedging
# ----------------------------------------------------------------------
def _service_burst(tag: str) -> list[tuple[float, ServiceRequest]]:
    # a full grid burst: large enough that each machine cohort takes
    # the grouped dispatch path (where routing and hedging live), not
    # the small-batch tick path
    cells = [("skl", pk.TRIAD_SKL_O3), ("zen", pk.TRIAD_ZEN_O3),
             ("skl", pk.PI_O1), ("zen", pk.PI_O1),
             ("skl", pk.PI_O2), ("zen", pk.PI_O2),
             ("skl", pk.PI_SKL_O3), ("zen", pk.PI_ZEN_O3)]
    return [(0.0, ServiceRequest(
        analysis=AnalysisRequest(kernel=src, arch=arch,
                                 mode="simulate"),
        tenant="t", tag=tag)) for arch, src in cells]


def test_service_responses_carry_routing_provenance():
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": "pallas"}),
        FaultSpec(point="engine.dispatch", mode="fail",
                  match={"backend": "jit"}),))
    engine = AnalysisService(faults=plan, router=HealthRouter(),
                             breaker_config=BreakerConfig(
                                 failure_threshold=1, cooldown_s=300.0))
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.01, backend="pallas", cache_ttl_s=0.0))
    replay(svc, _service_burst("r0"))     # trips pallas + jit breakers
    engine.drop_results()
    resps = replay(svc, _service_burst("r1"))
    for r in resps:
        assert r.ok and r.routed_from == "pallas" and not r.probe
        assert r.degraded and r.backend_used == "numpy"
        assert r.provenance_of(r.result)["routed_from"] == "pallas"
    stats = svc.export_stats()
    assert stats["router"] is not None
    assert stats["router"]["stats"]["routed"] >= 2
    assert sum(c["routed"] for c in
               stats["cohort_classes"].values()) >= 1
    assert engine.stats.rung_attempts.get("pallas", 0) <= 2


def _hlo_burst(tenant: str) -> list[tuple[float, ServiceRequest]]:
    text = """
HloModule dot64, entry_computation_layout={()->f32[64,64]{1,0}}

ENTRY %main.1 () -> f32[64,64] {
  %a = f32[64,64]{1,0} constant({...})
  ROOT %d = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    return [(0.0, ServiceRequest(hlo=HloRequest(text=text),
                                 tenant=tenant))]


def test_governed_retries_recover_with_recorded_sleeps():
    # two transient parse failures, then clean: the retry loop must
    # recover under capped full-jitter backoff and record every sleep
    engine = AnalysisService(faults=FaultPlan(specs=(
        FaultSpec(point="engine.hlo_parse", mode="fail", count=2),)))
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.005, max_retries=3, retry_backoff_s=0.005,
        retry_backoff_cap_s=0.02))
    resp = replay(svc, _hlo_burst("patient"))[0]
    assert resp.ok
    tele = svc.telemetry
    assert sum(c.retries for c in tele.cohort_classes.values()) == 2
    assert tele.retry_sleep.count == 2
    # capped full jitter can never sleep past the cap
    assert tele.retry_sleep.max <= 0.02 + 1e-9


def test_retry_backoff_is_deterministic_per_seed():
    cfg = ServiceConfig(retry_backoff_s=0.05, retry_backoff_cap_s=0.2,
                        retry_seed=42)
    a = PredictionService(config=cfg)
    b = PredictionService(config=cfg)
    seq_a = [a._backoff_s(i) for i in range(1, 6)]
    seq_b = [b._backoff_s(i) for i in range(1, 6)]
    assert seq_a == seq_b
    assert all(0.0 <= s <= 0.2 for s in seq_a)
    c = PredictionService(config=dataclasses.replace(cfg, retry_seed=7))
    assert [c._backoff_s(i) for i in range(1, 6)] != seq_a


def test_exhausted_retry_budget_fails_fast():
    engine = AnalysisService(faults=FaultPlan(specs=(
        FaultSpec(point="engine.hlo_parse", mode="fail", count=2),)))
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.005, max_retries=3, retry_backoff_s=0.005,
        default_policy=TenantPolicy(retry_rate_per_s=0.0,
                                    retry_burst=0.0)))
    resp = replay(svc, _hlo_burst("broke"))[0]
    assert not resp.ok and isinstance(resp.error, DispatchError)
    assert "retry budget" in str(resp.error)
    assert svc.telemetry.tenant("broke").retry_budget_exhausted == 1
    # no sleep was paid for the denied retry
    assert svc.telemetry.retry_sleep.count == 0


def test_retry_budget_refills_over_time():
    from repro.service import AdmissionController
    ctl = AdmissionController(default_policy=TenantPolicy(
        retry_rate_per_s=1.0, retry_burst=1.0))
    assert ctl.try_retry("t", now=0.0)
    assert not ctl.try_retry("t", now=0.1)
    assert ctl.try_retry("t", now=1.2)      # bucket refilled


@needs_jax
def test_hedged_dispatch_races_next_rung():
    # the primary jit dispatch straggles behind an injected latency
    # fault; after the hedge delay the numpy rung races it and wins.
    # The delay is generous so the hedge still wins on a loaded host.
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="latency", delay_s=2.0,
                  match={"backend": "jit"}),))
    engine = AnalysisService(faults=plan)
    svc = PredictionService(engine, ServiceConfig(
        batch_window_s=0.01, backend="jit", hedge=True,
        hedge_delay_s=0.05))
    resps = replay(svc, _service_burst("h0"))
    assert all(r.ok for r in resps)
    cls = svc.telemetry.cohort_classes
    assert sum(c.hedges for c in cls.values()) >= 1
    assert sum(c.hedge_wins for c in cls.values()) >= 1


def test_hedge_disabled_by_default_and_stats_shape():
    svc = PredictionService(config=ServiceConfig(batch_window_s=0.005))
    resps = replay(svc, _service_burst("plain"))
    assert all(r.ok for r in resps)
    assert all((r.routed_from, r.probe) == ("", False) for r in resps)
    stats = svc.export_stats()
    assert stats["router"] is None
    assert all(c["hedges"] == 0 for c in
               stats["cohort_classes"].values())
    assert stats["stages"]["retry_sleep"]["count"] == 0


# ----------------------------------------------------------------------
# journal compaction
# ----------------------------------------------------------------------
def test_segment_seal_readback_and_append_continues(tmp_path):
    j = RecordJournal(str(tmp_path), segment_size=5)
    for i in range(17):
        j.append({"i": i})
    st = j.stats()
    assert st["records"] == 17 and st["segments"] == 3
    assert st["loose_files"] == 2 and st["bytes"] > 0
    assert [r["i"] for r in j.records()] == list(range(17))
    # a fresh instance reads the same state and appends after the
    # sealed tail
    k = RecordJournal(str(tmp_path), segment_size=5)
    assert [r["i"] for r in k.records()] == list(range(17))
    k.append({"i": 17})
    assert [r["i"] for r in k.records()] == list(range(18))
    # manual compaction folds the remaining loose records
    sealed = k.compact()
    assert sealed == 3 and k.stats()["loose_files"] == 0
    assert [r["i"] for r in k.records()] == list(range(18))


def test_torn_segment_is_skipped_not_fatal(tmp_path):
    j = RecordJournal(str(tmp_path), segment_size=4)
    for i in range(8):
        j.append({"i": i})
    segs = sorted(p for p in os.listdir(tmp_path)
                  if p.startswith("seg_"))
    assert len(segs) == 2
    # corrupt the first segment's checksum footer (torn write)
    victim = tmp_path / segs[0]
    victim.write_text(victim.read_text()[:-10] + "deadbeef!\n")
    k = RecordJournal(str(tmp_path), segment_size=4)
    assert [r["i"] for r in k.records()] == [4, 5, 6, 7]


def test_segment_size_none_keeps_loose_layout(tmp_path):
    j = RecordJournal(str(tmp_path))
    for i in range(6):
        j.append({"i": i})
    names = os.listdir(tmp_path)
    assert all(n.startswith("rec_") for n in names) and len(names) == 6
    st = j.stats()
    assert st["segments"] == 0 and st["loose_files"] == 6


def test_sweep_journal_compaction_resume_bit_identical(tmp_path):
    sweep_kw = dict(archs=("skl", "zen"), schedulers=("uniform",),
                    mode="simulate")
    reference = AnalysisService(sim_backend="numpy").sweep(
        KERNELS, **sweep_kw)
    plan = FaultPlan(specs=(
        FaultSpec(point="engine.dispatch", mode="abort", skip=1),))
    killed = AnalysisService(sim_backend="numpy", faults=plan)
    with pytest.raises(FaultAbort):
        killed.sweep(KERNELS, journal=str(tmp_path),
                     journal_segment_size=1, **sweep_kw)
    # the surviving group was sealed into a segment before the kill
    assert SweepJournal(str(tmp_path)).stats()["segments"] >= 1
    resumed_svc = AnalysisService(sim_backend="numpy")
    resumed = resumed_svc.sweep(KERNELS, journal=str(tmp_path),
                                resume_from=str(tmp_path),
                                journal_segment_size=1, **sweep_kw)
    assert set(resumed) == set(reference)
    for k in reference:
        assert resumed[k].predicted_cycles == \
            reference[k].predicted_cycles
        assert resumed[k].bound_sim == reference[k].bound_sim
    s = resumed_svc.stats
    assert s.journal_hits == 1 and s.sim_group_dispatches == 1
    # ServiceStats surfaces the on-disk journal footprint
    assert s.journal_records == 2 and s.journal_segments >= 1
    assert s.journal_bytes > 0
