"""Multi-device semantics, run in a subprocess with 8 forced host devices:
distributed (shard_map) MoE == single-device reference, train step on the
test mesh, cache sharding, checkpoint reshard across different meshes,
and the compression codec."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_devices(code: str, n: int = 8) -> str:
    """Run ``code`` in a fresh python with n forced host devices."""
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n}'\n"
            + textwrap.dedent(code))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_distributed_moe_matches_reference():
    out = _run_in_devices("""
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.configs import get_smoke_config
    from repro.models import init_params, model_schema
    from repro.models.moe import moe_ffn
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import activation_sharding, make_rules

    cfg = get_smoke_config('kimi-k2-1t-a32b').with_updates(
        capacity_factor=8.0, moe_token_chunk=32)
    params = init_params(model_schema(cfg), jax.random.key(0))
    w = jax.tree.map(lambda x: x[0], params['stack'][0]['ffn'])
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    ref, aux_ref = moe_ffn(w, x, cfg)          # plain single-device path

    mesh = make_test_mesh()                     # (data=4, model=2)
    rules = make_rules(mesh)
    with mesh, activation_sharding(rules):
        dist, aux_d = jax.jit(lambda w, x: moe_ffn(w, x, cfg))(w, x)
    err = float(jnp.max(jnp.abs(dist.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    print(json.dumps({'err': err,
                      'aux_ref': float(aux_ref),
                      'aux_dist': float(aux_d)}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # bf16 psum + different summation order: loose elementwise tolerance
    assert res["err"] < 0.15, res
    assert res["aux_dist"] == pytest.approx(res["aux_ref"], rel=0.05)


def test_sharded_train_step_runs_and_is_finite():
    out = _run_in_devices("""
    import jax, jax.numpy as jnp, json, numpy as np
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_train_step
    from repro.models.config import ShapeConfig
    from repro.models import init_params, model_schema
    from repro.optim import adamw_init, AdamWConfig
    from repro.parallel.sharding import make_rules, param_shardings

    cfg = get_smoke_config('jamba-1.5-large-398b')
    shape = ShapeConfig('t', seq_len=128, global_batch=8, kind='train')
    mesh = make_test_mesh()
    rules = make_rules(mesh)
    with mesh:
        step = build_train_step(cfg, shape, rules, microbatches=2)
        fn = step.jitted()
        schema = model_schema(cfg)
        shardings = param_shardings(schema, rules)
        params = jax.jit(lambda k: init_params(schema, k),
                         out_shardings=shardings)(jax.random.key(0))
        opt = jax.eval_shape(lambda p: adamw_init(p, AdamWConfig()),
                             params)
        opt = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), opt)
        state = {'params': params, 'opt': opt}
        tokens = jnp.ones((8, 128), jnp.int32)
        from repro.parallel.sharding import activation_sharding
        with activation_sharding(rules):
            state, metrics = fn(state, {'tokens': tokens,
                                        'labels': tokens})
        print(json.dumps({'loss': float(metrics['loss']),
                          'gnorm': float(metrics['grad_norm'])}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert np.isfinite(res["loss"]) and np.isfinite(res["gnorm"])


def test_checkpoint_reshard_across_meshes(tmp_path):
    out = _run_in_devices(f"""
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointStore

    devs = np.asarray(jax.devices())
    mesh_a = Mesh(devs.reshape(4, 2), ('data', 'model'))
    mesh_b = Mesh(devs.reshape(2, 4), ('data', 'model'))
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    tree = {{'w': jax.device_put(
        w, NamedSharding(mesh_a, P('data', 'model')))}}
    store = CheckpointStore({json.dumps(str(tmp_path))})
    store.save(1, tree)
    # reload onto a different mesh layout (elastic restart)
    loaded = store.load(1, jax.eval_shape(lambda: tree),
                        {{'w': NamedSharding(mesh_b, P('model', None))}})
    ok = bool(jnp.all(loaded['w'] == w))
    shard_shape = loaded['w'].sharding.shard_shape(loaded['w'].shape)
    print(json.dumps({{'ok': ok, 'shard': list(shard_shape)}}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"] and res["shard"] == [2, 8]


def test_compression_roundtrip_and_error_feedback():
    import jax
    import jax.numpy as jnp
    from repro.optim.compression import (compress, compress_with_feedback,
                                         decompress)
    g = jax.random.normal(jax.random.key(0), (1000,), jnp.float32)
    codes, scale = compress(g)
    approx = decompress(codes, scale, g.shape)
    rel = float(jnp.linalg.norm(approx - g) / jnp.linalg.norm(g))
    assert rel < 0.01  # int8 block quantisation: <1% energy error
    # error feedback: two-step accumulated error is smaller than naive
    residual = jnp.zeros_like(g)
    total_err = jnp.zeros_like(g)
    for _ in range(8):
        codes, scale, approx, residual = compress_with_feedback(
            g, residual)
        total_err = total_err + (approx - g)
    drift = float(jnp.linalg.norm(total_err / 8) / jnp.linalg.norm(g))
    assert drift < 0.002, drift  # residual cancels bias over steps
