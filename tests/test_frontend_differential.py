"""Differential suite for the front-end model: the three batch backends
(numpy slot sweep, ``jax.jit`` scan, Pallas arbitration step) must agree
to 1e-9 on randomly generated programs with the front end enabled, and
turning every front-end feature off must reproduce the pre-front-end
simulator's numbers *bit-exactly* on the paper kernels.

Random programs are exercised twice: a seeded deterministic sweep that
always runs, and a hypothesis property test that runs when the optional
``[dev]`` dependency is installed.
"""
import dataclasses
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional [dev] dependency
    from repro.testing import given, settings, st

from repro.core import extract_kernel, get_model
from repro.core import paper_kernels as pk
from repro.core.ports import PipelineParams, PortModel
from repro.core.sim import (SimProgram, SimUop, compile_program,
                            has_jax, simulate, simulate_many)

RAND_MODEL = PortModel(name="rand", ports=("0", "1", "2", "3"))

#: a fully enabled SKL-flavoured front end for the random sweeps
FE_PARAMS = PipelineParams(
    issue_width=4, rob_size=64, scheduler_size=40, retire_width=4,
    predecode_width=5, decode_width=4, complex_decode_width=1,
    dsb_width=6, dsb_size=1536, lsd_size=64, macro_fusion=True,
    micro_fusion=True, move_elimination=True, mispredict_penalty=17.0)


def frontend_off(params: PipelineParams) -> PipelineParams:
    """The same backend windows with every front-end feature disabled —
    by construction the pre-front-end simulator's parameter set."""
    return dataclasses.replace(
        params, predecode_width=0, decode_width=0,
        complex_decode_width=1, dsb_width=0, dsb_size=0, lsd_size=0,
        macro_fusion=False, micro_fusion=False, move_elimination=False,
        mispredict_penalty=0.0)


def random_program(rng: random.Random) -> SimProgram:
    """A small random loop body with random fusion capabilities."""
    n_instr = rng.randint(2, 6)
    uops, fuse_prev, eliminable, lat, macro_prev = [], [], [], [], []
    for i in range(n_instr):
        n_u = rng.choice((1, 1, 1, 2, 2, 3))
        for j in range(n_u):
            ports = tuple(sorted(rng.sample(
                RAND_MODEL.ports, rng.randint(1, 2))))
            uops.append(SimUop(i, ports, rng.choice((0.5, 1.0, 1.0))))
            # second uop of an instruction may laminate with the first
            fuse_prev.append(j == 1 and rng.random() < 0.5)
            eliminable.append(n_u == 1 and rng.random() < 0.2)
        lat.append(float(rng.randint(1, 5)))
        macro_prev.append(i > 0 and rng.random() < 0.2)
    edges = [(i, i + 1, lat[i], False) for i in range(n_instr - 1)
             if rng.random() < 0.6]
    if rng.random() < 0.7:   # loop-carried chain
        edges.append((n_instr - 1, 0, lat[-1], True))
    return SimProgram(
        model=RAND_MODEL, n_instructions=n_instr, uops=tuple(uops),
        latency=tuple(lat), edges=tuple(edges),
        fuse_prev=tuple(fuse_prev), eliminable=tuple(eliminable),
        macro_prev=tuple(macro_prev))


def _assert_backends_agree(programs, params):
    ref = simulate_many(programs, params, backend="numpy")
    for backend in ("jit", "pallas"):
        got = simulate_many(programs, params, backend=backend)
        for prog, r, g in zip(programs, ref, got):
            assert g.cycles_per_iteration == pytest.approx(
                r.cycles_per_iteration, abs=1e-9), (
                backend, prog.digest[:12], r.cycles_per_iteration,
                g.cycles_per_iteration)
            assert g.converged == r.converged, (backend, prog.digest)


# ------------------------------------------------------------------ #
# Random differential sweep (seeded, always runs)
# ------------------------------------------------------------------ #
@pytest.mark.skipif(not has_jax(), reason="jax not installed")
@pytest.mark.parametrize("seed", range(6))
def test_random_programs_backends_agree_frontend_on(seed):
    rng = random.Random(1000 + seed)
    programs = [random_program(rng) for _ in range(4)]
    _assert_backends_agree(programs, FE_PARAMS)


@pytest.mark.skipif(not has_jax(), reason="jax not installed")
def test_random_programs_backends_agree_frontend_off():
    rng = random.Random(7)
    programs = [random_program(rng) for _ in range(8)]
    _assert_backends_agree(programs, frontend_off(FE_PARAMS))


@pytest.mark.parametrize("seed", range(4))
def test_random_program_frontend_off_ignores_capabilities(seed):
    """With every feature flag off, the recorded fusion capabilities are
    inert: stripping them from the program must not move the numpy
    sweep's result at all."""
    rng = random.Random(2000 + seed)
    prog = random_program(rng)
    bare = dataclasses.replace(
        prog, fuse_prev=(), eliminable=(), macro_prev=())
    off = frontend_off(FE_PARAMS)
    a = simulate_many([prog], off, backend="numpy")[0]
    b = simulate_many([bare], off, backend="numpy")[0]
    assert a.cycles_per_iteration == b.cycles_per_iteration
    assert a.bottleneck == b.bottleneck


# ------------------------------------------------------------------ #
# Hypothesis property form (runs when the [dev] extra is installed)
# ------------------------------------------------------------------ #
@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31))
def test_property_backends_agree(seed):
    if not has_jax():
        pytest.skip("jax not installed")
    rng = random.Random(seed)
    _assert_backends_agree([random_program(rng)], FE_PARAMS)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31))
def test_property_frontend_off_is_inert(seed):
    rng = random.Random(seed)
    prog = random_program(rng)
    bare = dataclasses.replace(
        prog, fuse_prev=(), eliminable=(), macro_prev=())
    off = frontend_off(FE_PARAMS)
    a = simulate_many([prog], off, backend="numpy")[0]
    b = simulate_many([bare], off, backend="numpy")[0]
    assert a.cycles_per_iteration == b.cycles_per_iteration


# ------------------------------------------------------------------ #
# Features-off reproduces the pre-front-end simulator bit-exactly
# ------------------------------------------------------------------ #
PAPER_CASES = {
    "triad_skl": ("skl", pk.TRIAD_SKL_O3),
    "triad_zen": ("zen", pk.TRIAD_ZEN_O3),
    "pi_skl_O1": ("skl", pk.PI_O1),
    "pi_skl_O2": ("skl", pk.PI_O2),
    "pi_skl_O3": ("skl", pk.PI_SKL_O3),
    "pi_zen_O1": ("zen", pk.PI_O1),
    "pi_zen_O2": ("zen", pk.PI_O2),
    "pi_zen_O3": ("zen", pk.PI_ZEN_O3),
}

#: cycles/iteration of the simulator *before* the front-end model
#: existed (captured at the pre-front-end commit); the reference tick
#: loop and the numpy sweep differed on triad_skl already (documented
#: arbitration-order divergence), so both baselines are pinned
PRE_FRONTEND_TICK = {
    "triad_skl": 2.5, "triad_zen": 2.0, "pi_skl_O1": 9.0,
    "pi_skl_O2": 4.0, "pi_skl_O3": 16.0, "pi_zen_O1": 12.0,
    "pi_zen_O2": 4.0, "pi_zen_O3": 4.0,
}
PRE_FRONTEND_NUMPY = dict(PRE_FRONTEND_TICK, triad_skl=2.25)


@pytest.mark.parametrize("name", list(PAPER_CASES))
def test_features_off_reproduces_pre_frontend_cycles(name):
    arch, src = PAPER_CASES[name]
    prog = compile_program(extract_kernel(src), arch)
    off = frontend_off(get_model(arch).pipeline)
    tick = simulate(prog, params=off, max_iterations=200)
    assert tick.cycles_per_iteration == PRE_FRONTEND_TICK[name], name
    assert tick.converged
    sweep = simulate_many([prog], off, backend="numpy")[0]
    assert sweep.cycles_per_iteration == PRE_FRONTEND_NUMPY[name], name
    assert sweep.converged


@pytest.mark.skipif(not has_jax(), reason="jax not installed")
@pytest.mark.parametrize("name", ["triad_skl", "pi_zen_O2"])
def test_features_off_jit_matches_numpy_baseline(name):
    arch, src = PAPER_CASES[name]
    prog = compile_program(extract_kernel(src), arch)
    off = frontend_off(get_model(arch).pipeline)
    for backend in ("jit", "pallas"):
        res = simulate_many([prog], off, backend=backend)[0]
        assert res.cycles_per_iteration == PRE_FRONTEND_NUMPY[name], \
            (name, backend)


# ------------------------------------------------------------------ #
# Front end ON: the paper kernels across all three batch backends
# ------------------------------------------------------------------ #
@pytest.mark.skipif(not has_jax(), reason="jax not installed")
def test_paper_kernels_backends_agree_frontend_on():
    programs = [compile_program(extract_kernel(src), arch)
                for arch, src in PAPER_CASES.values()]
    _assert_backends_agree(programs, None)
